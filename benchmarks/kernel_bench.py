"""Kernel micro-benchmarks (CPU wall-clock of the XLA reference paths; the
Pallas kernels are validated in interpret mode and TARGET the TPU — CPU
timings of interpret mode are meaningless, so what we time here is the
packed-vs-dense REPRESENTATION effect that survives on any backend, plus the
spikformer step)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def timeit(f, *args, n=5) -> float:
    f(*args)  # compile
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def run() -> dict:
    out = {}
    kx, kw = jax.random.split(jax.random.PRNGKey(0))

    # packed spike matmul (8 planes in one byte) vs 8 dense fp32 matmuls
    m, k, n = 512, 512, 512
    xp = jax.random.randint(kx, (m, k), 0, 256, jnp.uint8)
    w = jax.random.normal(kw, (k, n))
    dense = jax.random.normal(kx, (8, m, k))

    out["spike_matmul_packed_us"] = timeit(
        jax.jit(lambda a, b: ref.spike_matmul_ref(a, b)), xp, w)
    out["dense_8plane_matmul_us"] = timeit(
        jax.jit(lambda a, b: jnp.einsum("pmk,kn->pmn", a, b)), dense, w)
    out["packed_hbm_bytes"] = int(xp.size)
    out["dense_hbm_bytes"] = int(dense.size * 4)
    out["activation_bytes_saving_x"] = out["dense_hbm_bytes"] / out["packed_hbm_bytes"]

    # STDP associativity: (QK^T)V vs Q(K^TV) wall time at N >> Dh
    q = (jax.random.uniform(kx, (8, 1024, 64)) < 0.3).astype(jnp.float32)
    out["stdp_naive_us"] = timeit(
        jax.jit(lambda a, b, c: jnp.einsum(
            "bnm,bmd->bnd", jnp.einsum("bnd,bmd->bnm", a, b), c)), q, q, q)
    out["stdp_assoc_us"] = timeit(
        jax.jit(lambda a, b, c: jnp.einsum(
            "bnd,bdf->bnf", a, jnp.einsum("bnd,bnf->bdf", b, c))), q, q, q)
    out["stdp_speedup_x"] = out["stdp_naive_us"] / out["stdp_assoc_us"]

    # spikformer reduced fwd+bwd step
    from repro.core.spikformer import SpikformerConfig, init, loss_fn
    cfg = SpikformerConfig().scaled()
    p = init(jax.random.PRNGKey(0), cfg)
    img = jax.random.randint(kx, (4, 32, 32, 3), 0, 256, jnp.uint8)
    batch = {"image": img, "label": jnp.array([0, 1, 2, 3])}
    step = jax.jit(jax.grad(lambda pp: loss_fn(pp, batch, cfg)[0]))
    out["spikformer_reduced_grad_us"] = timeit(step, p, n=3)
    return out


def main():
    for k, v in run().items():
        print(f"kernel,{k},{v:.6g}" if isinstance(v, float)
              else f"kernel,{k},{v}")


if __name__ == "__main__":
    main()
