"""Render the §Roofline comparison: baseline vs optimized dry-run records.

  PYTHONPATH=src python -m benchmarks.compare_sweeps \
      --base experiments/dryrun --opt experiments/dryrun_opt [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.roofline import fmt_s


def load(dirpath):
    out = {}
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        r = json.loads(p.read_text())
        out[(r.get("arch"), r.get("shape"), r.get("mesh", "16x16"))] = r
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", default="experiments/dryrun")
    ap.add_argument("--opt", default="experiments/dryrun_opt")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    base = load(args.base)
    opt = load(args.opt)

    print("| arch | shape | bound before | bound after | speedup | dominant "
          "after | peak GB before→after | frac after |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(base):
        arch, shape, mesh = key
        if mesh != args.mesh:
            continue
        b, o = base[key], opt.get(key)
        if "skipped" in b:
            continue
        if o is None or "roofline" not in o or "roofline" not in b:
            continue
        tb, to = b["roofline"], o["roofline"]
        sp = tb["bound_s"] / max(to["bound_s"], 1e-12)
        print(f"| {arch} | {shape} | {fmt_s(tb['bound_s'])} | "
              f"{fmt_s(to['bound_s'])} | **{sp:.2f}x** | "
              f"{to['dominant'].replace('_s','')} | "
              f"{b['memory']['peak_gb_per_chip']}→"
              f"{o['memory']['peak_gb_per_chip']} | "
              f"{to['roofline_frac']} |")


if __name__ == "__main__":
    main()
