"""Benchmark orchestrator: one section per paper table + kernels +
compression transport + the roofline summary (if dry-run records exist).
Every line is ``section,name,value`` CSV.

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import pathlib
import sys
import traceback


def main() -> None:
    from benchmarks import (table1_engine, table2_distribution,
                            table3_buffers, kernel_bench, compression_bench)
    sections = [
        ("table1 (throughput/efficiency)", table1_engine.main),
        ("table2 (compute-time distribution)", table2_distribution.main),
        ("table3 (buffer savings)", table3_buffers.main),
        ("kernels", kernel_bench.main),
        ("compression transport", compression_bench.main),
    ]
    failed = []
    for name, fn in sections:
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()

    from benchmarks import roofline
    for name, d in (("baseline", "experiments/dryrun"),
                    ("optimized (post-§Perf)", "experiments/dryrun_opt")):
        if pathlib.Path(d).exists() and any(pathlib.Path(d).glob("*.json")):
            print(f"# --- roofline, {name} ({d}) ---", flush=True)
            print(roofline.table(roofline.load(d)))

    if failed:
        print(f"FAILED sections: {failed}", file=sys.stderr)
        sys.exit(1)
    print("# all benchmark sections completed")


if __name__ == "__main__":
    main()
