"""Roofline reporting: reads the dry-run records under experiments/dryrun and
renders the §Roofline table (terms in seconds, dominant bottleneck, useful-
flops ratio, roofline fraction) plus the hillclimb shortlist.

  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib


def load(dirpath: str) -> list[dict]:
    recs = []
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        r = json.loads(p.read_text())
        recs.append(r)
    return recs


def fmt_s(x: float) -> str:
    if x >= 0.1:
        return f"{x:.3f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def table(recs: list[dict], mesh: str = "16x16") -> str:
    rows = []
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "peak GB | useful | frac |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skip: {r['skipped'][:40]} | — | — | — |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"ERROR {r['error'][:40]} | — | — | — |")
            continue
        t = r["roofline"]
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['dominant'].replace('_s','')}** | "
            f"{m['peak_gb_per_chip']} | {t['useful_flops_ratio']} | "
            f"{t['roofline_frac']} |")
    return "\n".join(rows)


def shortlist(recs: list[dict]) -> list[dict]:
    """The three hillclimb picks: worst roofline fraction (train cells),
    most collective-bound, most paper-representative."""
    ok = [r for r in recs if "roofline" in r and r.get("mesh") == "16x16"]
    train = [r for r in ok if r["kind"] == "train"]
    worst = min(train, key=lambda r: r["roofline"]["roofline_frac"],
                default=None)
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"], default=None)
    return [r for r in (worst, coll) if r]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(args.dir)
    print(table(recs, args.mesh))
    print()
    for r in shortlist(recs):
        t = r["roofline"]
        print(f"hillclimb-candidate,{r['arch']},{r['shape']},"
              f"{t['dominant']},{t['bound_s']}")


if __name__ == "__main__":
    main()
