"""Paper Table III analog: buffer-size reductions of each proposed method.

The paper states qualitative checkmarks; we quantify them for Spikformer
V2-8-512 @ 224px (T=4):

  STDP  — bytes held for attention: one V column tile vs full N x N scores +
          full V (the paper's 'reduce buffer size' for SSA). We report both
          the ASIC-side counts and the TPU VMEM tile footprint of our Pallas
          kernel schedule.
  TFLIF — output storage: 1 bit/spike packed vs 8-bit accumulators per
          timestep (the Output SRAM saving).
  WSSL  — the MLP2 carry: 192-bit segment buffer vs materializing the
          (2048 -> 512) intermediate per column group.
  ZSC   — conv stem: streaming space-to-depth (no im2col buffer) vs a full
          im2col expansion.

Measured cross-check: peak temp bytes of the chunked STDP jaxpr vs the naive
(QK^T)V jaxpr on a reduced config, from compiled.memory_analysis().
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.spikformer import SpikformerConfig


def analytic(cfg: SpikformerConfig | None = None) -> dict:
    cfg = cfg or SpikformerConfig()
    t, n, d, h = cfg.timesteps, cfg.tokens, cfg.dim, cfg.heads
    dh = d // h

    rows = {}
    # --- STDP ---------------------------------------------------------------
    naive_scores = t * h * n * n * 4            # fp32 scores
    naive_v = t * h * n * dh                    # V spikes held in full (1B)
    stdp_tile = t * h * dh * dh * 4             # K^T V context tile (fp32)
    rows["stdp_naive_bytes"] = naive_scores + naive_v
    rows["stdp_tiled_bytes"] = stdp_tile
    rows["stdp_saving_x"] = (naive_scores + naive_v) / stdp_tile

    # --- TFLIF --------------------------------------------------------------
    per_layer_outputs = n * d                   # one encoder linear's outputs
    rows["tflif_unpacked_bytes"] = t * per_layer_outputs        # int8 / step
    rows["tflif_packed_bytes"] = per_layer_outputs // 8 * t     # 1 bit
    rows["tflif_saving_x"] = 8.0

    # --- WSSL ---------------------------------------------------------------
    # MLP2 (2048 -> 512): 4 column segments of 512; carry = 2 pixels x 4
    # timesteps x 24-bit partials = 192 bits (the paper's number) vs the
    # full hidden map t*n*2048 int8.
    rows["wssl_carry_bits"] = 192
    rows["wssl_naive_intermediate_bytes"] = t * n * (d * cfg.mlp_ratio)
    rows["wssl_saving_x"] = rows["wssl_naive_intermediate_bytes"] / (192 / 8)

    # --- ZSC ----------------------------------------------------------------
    side = cfg.img_size // 2                     # after conv0
    c1 = cfg.scs_channels[0]
    im2col = t * (side // 2) * (side // 2) * (4 * c1)   # 1B spikes expanded
    rows["zsc_im2col_bytes"] = im2col
    rows["zsc_streaming_bytes"] = 4 * c1 * 2 * 8  # two 2x2 groups in flight
    rows["zsc_saving_x"] = im2col / rows["zsc_streaming_bytes"]
    return rows


def measured_stdp_peak() -> dict:
    """Compiled peak-temp bytes: naive (QK^T)V vs K^T-first STDP on one head
    group — the associativity VESTA's tiling exploits, visible to XLA."""
    t, b, h, n, dh = 4, 1, 8, 1024, 64

    def naive(q, k, v):
        s = jnp.einsum("tbhnd,tbhmd->tbhnm", q, k)
        return jnp.einsum("tbhnm,tbhmf->tbhnf", s, v) * 0.125

    def tiled(q, k, v):
        ctx = jnp.einsum("tbhnd,tbhnf->tbhdf", k, v)
        return jnp.einsum("tbhnd,tbhdf->tbhnf", q, ctx) * 0.125

    sds = jax.ShapeDtypeStruct((t, b, h, n, dh), jnp.float32)
    out = {}
    for name, fn in (("naive", naive), ("tiled", tiled)):
        ma = jax.jit(fn).lower(sds, sds, sds).compile().memory_analysis()
        out[f"stdp_{name}_temp_bytes_measured"] = ma.temp_size_in_bytes
    out["stdp_measured_saving_x"] = (
        out["stdp_naive_temp_bytes_measured"]
        / max(out["stdp_tiled_temp_bytes_measured"], 1))
    return out


def run() -> dict:
    rows = analytic()
    rows.update(measured_stdp_peak())
    return rows


def main():
    for k, v in run().items():
        print(f"table3,{k},{v:.6g}" if isinstance(v, float)
              else f"table3,{k},{v}")


if __name__ == "__main__":
    main()
