"""CI gate: compare a fresh infer_bench record against the committed
trajectory (``BENCH_infer.json``) with a tolerance.

Checks against the latest committed record of the SAME mode (smoke vs
smoke, full vs full — timings across configs are not comparable):

  * ``bit_exact`` must hold in the current record (hard gate);
  * the geometric mean over shared (timesteps, weight_dtype) points of
    ``current.packed_speedup / committed.packed_speedup`` must be at least
    ``--min-ratio`` (default 0.4). A real regression — the LUT route
    silently falling off a cliff — drags every point down together; CI
    runner noise hits single points, which a per-point gate would flake on
    and the geomean absorbs.
  * occupancy-sweep and pallas-sweep rows must each stay bit-exact and
    non-lossy vs the baseline (pallas timings are interpret-mode on CPU
    hosts and are never compared — only exactness and row presence gate);
  * serving-under-load rows are non-lossy keyed by (rps, replicas) with
    zero dropped-but-accepted requests; paced fleet rows additionally
    gate SLO attainment 1.0 and 1->2 replica goodput scaling >= 1.5;
  * tracer-overhead rows (``serving_overhead``) are non-lossy; each must
    show tracer-on goodput within 3% of tracer-off (``overhead_ratio >=
    0.97`` — the arrival rate is sub-capacity, so the ratio isolates the
    tracer's hot-path cost) and a lossless ring (``dropped_spans == 0``);
  * event-workload rows (``serving_events``) are non-lossy keyed by
    (trace, replicas), must shed nothing (zero drops AND zero rejections
    — the committed trace is sized under capacity), must hit attainment
    1.0, and must keep the replay determinism flags true (same trace
    twice → identical labels; fleet labels match single-replica labels).

  PYTHONPATH=src python benchmarks/compare_bench.py current.json \
      [--baseline BENCH_infer.json] [--min-ratio 0.4]

``current.json`` may be a single record or a trajectory array (last record
wins). Exits 0 when no committed baseline of the same mode exists yet.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def load_record(path, mode=None):
    data = json.loads(pathlib.Path(path).read_text())
    records = data if isinstance(data, list) else [data]
    if mode is not None:
        records = [r for r in records if r.get("mode", "full") == mode]
    return records[-1] if records else None


def point_key(p):
    return (p["timesteps"], p["weight_dtype"])


def compare(current: dict, baseline: dict, *, min_ratio: float):
    failures = []
    if not current.get("bit_exact", False):
        failures.append("current record is not bit_exact")
    base_points = {point_key(p): p for p in baseline.get("sweep", [])}
    ratios = []
    for p in current.get("sweep", []):
        b = base_points.get(point_key(p))
        if b is None or b["packed_speedup"] <= 0:
            continue
        ratio = p["packed_speedup"] / b["packed_speedup"]
        ratios.append(ratio)
        print(f"T={p['timesteps']}/{p['weight_dtype']}: speedup "
              f"{p['packed_speedup']:.3f} vs committed "
              f"{b['packed_speedup']:.3f} (ratio {ratio:.2f})")
    if not ratios and (baseline.get("sweep") or current.get("sweep")):
        # a silent pass here would let a sweep rename green-light CI forever
        # (occupancy-only records carry no dense/packed sweep at all, so a
        # missing sweep on BOTH sides is fine — there is nothing to lose)
        failures.append("no comparable sweep points between current and "
                        "baseline — re-commit a matching baseline")
        return failures
    # occupancy-sweep rows (sparse-vs-dense LUT at fixed firing rates).
    # Absolute speedups are runner-dependent, but every row must stay
    # bit-exact and the rows themselves are non-lossy: a baseline firing
    # rate that disappears from the current record fails the gate.
    base_occ = {o["firing_rate"]: o for o in baseline.get("occupancy_sweep", [])}
    for o in current.get("occupancy_sweep", []):
        print(f"occupancy rate={o['firing_rate']:g} "
              f"(chunk occ {o['chunk_occupancy']:.3f}, "
              f"budget {o['max_chunks']}/{o['chunks']}): "
              f"sparse {o['sparse_s'] * 1e6:.0f}us vs dense "
              f"{o['dense_s'] * 1e6:.0f}us "
              f"(speedup {o['sparse_speedup']:.2f}x, "
              f"exact={o['exact']})")
        if not o.get("exact", False):
            failures.append(
                f"occupancy rate={o['firing_rate']:g}: sparse route is not "
                f"bit-exact against the dense LUT")
    cur_rates = {o["firing_rate"] for o in current.get("occupancy_sweep", [])}
    for rate in sorted(set(base_occ) - cur_rates):
        failures.append(
            f"occupancy-sweep row for firing rate {rate:g} present in the "
            f"committed baseline but missing from the current record")
    # pallas-route rows (interpret-mode kernels vs their CPU fold-order
    # oracles). The timings are interpreter timings, never compared — the
    # hard gates are exactness per row and non-lossy (route, weight_dtype)
    # coverage: a pallas route that silently drops out of the sweep or
    # stops matching its oracle fails here, not in a later TPU run.
    def pallas_key(r):
        return (r["route"], r["weight_dtype"])

    base_pallas = {pallas_key(r): r for r in baseline.get("pallas_sweep", [])}
    for r in current.get("pallas_sweep", []):
        print(f"pallas {r['route']}/{r['weight_dtype']} "
              f"(t={r['timesteps']}, {r['m']}x{r['k']}x{r['n']}, "
              f"interpret={r.get('interpret')}): "
              f"pallas {r['pallas_s'] * 1e6:.0f}us vs cpu "
              f"{r['cpu_s'] * 1e6:.0f}us (exact={r['exact']})")
        if not r.get("exact", False):
            failures.append(
                f"pallas row {pallas_key(r)}: kernel output is not "
                f"bit-exact against its CPU oracle")
    cur_pallas = {pallas_key(r) for r in current.get("pallas_sweep", [])}
    for key in sorted(set(base_pallas) - cur_pallas):
        failures.append(
            f"pallas-sweep row {key} present in the committed baseline "
            f"but missing from the current record")
    # engine-level serving rows (informational: absolute fps on a CI runner
    # is noise, but the rows must exist so the serving path can't silently
    # drop out of the benchmark)
    for s in current.get("serving", []):
        p95 = s.get("latency_p95_s")
        # latencies are recorded in seconds at microsecond precision
        # (latency_summary rounds to 6 decimals); print them as µs
        p95_us = "n/a" if p95 is None else f"{p95 * 1e6:.0f}us"
        print(f"serving T={s['timesteps']}/{s['weight_dtype']}: "
              f"{s['fps']:.1f} fps (target {s.get('paper_fps', 30.0):.0f}), "
              f"p95 {p95_us}, "
              f"pad_waste {s.get('pad_waste')}")
    if baseline.get("serving") and not current.get("serving"):
        failures.append("baseline has engine-level serving rows but the "
                        "current record lost them")
    # serving-under-load rows (open-loop goodput/p99/SLO — absolute numbers
    # are runner noise, but the rows must survive AND keep the zero-drop
    # contract: an accepted request is a promise). Runtime rows carry no
    # "replicas" field; fleet rows do, plus pace_fps and goodput_scaling.
    def load_key(s):
        return (s["rps"], s.get("replicas"))

    fleet_scaling = {}
    for s in current.get("serving_load", []):
        p99 = s.get("latency_p99_s")
        p99_us = "n/a" if p99 is None else f"{p99 * 1e6:.0f}us"
        tag = ("" if s.get("replicas") is None
               else f" replicas={s['replicas']}"
                    f" pace={s.get('pace_fps')}")
        print(f"serving_load rps={s['rps']:g}{tag}: goodput "
              f"{s['goodput_fps']:.1f} fps, p99 {p99_us}, "
              f"slo_attainment {s.get('slo_attainment')}, "
              f"rejected {s.get('requests_rejected')}, "
              f"dropped {s.get('requests_dropped')}")
        if s.get("requests_dropped", 0):
            failures.append(
                f"serving_load {load_key(s)} dropped "
                f"{s['requests_dropped']} accepted request(s)")
        if s.get("replicas") is not None and s.get("pace_fps") is not None:
            # paced fleet rows model fixed-rate cores, so the SLO numbers
            # are deterministic up to scheduling — attainment below 1.0
            # means the placement/admission logic regressed, not the runner
            if s.get("slo_attainment") != 1.0:
                failures.append(
                    f"fleet row {load_key(s)}: slo_attainment "
                    f"{s.get('slo_attainment')} != 1.0 under paced replicas")
            fleet_scaling[s["replicas"]] = s.get("goodput_scaling")
    if fleet_scaling.get(1) is not None and fleet_scaling.get(2) is not None:
        # the fleet's reason to exist: goodput must scale with replicas.
        # The committed full run shows ~1.85x; 1.5 leaves room for runner
        # scheduling noise while still failing a placement regression that
        # serializes the fleet (scaling ~1.0).
        if fleet_scaling[2] < 1.5:
            failures.append(
                f"fleet goodput scaling 1->2 replicas is "
                f"{fleet_scaling[2]} < 1.5")
    base_load = {load_key(s) for s in baseline.get("serving_load", [])}
    cur_load = {load_key(s) for s in current.get("serving_load", [])}
    for key in sorted(base_load - cur_load,
                      key=lambda k: (k[0], k[1] is not None, k[1] or 0)):
        failures.append(
            f"serving-under-load row (rps, replicas)={key} present in the "
            f"committed baseline but missing from the current record")
    # tracer-overhead rows: serving with the tracer ON must keep goodput
    # within 3% of tracer-off, with a lossless ring. The arrival rate is
    # sub-capacity by design, so both goodputs are arrival-bound and the
    # ratio is stable on a noisy runner — a miss is tracer hot-path cost,
    # not compute jitter.
    OVERHEAD_FLOOR = 0.97
    for s in current.get("serving_overhead", []):
        ratio = s.get("overhead_ratio")
        print(f"serving_overhead rps={s['rps']:g}: goodput off "
              f"{s['goodput_fps_off']:.1f} fps, on "
              f"{s['goodput_fps_on']:.1f} fps (ratio {ratio}), "
              f"{s.get('spans')} spans, dropped {s.get('dropped_spans')}")
        if ratio is None or ratio < OVERHEAD_FLOOR:
            failures.append(
                f"serving_overhead rps={s['rps']:g}: tracer-on/off goodput "
                f"ratio {ratio} below {OVERHEAD_FLOOR} — tracing costs "
                f"real throughput")
        if s.get("dropped_spans", 0):
            failures.append(
                f"serving_overhead rps={s['rps']:g}: ring dropped "
                f"{s['dropped_spans']} spans under bench load — default "
                f"tracer capacity is undersized")
    if (baseline.get("serving_overhead")
            and not current.get("serving_overhead")):
        failures.append("baseline has serving_overhead rows but the "
                        "current record lost them")
    # event-workload rows (bursty DVS trace replay — the trace is sized
    # well under capacity, so ANY shed request is a serving bug, and the
    # replay contract is bit-identical labels: same trace twice at one
    # replica -> same labels_sha; fleet labels match single-replica
    # labels. Cross-RUN label checksums are deliberately NOT compared —
    # logits depend on platform float behavior; determinism is gated
    # within each run, where the flags were computed.)
    def events_key(s):
        return (s["trace"], s["replicas"])

    for s in current.get("serving_events", []):
        p99 = s.get("latency_p99_s")
        p99_us = "n/a" if p99 is None else f"{p99 * 1e6:.0f}us"
        print(f"serving_events {s['trace']} replicas={s['replicas']}: "
              f"{s['windows']} windows, goodput {s['goodput_fps']:.1f} fps, "
              f"p99 {p99_us}, attainment {s.get('slo_attainment')}, "
              f"dispersion {s.get('dispersion_index')}, "
              f"deterministic={s.get('deterministic')}, "
              f"labels_match_single={s.get('labels_match_single')}")
        if s.get("requests_dropped", 0):
            failures.append(
                f"serving_events {events_key(s)} dropped "
                f"{s['requests_dropped']} accepted request(s)")
        if s.get("requests_rejected", 0):
            failures.append(
                f"serving_events {events_key(s)} rejected "
                f"{s['requests_rejected']} request(s) of an under-capacity "
                f"trace")
        if s.get("slo_attainment") != 1.0:
            failures.append(
                f"serving_events {events_key(s)}: slo_attainment "
                f"{s.get('slo_attainment')} != 1.0")
        if s.get("deterministic") is False:
            failures.append(
                f"serving_events {events_key(s)}: double replay of the "
                f"same trace produced different labels")
        if s.get("labels_match_single") is False:
            failures.append(
                f"serving_events {events_key(s)}: fleet labels diverge "
                f"from the single-replica replay")
    base_ev = {events_key(s) for s in baseline.get("serving_events", [])}
    cur_ev = {events_key(s) for s in current.get("serving_events", [])}
    for key in sorted(base_ev - cur_ev):
        failures.append(
            f"serving_events row (trace, replicas)={key} present in the "
            f"committed baseline but missing from the current record")
    if ratios:
        geomean = 1.0
        for r in ratios:
            geomean *= r
        geomean **= 1.0 / len(ratios)
        verdict = "OK" if geomean >= min_ratio else "REGRESSION"
        print(f"{verdict}: geomean ratio {geomean:.3f} over {len(ratios)} "
              f"points (floor {min_ratio:.2f})")
        if geomean < min_ratio:
            failures.append(
                f"geomean speedup ratio {geomean:.3f} < {min_ratio:.2f}")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh infer_bench JSON (record or array)")
    ap.add_argument("--baseline", default=str(REPO_ROOT / "BENCH_infer.json"))
    ap.add_argument("--min-ratio", type=float, default=0.4)
    args = ap.parse_args(argv)

    current = load_record(args.current)
    if current is None:
        print("no current record", file=sys.stderr)
        return 2
    baseline = load_record(args.baseline, mode=current.get("mode", "full"))
    if baseline is None:
        print(f"no committed {current.get('mode', 'full')!r} baseline in "
              f"{args.baseline}; skipping comparison")
        return 0
    failures = compare(current, baseline, min_ratio=args.min_ratio)
    for f in failures:
        print(f"BENCH REGRESSION: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
