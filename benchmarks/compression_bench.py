"""Gradient-compression transport benchmark: HLO collective bytes of the
cross-pod reduction with fp32 vs int8(+scale) payloads, plus the numerics
cost (quantization error with/without error feedback).

The transport measurement lowers a shard_map over an N-device CPU mesh and
counts all-gather/all-reduce payload bytes with the same analyzer the
roofline uses — the wire saving is visible structurally, no TPU needed.
"""
from __future__ import annotations

import os

import numpy as np


def transport_bytes() -> dict:
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.hlo_analysis import analyze
    from repro.optim.compression import compressed_psum_int8

    n = 1 << 20  # 4 MB fp32 gradient shard
    mesh = jax.make_mesh((jax.device_count(),), ("x",))

    def f_fp32(x):
        return jax.lax.pmean(x, "x")

    def f_int8(x):
        return compressed_psum_int8(x, "x")

    out = {}
    for name, f in (("fp32_pmean", f_fp32), ("int8_ef", f_int8)):
        sf = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_rep=False)
        text = jax.jit(sf).lower(
            jax.ShapeDtypeStruct((n,), jnp.float32)).compile().as_text()
        c = analyze(text)
        out[f"{name}_collective_bytes"] = c.collective_total
    return out


def numerics() -> dict:
    import jax
    import jax.numpy as jnp
    from repro.optim.compression import ef_init, ef_compress

    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 0.01}
    out = {}
    # one-shot error
    deq, _ = ef_compress(g, ef_init(g), method="int8")
    out["int8_one_shot_rel_err"] = float(
        jnp.linalg.norm(deq["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    # accumulated with EF over 20 steps of the same grad
    ef = ef_init(g)
    tot = jnp.zeros_like(g["w"])
    for _ in range(20):
        deq, ef = ef_compress(g, ef, method="int8")
        tot += deq["w"]
    out["int8_ef_20step_rel_err"] = float(
        jnp.linalg.norm(tot / 20 - g["w"]) / jnp.linalg.norm(g["w"]))
    return out


def run() -> dict:
    rows = transport_bytes()
    rows.update(numerics())
    return rows


def main():
    for k, v in run().items():
        print(f"compression,{k},{v:.6g}")


if __name__ == "__main__":
    main()
