"""Paper Table II analog: computation-time distribution across the four
dataflows (ZSC / SSSC / WSSL / STDP).

Three columns:
  paper      — the published shares.
  ideal      — our MAC reconstruction at utilization 1.0 for every dataflow.
  calibrated — per-dataflow utilization back-solved from the paper's shares
               + 30 fps (reproduces Table II by construction; the artifact is
               the utilization vector itself, a quantitative statement the
               paper never publishes).

Also measures the REAL flop split of our JAX spikformer forward (reduced
config, counted from the jaxpr) as a cross-check of the reconstruction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine_model import (PAPER_TABLE2, table2_distribution,
                                     implied_utilization, macs_by_method)
from repro.core.spikformer import SpikformerConfig


def measured_flops_split() -> dict:
    """Count einsum/dot FLOPs per dataflow on the reduced config by tracing
    each unified op separately (the model is built from exactly these)."""
    from repro.core import unified
    cfg = SpikformerConfig().scaled(img_size=32, dim=64, depth=2, heads=2)
    t = cfg.timesteps
    key = jax.random.PRNGKey(0)

    def count_matmul_flops(f, *args):
        jaxpr = jax.make_jaxpr(f)(*args)
        total = 0
        def walk(jx):
            nonlocal total
            for eqn in jx.eqns:
                if eqn.primitive.name in ("dot_general",):
                    out = eqn.outvars[0].aval
                    lhs = eqn.invars[0].aval
                    dn = eqn.params["dimension_numbers"]
                    k = 1
                    for d in dn[0][0]:
                        k *= lhs.shape[d]
                    total += 2 * out.size * k
                for v in eqn.params.values():
                    if hasattr(v, "jaxpr"):
                        walk(v.jaxpr)
                    if isinstance(v, (list, tuple)):
                        for vv in v:
                            if hasattr(vv, "jaxpr"):
                                walk(vv.jaxpr)
        walk(jaxpr.jaxpr)
        return total

    side = cfg.img_size
    cin = cfg.in_channels
    out = {"SSSC": 0, "ZSC": 0, "WSSL": 0, "STDP": 0}
    x_img = jnp.zeros((1, side, side, cin), jnp.uint8)
    k0 = jnp.zeros((2, 2, cin, cfg.scs_channels[0]))
    out["SSSC"] += count_matmul_flops(
        lambda a, b: unified.sssc(a, b), x_img, k0)
    side //= 2
    cin = cfg.scs_channels[0]
    for cout in cfg.scs_channels[1:]:
        xs = jnp.zeros((t, 1, side, side, cin))
        kk = jnp.zeros((2, 2, cin, cout))
        out["ZSC"] += count_matmul_flops(
            lambda a, b: unified.zsc(a, b), xs, kk)
        side //= 2
        cin = cout
    n, d, hid = cfg.tokens, cfg.dim, cfg.dim * cfg.mlp_ratio
    xtok = jnp.zeros((t, 1, n, d))
    for _ in range(cfg.depth):
        for (din, dout) in ((d, d), (d, d), (d, d), (d, d), (d, hid), (hid, d)):
            out["WSSL"] += count_matmul_flops(
                lambda a, b: unified.wssl(a, b),
                jnp.zeros((t, 1, n, din)), jnp.zeros((din, dout)))
        dh = d // cfg.heads
        q = jnp.zeros((t, 1, cfg.heads, n, dh))
        out["STDP"] += count_matmul_flops(
            lambda a, b, c: unified.stdp(a, b, c, scale=0.125), q, q, q)
    total = sum(out.values())
    return {k: 100.0 * v / total for k, v in out.items()}


def run() -> dict:
    ideal = table2_distribution(calibrated=False)
    cal = table2_distribution(calibrated=True)
    util = implied_utilization()
    meas = measured_flops_split()
    rows = {}
    for m in ("ZSC", "SSSC", "WSSL", "STDP"):
        rows[f"{m}_paper_pct"] = PAPER_TABLE2[m]
        rows[f"{m}_ideal_pct"] = round(ideal[m], 2)
        rows[f"{m}_calibrated_pct"] = round(cal[m], 2)
        rows[f"{m}_implied_utilization"] = round(util[m], 4)
        rows[f"{m}_measured_flops_pct_reduced"] = round(meas[m], 2)
        rows[f"{m}_gmacs"] = round(macs_by_method()[m] / 1e9, 3)
    return rows


def main():
    for k, v in run().items():
        print(f"table2,{k},{v}")


if __name__ == "__main__":
    main()
