"""Paper Table I analog: engine-level throughput / efficiency.

Reproduces the ASIC-side numbers analytically (4096 PEs @ 500 MHz => 4096
GSOPS peak; 30 fps on 224x224 ImageNet) and derives the TPU-side shadow of
the same workload: MACs/frame, ideal v5e frame time, and the activation-
traffic saving from packed 1-bit spikes (the paper's mux/SRAM trick mapped to
memory bandwidth).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.engine_model import (table1_summary, macs_by_method,
                                     PAPER_CYCLES_PER_FRAME, PE_TOTAL)
from repro.core.spikformer import SpikformerConfig

V5E_PEAK = 197e12
V5E_HBM = 819e9


def activation_bytes_per_frame(cfg: SpikformerConfig, packed: bool) -> int:
    """Bytes of inter-layer activation traffic for one frame (T=4)."""
    t = cfg.timesteps
    side = cfg.img_size
    total_elems = 0
    # SCS outputs
    for cout in cfg.scs_channels:
        side //= 2
        total_elems += t * side * side * cout
    # encoder blocks: q,k,v,attn,o + mlp hidden + mlp out, per block
    n, d, hid = cfg.tokens, cfg.dim, cfg.dim * cfg.mlp_ratio
    per_block = t * n * (4 * d + d + hid + d)
    total_elems += cfg.depth * per_block
    bits = 1 if packed else 8
    return total_elems * bits // 8


def run() -> dict:
    cfg = SpikformerConfig()
    s = table1_summary()
    macs = sum(macs_by_method(cfg).values())

    packed = activation_bytes_per_frame(cfg, packed=True)
    unpacked = activation_bytes_per_frame(cfg, packed=False)

    # TPU shadow: one frame's matmul work at bf16 peak vs its activation
    # traffic at HBM bw — is the spiking workload compute or memory bound?
    t_compute = 2 * macs / V5E_PEAK
    t_mem_packed = packed / V5E_HBM
    t_mem_unpacked = unpacked / V5E_HBM

    rows = {
        **{f"paper_{k}": v for k, v in s.items()},
        "paper_cycles_per_frame": PAPER_CYCLES_PER_FRAME,
        "gmacs_per_frame": macs / 1e9,
        "tpu_ideal_compute_us_frame": t_compute * 1e6,
        "tpu_act_bytes_packed": packed,
        "tpu_act_bytes_int8": unpacked,
        "tpu_mem_us_packed": t_mem_packed * 1e6,
        "tpu_mem_us_int8": t_mem_unpacked * 1e6,
        "packing_traffic_saving_x": unpacked / packed,
        # one v5e chip runs the whole spikformer >= this many fps (compute
        # roofline; the packed memory term is far below it)
        "tpu_roofline_fps": 1.0 / max(t_compute, t_mem_packed),
    }
    return rows


def main():
    for k, v in run().items():
        print(f"table1,{k},{v:.6g}" if isinstance(v, float)
              else f"table1,{k},{v}")


if __name__ == "__main__":
    main()
