"""Inference throughput: packed-bit datapath vs float reference, end to end.

Times the jit-compiled fixed-batch ``InferenceSession`` forward for both
backends over a sweep of (timesteps, weight_dtype) points — by default
T in {4, 16} x {float32, int8}, so the perf trajectory captures both the
plane-group loop overhead (T=16 -> 2 uint8 groups per neuron) and the int8
scale-folded route — and emits ONE JSON record (stdout, and --out FILE) so
successive PRs accumulate a perf trajectory. Also reports the
activation-traffic ratio (the 8x/T-fold packing win that holds on any
backend) and verifies the two paths agree bit-exactly before timing — a
benchmark of a wrong path is worthless.

  PYTHONPATH=src python benchmarks/infer_bench.py [--batch-size 8] [--out f.json]
  PYTHONPATH=src python benchmarks/infer_bench.py --smoke     # tiny, 1 repeat
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spike import num_plane_groups
from repro.core.spikformer import SpikformerConfig, init as spik_init
from repro.infer import InferenceSession, benchmark_session


def run_point(params, cfg, *, timesteps: int, weight_dtype: str,
              batch_size: int, batches: int, seed: int) -> dict:
    """One sweep point: both backends at (timesteps, weight_dtype)."""
    cfg = dataclasses.replace(cfg, timesteps=timesteps)
    sessions = {
        name: InferenceSession(params, cfg, backend=name,
                               batch_size=batch_size,
                               weight_dtype=weight_dtype)
        for name in ("packed", "reference")
    }

    # correctness gate: identical logits on one probe batch
    probe = jax.random.randint(jax.random.PRNGKey(seed + 1),
                               sessions["packed"].input_shape, 0, 256,
                               jnp.uint8)
    exact = bool((np.asarray(sessions["packed"].logits(probe))
                  == np.asarray(sessions["reference"].logits(probe))).all())

    results = {name: benchmark_session(s, batches=batches, seed=seed + 2)
               for name, s in sessions.items()}
    return {
        "timesteps": timesteps,
        "weight_dtype": weight_dtype,
        "plane_groups": num_plane_groups(timesteps),
        "bit_exact": exact,
        "packed": results["packed"],
        "reference": results["reference"],
        "packed_speedup": round(results["packed"]["images_per_s"]
                                / results["reference"]["images_per_s"], 3),
        # storage bytes per activation element between layers:
        # float spikes carry T fp32 values, packed carries ceil(T/8) uint8
        "activation_traffic_ratio": round(
            4.0 * timesteps / num_plane_groups(timesteps), 2),
    }


def run(*, batch_size: int = 8, batches: int = 4, seed: int = 0,
        img_size: int = 32, dim: int = 64, depth: int = 2,
        sweep=((4, "float32"), (4, "int8"), (16, "float32"), (16, "int8")),
        ) -> dict:
    cfg = SpikformerConfig().scaled(img_size=img_size, dim=dim, depth=depth)
    params = spik_init(jax.random.PRNGKey(seed), cfg)

    points = [run_point(params, cfg, timesteps=t, weight_dtype=wd,
                        batch_size=batch_size, batches=batches, seed=seed)
              for t, wd in sweep]

    # PR-1-compatible trajectory fields come from the (4, float32) point
    # when the sweep carries one, else the first point
    base = next((p for p in points
                 if p["timesteps"] == 4 and p["weight_dtype"] == "float32"),
                points[0])
    record = {
        "bench": "infer_spikformer",
        "backend_platform": jax.default_backend(),
        "machine": platform.machine(),
        "config": {"img_size": cfg.img_size, "dim": cfg.dim,
                   "depth": cfg.depth, "heads": cfg.heads,
                   "timesteps": base["timesteps"], "batch_size": batch_size,
                   "batches": batches},
        "bit_exact": all(p["bit_exact"] for p in points),
        "packed": base["packed"],
        "reference": base["reference"],
        "packed_speedup": base["packed_speedup"],
        "activation_traffic_ratio": base["activation_traffic_ratio"],
        "sweep": points,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    # None = "not passed": lets --smoke shrink only unspecified values while
    # an explicit flag always wins
    ap.add_argument("--batch-size", type=int, default=None, help="default 8")
    ap.add_argument("--batches", type=int, default=None, help="default 4")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, 1 repeat — CI gate that the sweep "
                         "runs and stays bit-exact, not a timing")
    ap.add_argument("--out", default=None, help="also append JSON to FILE")
    args = ap.parse_args(argv)

    small = (2, 1) if args.smoke else (8, 4)
    kw = dict(batch_size=small[0] if args.batch_size is None
              else args.batch_size,
              batches=small[1] if args.batches is None else args.batches,
              seed=args.seed)
    if args.smoke:
        kw.update(img_size=16, dim=32, depth=1)

    record = run(**kw)
    line = json.dumps(record)
    print(line)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")
    if not record["bit_exact"]:
        raise SystemExit("packed/reference logits diverged — see record")
    return record


if __name__ == "__main__":
    main()
