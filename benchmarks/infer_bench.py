"""Inference throughput: packed-bit datapath vs float reference, end to end.

Times the jit-compiled fixed-batch compiled step for both
backends over a sweep of (timesteps, weight_dtype) points — by default
T in {4, 16} x {float32, int8}, so the perf trajectory captures both the
plane-group loop overhead (T=16 -> 2 uint8 groups per neuron) and the int8
scale-folded route — and emits ONE JSON record (stdout; ``--out`` appends it
to the committed ``BENCH_infer.json`` trajectory at the repo root, so
successive PRs accumulate a perf history; ``benchmarks/compare_bench.py``
gates CI against it).

Three compiled models per point keep the comparison honest:
  * packed (auto-planned)     — the byte-LUT/unpack datapath being measured;
  * reference (route=unpack)  — the plain single-dot float graph, the
    throughput *denominator* (the planner's fold-order emulation would slow
    the reference and flatter the speedup, so it is never timed as baseline);
  * reference (auto-planned)  — the packed model's bit-exact partner, used
    only for the exactness probe. A benchmark of a wrong path is worthless.

On top of the per-step sweep, a SERVING sweep drives requests through the
micro-batching engine (multi-bucket dispatch) and records achieved fps vs
the paper's 30 fps target, p50/p95 latency, and pad waste — the
engine-level numbers production cares about, in the same trajectory.

A third layer, SERVING UNDER LOAD, replays open-loop Poisson arrival
traces at two rates through ``repro.serve.AsyncServeRuntime`` and records
what a closed-loop drain cannot: goodput, p99 latency, and SLO attainment
(``serving_load`` rows; ``compare_bench.py`` guards them non-lossy keyed
by (rps, replicas)). The same trajectory carries FLEET rows: one trace
replayed through ``ServeFleet`` at 1 and 2 paced replicas
(``pace_fps``-rate emulated cores), gated on goodput scaling and
attainment — the multi-replica serving claim, measured.

The EVENT WORKLOAD layer replays the committed synthetic DVS trace
(``benchmarks/traces/dvs_synth_mini.jsonl``) through 1 and 2 replicas and
records ``serving_events`` rows: the bursty ON/OFF arrival process of an
event camera, gated zero-drop, attainment 1.0, and deterministic (same
trace twice → identical ``labels_sha``; fleet labels match single-replica
labels).

A fourth layer, the PALLAS SWEEP, runs the Pallas kernel routes (VMEM
byte-LUT gather, grouped unpack-dot) against their CPU fold-order oracles
at a tail-timestep/odd-K shape. On a CPU host the kernels execute under
the Pallas interpreter, so each row carries ``interpret: true`` and its
timings measure the interpreter, never the accelerator — the gate is
exactness plus row presence, not speed.

  PYTHONPATH=src python benchmarks/infer_bench.py [--batch-size 8] [--out [f]]
  PYTHONPATH=src python benchmarks/infer_bench.py --smoke     # tiny, CI gate
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spike import (num_plane_groups, pack_timesteps,
                              structured_spikes)
from repro.core.spikformer import SpikformerConfig, init as spik_init
from repro.infer import (ExecutionPlan, MicroBatchEngine, chunk_occupancy,
                         compile as infer_compile)
from repro.kernels import lut_matmul as lut
from repro.kernels import ops
from repro.kernels.lut_matmul import sparse_budget
from repro.events import TRACE_VERSION, load_trace, replay_trace
from repro.obs import Tracer
from repro.serve import (AsyncServeRuntime, ServeFleet, ServePolicy,
                         image_maker, poisson_trace, run_open_loop,
                         run_replica_sweep)

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO_ROOT / "BENCH_infer.json"
DEFAULT_TRACE = REPO_ROOT / "benchmarks" / "traces" / "dvs_synth_mini.jsonl"


def benchmark_model(model, *, batches: int = 4, seed: int = 0,
                    repeats: int = 3) -> dict:
    """Throughput probe: images/sec over ``batches`` full compiled batches
    of random uint8 images at the largest bucket (compile excluded via
    warmup). The window is repeated ``repeats`` times and the best
    wall-time wins — the standard throughput convention, and the only way
    to get a stable number on a noisy shared machine."""
    compile_s = model.warmup()
    imgs = jax.random.randint(jax.random.PRNGKey(seed), model.input_shape(),
                              0, 256, jnp.uint8)
    wall = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        for _ in range(batches):
            jax.block_until_ready(model._fwd(model.folded, imgs))
        wall = min(wall, time.perf_counter() - t0)
    n = batches * model.batch_size
    return {
        "backend": model.backend.name,
        "weight_dtype": model.weight_dtype,
        "batch_size": model.batch_size,
        "images": n,
        "repeats": repeats,
        "compile_s": round(compile_s, 3),
        "wall_s": round(wall, 4),
        "images_per_s": round(n / wall, 2),
    }


def run_point(params, cfg, *, timesteps: int, weight_dtype: str,
              batch_size: int, batches: int, repeats: int, seed: int) -> dict:
    """One sweep point: packed vs plain float reference at (T, weight_dtype),
    with the planned-reference exactness gate."""
    cfg = dataclasses.replace(cfg, timesteps=timesteps)
    plan = ExecutionPlan(weight_dtype=weight_dtype,
                         batch_buckets=(batch_size,))
    packed = infer_compile(params, cfg, plan, backend="packed")
    ref_plain = infer_compile(params, cfg, plan, backend="reference",
                              route="unpack")
    ref_planned = infer_compile(params, cfg, plan, backend="reference")

    # correctness gate: identical logits on one probe batch (the planned
    # reference is the packed model's bit-exact partner)
    probe = jax.random.randint(jax.random.PRNGKey(seed + 1),
                               packed.input_shape(), 0, 256, jnp.uint8)
    exact = bool((np.asarray(packed.logits(probe))
                  == np.asarray(ref_planned.logits(probe))).all())

    results = {
        "packed": benchmark_model(packed, batches=batches, seed=seed + 2,
                                  repeats=repeats),
        "reference": benchmark_model(ref_plain, batches=batches,
                                     seed=seed + 2, repeats=repeats),
    }
    lut_layers = sum(1 for r in packed.plan.routes.values() if r == "lut")
    return {
        "timesteps": timesteps,
        "weight_dtype": weight_dtype,
        "plane_groups": num_plane_groups(timesteps),
        "bit_exact": exact,
        "lut_layers": lut_layers,
        "planned_layers": len(packed.plan.routes),
        "packed": results["packed"],
        "reference": results["reference"],
        "packed_speedup": round(results["packed"]["images_per_s"]
                                / results["reference"]["images_per_s"], 3),
        # storage bytes per activation element between layers:
        # float spikes carry T fp32 values, packed carries ceil(T/8) uint8
        "activation_traffic_ratio": round(
            4.0 * timesteps / num_plane_groups(timesteps), 2),
    }


def _best_time(fn, *, repeats: int) -> float:
    """Best-of-N wall seconds for one already-jitted call."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run_occupancy_sweep(*, rates=(0.1, 0.2, 0.3), m: int, k: int, n: int,
                        repeats: int = 5, seed: int = 0) -> list:
    """Firing-rate sweep: dense byte-LUT vs zero-chunk-skipping gather on
    one spiking linear, at channel-structured spike rates (~10/20/30% —
    realistic trained-Spikformer occupancy, not the ~50% of random test
    weights). The sparse budget is sized the same way a compiled plan
    would: ``sparse_budget`` over the MEASURED chunk occupancy of the
    input. Each row carries an exactness flag — a fast wrong gather is
    worthless — and ``compare_bench.py`` gates the rows non-lossy.
    """
    t = 8
    key = jax.random.PRNGKey(seed + 7)
    kw_key, *rate_keys = jax.random.split(key, len(rates) + 1)
    w = jax.random.normal(kw_key, (k, n), jnp.float32)
    rows = []
    for rate, rk in zip(rates, rate_keys):
        x = structured_spikes(rk, t=t, shape=(m, k), rate=rate)
        occ = chunk_occupancy(x, t)
        c = -(-k // 8)
        budget = sparse_budget(c, occ)
        dense = jax.jit(lambda xx: ops.spike_linear(xx, w, None, t=t,
                                                    route="lut"))
        sparse = jax.jit(lambda xx: ops.spike_linear(xx, w, None, t=t,
                                                     route="lut_sparse",
                                                     occupancy=occ))
        d_out, s_out = dense(x), sparse(x)
        exact = bool((np.asarray(d_out) == np.asarray(s_out)).all())
        dense_s = _best_time(lambda: dense(x), repeats=repeats)
        sparse_s = _best_time(lambda: sparse(x), repeats=repeats)
        rows.append({
            "firing_rate": rate,
            "chunk_occupancy": round(occ, 4),
            "chunks": c,
            "max_chunks": budget,
            "m": m, "k": k, "n": n, "timesteps": t,
            "exact": exact,
            "dense_s": round(dense_s, 6),
            "sparse_s": round(sparse_s, 6),
            "sparse_speedup": round(dense_s / sparse_s, 3),
        })
    return rows


def run_pallas_sweep(*, t: int = 9, m: int = 24, k: int = 33, n: int = 12,
                     rate: float = 0.3, repeats: int = 3,
                     seed: int = 0) -> list:
    """Pallas-route rows: the real kernels (VMEM byte-LUT gather, grouped
    unpack-dot) vs their CPU fold-order oracles on one spiking linear at a
    deliberately awkward shape — tail timesteps (t=9 -> a 1-bit second
    plane group) and an odd K (33 -> a 1-lane tail chunk).

    Every row carries ``interpret``: on a CPU host the kernels run under
    the Pallas interpreter, so ``pallas_s`` times the interpreter, NOT an
    accelerator, and must never feed a speedup gate. What ``compare_bench``
    DOES gate: each row stays bit-exact against its CPU oracle (the same
    defined reduction fold, so equality is exact, not toleranced), and the
    (route, weight_dtype) rows are non-lossy vs the committed baseline.
    The float32 unpack route is reduction-order-tolerant by contract, so
    only routes with a bit-exactness contract appear here.
    """
    rng = np.random.default_rng(seed + 13)
    spikes = jnp.asarray(rng.random((t, m, k)) < rate, jnp.float32)
    x = pack_timesteps(spikes)
    interp = not ops.on_tpu()
    weights = {
        "float32": jnp.asarray(rng.standard_normal((k, n)), jnp.float32),
        "int8": jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8),
    }
    rows = []
    for route, wd in (("lut", "float32"), ("lut", "int8"),
                      ("unpack", "int8")):
        w = weights[wd]
        table = lut.build_lut(w) if route == "lut" else None
        pal = jax.jit(lambda xx, w=w, table=table, route=route:
                      ops.spike_linear(xx, w, None, t=t, pallas=True,
                                       route=route, table=table))
        cpu = jax.jit(lambda xx, w=w, table=table, route=route:
                      ops.spike_linear(xx, w, None, t=t, pallas=False,
                                       route=route, table=table))
        p_out, c_out = pal(x), cpu(x)
        exact = bool((np.asarray(p_out) == np.asarray(c_out)).all())
        rows.append({
            "route": route, "weight_dtype": wd,
            "timesteps": t, "m": m, "k": k, "n": n,
            "interpret": interp, "exact": exact,
            "pallas_s": round(_best_time(lambda: pal(x), repeats=repeats), 6),
            "cpu_s": round(_best_time(lambda: cpu(x), repeats=repeats), 6),
        })
    return rows


def serving_models(params, cfg, *, buckets):
    """Lazy cache of warmed multi-bucket packed models keyed by
    (timesteps, weight_dtype) — the engine-level serving sweep and the
    serving-under-load sweep share one compile per point instead of each
    paying their own."""
    cache = {}

    def get(timesteps: int, weight_dtype: str):
        key = (timesteps, weight_dtype)
        if key not in cache:
            c = dataclasses.replace(cfg, timesteps=timesteps)
            model = infer_compile(params, c,
                                  ExecutionPlan(backend="packed",
                                                weight_dtype=weight_dtype,
                                                batch_buckets=tuple(buckets)))
            cache[key] = (model, model.warmup())
        return cache[key]

    return get


def run_serving(model, compile_s: float, *, timesteps: int,
                weight_dtype: str, requests: int, seed: int) -> dict:
    """Engine-level serving point: Poisson-ish mixed-size requests through
    the micro-batching engine over a multi-bucket compiled model. Reports
    achieved fps vs the paper's 30 fps target, p50/p95 latency, and pad
    waste (the multi-bucket-dispatch metric)."""
    eng = MicroBatchEngine(model)
    rng = np.random.default_rng(seed + 3)
    shape = model.input_shape()[1:]
    for rid in range(requests):
        n = int(rng.integers(1, 4))          # 1-3 images per request
        eng.submit(rng.integers(0, 256, (n, *shape), dtype=np.uint8))
    eng.run()
    stats = eng.stats()
    return {
        "timesteps": timesteps,
        "weight_dtype": weight_dtype,
        "compile_s": round(compile_s, 3),
        **stats,
    }


def run_serving_load(model, *, timesteps: int, weight_dtype: str,
                     rates, duration_s: float, slo_ms: float,
                     seed: int) -> list:
    """Serving-under-load points: the SAME compiled model serves an
    open-loop Poisson trace at each arrival rate through the async runtime.
    Reports goodput, p99 latency, and SLO attainment — arrival-bounded
    numbers the closed-loop serving sweep cannot produce."""
    rows = []
    for rps in rates:
        policy = ServePolicy(max_wait_ms=10.0, slo_ms=slo_ms,
                             max_queue_images=512)
        trace = poisson_trace(rps=rps, duration_s=duration_s,
                              seed=seed + 5, images_per_request=(1, 3))
        with AsyncServeRuntime(model, policy=policy) as rt:
            metrics = run_open_loop(
                rt, trace, image_maker(model.input_shape()[1:],
                                       seed=seed + 6),
                slo_ms=slo_ms)
        stats = rt.stats()
        rows.append({
            "timesteps": timesteps,
            "weight_dtype": weight_dtype,
            "rps": rps,
            "duration_s": duration_s,
            **metrics,
            "pad_waste": stats["pad_waste"],
            "batches": stats["batches"],
        })
    return rows


def run_serving_overhead(model, *, timesteps: int, weight_dtype: str,
                         rps: float, duration_s: float, slo_ms: float,
                         seed: int) -> list:
    """Tracer-overhead row: the SAME open-loop Poisson trace served twice
    through ``AsyncServeRuntime`` — tracer off, then a live ``Tracer``
    recording every lifecycle span — and the goodput ratio between the
    runs. The arrival rate is deliberately sub-capacity, so goodput is
    arrival-bound on both runs and the ratio isolates the tracer's hot-path
    cost (ring append + counter samples) instead of compute jitter:
    a tracer that costs real throughput would push the ratio below
    ``compare_bench.py``'s 0.97 gate. The row also carries the span count
    and ``dropped_spans`` (must be 0 — a lossy ring under bench load means
    the default capacity is undersized)."""
    policy = ServePolicy(max_wait_ms=10.0, slo_ms=slo_ms,
                         max_queue_images=512)
    trace = poisson_trace(rps=rps, duration_s=duration_s, seed=seed + 9,
                          images_per_request=(1, 3))

    def once(tracer):
        with AsyncServeRuntime(model, policy=policy, tracer=tracer) as rt:
            return run_open_loop(
                rt, trace, image_maker(model.input_shape()[1:],
                                       seed=seed + 10),
                slo_ms=slo_ms)

    off = once(None)
    tracer = Tracer()
    on = once(tracer)
    return [{
        "timesteps": timesteps,
        "weight_dtype": weight_dtype,
        "rps": rps,
        "duration_s": duration_s,
        "requests_offered": off["requests_offered"],
        "goodput_fps_off": off["goodput_fps"],
        "goodput_fps_on": on["goodput_fps"],
        "overhead_ratio": (round(on["goodput_fps"] / off["goodput_fps"], 4)
                           if off["goodput_fps"] else None),
        "spans": len(tracer),
        "dropped_spans": tracer.dropped_spans,
    }]


def run_fleet_load(model, *, timesteps: int, weight_dtype: str,
                   rps: float, duration_s: float, slo_ms: float,
                   replica_counts, pace_fps: float, seed: int) -> list:
    """Fleet scaling points: ONE open-loop Poisson trace replayed through
    ``ServeFleet`` at each replica count, same payload bytes per run.

    Each replica is paced as a fixed-rate core at ``pace_fps`` images/s
    (the paper's deployment unit — one VESTA core sustains ~30 fps), so a
    single replica saturates below the offered rate and the sweep measures
    what the fleet adds: placement, admission, and goodput scaling —
    independent of how many host cores the bench machine has. Compute
    still runs (labels are real); ``pace_fps`` is recorded on every row.
    The admission bound is deliberately tight (2 max buckets) so overload
    resolves as rejections with attainment 1.0, never as dropped promises.
    """
    policy = ServePolicy(max_wait_ms=10.0, slo_ms=slo_ms,
                         max_queue_images=2 * max(model.buckets))
    trace = poisson_trace(rps=rps, duration_s=duration_s, seed=seed + 5,
                          images_per_request=(1, 3))
    rows = run_replica_sweep(
        lambda n: ServeFleet(model, replicas=n, policy=policy,
                             pace_fps=pace_fps).start(),
        trace,
        lambda: image_maker(model.input_shape()[1:], seed=seed + 6),
        replica_counts=replica_counts, slo_ms=slo_ms)
    return [{
        "timesteps": timesteps,
        "weight_dtype": weight_dtype,
        "rps": rps,
        "duration_s": duration_s,
        "pace_fps": pace_fps,
        **row,
    } for row in rows]


def run_serving_events(*, trace_path=None, slo_ms: float = 400.0,
                       seed: int = 0, replica_counts=(1, 2)) -> list:
    """Event-workload rows: the committed DVS mini-trace replayed through
    the serving stack at each replica count — the bursty ON/OFF arrival
    process a real event camera produces, not a Poisson approximation.

    Determinism is part of the measurement, not a side note. The
    single-replica point replays the SAME trace twice and records
    ``deterministic`` (within-run ``labels_sha`` equality); every
    multi-replica point records ``labels_match_single`` (its labels vs
    the single-replica replay's). Both flags plus zero drops / zero
    rejections / attainment 1.0 are gated by ``compare_bench.py`` — the
    trace is sized well under one replica's capacity on purpose, so any
    shed request is a serving bug, not an overload artifact."""
    path = pathlib.Path(trace_path or DEFAULT_TRACE)
    trace = load_trace(path)
    cfg = dataclasses.replace(
        SpikformerConfig().scaled(img_size=trace.height, dim=32, depth=1),
        in_channels=trace.channels)
    params = spik_init(jax.random.PRNGKey(seed), cfg)
    model = infer_compile(params, cfg,
                          ExecutionPlan(backend="packed",
                                        batch_buckets=(2, 8)))
    compile_s = model.warmup()
    policy = ServePolicy(max_wait_ms=10.0, slo_ms=slo_ms,
                         max_queue_images=64)

    def replay(n: int) -> dict:
        client = (ServeFleet(model, replicas=n, policy=policy).start()
                  if n > 1 else
                  AsyncServeRuntime(model, policy=policy).start())
        try:
            m = replay_trace(trace, client, slo_ms=slo_ms)
            m["queue_depth_peak"] = client.stats()["queue_depth_peak"]
        finally:
            client.close()
        return m

    rows, single_sha = [], None
    for n in replica_counts:
        m = replay(n)
        row = {
            "trace": path.name,
            "trace_version": TRACE_VERSION,
            "replicas": int(n),
            "windows": m["windows"],
            "trace_duration_s": m["trace_duration_s"],
            "compile_s": round(compile_s, 3),
            "slo_ms": slo_ms,
            "offered_rps": m["offered_rps"],
            "requests_offered": m["requests_offered"],
            "requests_accepted": m["requests_accepted"],
            "requests_rejected": m["requests_rejected"],
            "requests_dropped": m["requests_dropped"],
            "goodput_fps": m["goodput_fps"],
            "latency_p99_s": m["latency_p99_s"],
            "slo_attainment": m["slo_attainment"],
            "dispersion_index": m["dispersion_index"],
            "peak_to_mean_rate": m["peak_to_mean_rate"],
            "queue_depth_peak": m["queue_depth_peak"],
            "labels_sha": m["labels_sha"],
        }
        if n == min(replica_counts):
            again = replay(n)
            row["deterministic"] = again["labels_sha"] == m["labels_sha"]
            single_sha = m["labels_sha"]
        elif single_sha is not None:
            row["labels_match_single"] = m["labels_sha"] == single_sha
        rows.append(row)
    return rows


def run(*, batch_size: int = 8, batches: int = 4, repeats: int = 3,
        seed: int = 0, img_size: int = 32, dim: int = 64, depth: int = 2,
        mode: str = "full",
        sweep=((4, "float32"), (4, "int8"), (16, "float32"), (16, "int8")),
        serving_sweep=((4, "float32"), (16, "int8")),
        serving_requests: int = 24,
        load_point=(4, "float32"),
        load_rates=(64.0, 256.0),
        load_duration_s: float = 2.0,
        load_slo_ms: float = 100.0,
        fleet_replicas=(1, 2),
        fleet_rps: float = 40.0,
        fleet_pace_fps: float = 40.0,
        fleet_slo_ms: float = 1000.0,
        overhead_rps: float = 40.0,
        overhead_duration_s: float = 1.5,
        events_trace=None,
        events_replicas=(1, 2),
        events_slo_ms: float = 400.0,
        occupancy_rates=(0.1, 0.2, 0.3),
        occupancy_shape=(512, 256, 256),
        occupancy_repeats: int = 5,
        occupancy_only: bool = False) -> dict:
    om, ok, on = occupancy_shape
    occupancy_sweep = run_occupancy_sweep(
        rates=occupancy_rates, m=om, k=ok, n=on,
        repeats=occupancy_repeats, seed=seed)
    occ_exact = all(r["exact"] for r in occupancy_sweep)
    pallas_sweep = run_pallas_sweep(repeats=occupancy_repeats, seed=seed)
    pallas_exact = all(r["exact"] for r in pallas_sweep)

    if occupancy_only:
        # the fast-CI shape of the record: just the kernel-level sparsity
        # and pallas-route rows with their exactness gates, no model
        # compiles
        return {
            "bench": "infer_spikformer",
            "mode": mode,
            "backend_platform": jax.default_backend(),
            "machine": platform.machine(),
            "config": {"occupancy_shape": list(occupancy_shape),
                       "occupancy_rates": list(occupancy_rates)},
            "bit_exact": occ_exact and pallas_exact,
            "occupancy_sweep": occupancy_sweep,
            "pallas_sweep": pallas_sweep,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }

    cfg = SpikformerConfig().scaled(img_size=img_size, dim=dim, depth=depth)
    params = spik_init(jax.random.PRNGKey(seed), cfg)

    points = [run_point(params, cfg, timesteps=t, weight_dtype=wd,
                        batch_size=batch_size, batches=batches,
                        repeats=repeats, seed=seed)
              for t, wd in sweep]
    buckets = (max(1, batch_size // 4), batch_size)
    get_model = serving_models(params, cfg, buckets=buckets)
    serving = [run_serving(*get_model(t, wd), timesteps=t, weight_dtype=wd,
                           requests=serving_requests, seed=seed)
               for t, wd in serving_sweep]
    serving_load = run_serving_load(
        get_model(*load_point)[0],
        timesteps=load_point[0], weight_dtype=load_point[1],
        rates=load_rates, duration_s=load_duration_s,
        slo_ms=load_slo_ms, seed=seed)
    # fleet rows live in the same serving_load trajectory, keyed by their
    # "replicas" field (runtime rows carry none)
    serving_load += run_fleet_load(
        get_model(*load_point)[0],
        timesteps=load_point[0], weight_dtype=load_point[1],
        rps=fleet_rps, duration_s=max(load_duration_s, 2.0),
        slo_ms=fleet_slo_ms, replica_counts=fleet_replicas,
        pace_fps=fleet_pace_fps, seed=seed)
    serving_overhead = run_serving_overhead(
        get_model(*load_point)[0],
        timesteps=load_point[0], weight_dtype=load_point[1],
        rps=overhead_rps, duration_s=overhead_duration_s,
        slo_ms=load_slo_ms, seed=seed)
    # the event workload compiles its own DVS-shaped model (2 input
    # channels, sensor-sized), so it does not share the serving cache
    serving_events = run_serving_events(
        trace_path=events_trace, slo_ms=events_slo_ms,
        seed=seed, replica_counts=events_replicas)

    # PR-1-compatible trajectory fields come from the (4, float32) point
    # when the sweep carries one, else the first point
    base = next((p for p in points
                 if p["timesteps"] == 4 and p["weight_dtype"] == "float32"),
                points[0])
    record = {
        "bench": "infer_spikformer",
        "mode": mode,
        "backend_platform": jax.default_backend(),
        "machine": platform.machine(),
        "config": {"img_size": cfg.img_size, "dim": cfg.dim,
                   "depth": cfg.depth, "heads": cfg.heads,
                   "timesteps": base["timesteps"], "batch_size": batch_size,
                   "batches": batches,
                   "occupancy_shape": list(occupancy_shape),
                   "occupancy_rates": list(occupancy_rates)},
        "bit_exact": (all(p["bit_exact"] for p in points)
                      and occ_exact and pallas_exact),
        "packed": base["packed"],
        "reference": base["reference"],
        "packed_speedup": base["packed_speedup"],
        "activation_traffic_ratio": base["activation_traffic_ratio"],
        "sweep": points,
        "occupancy_sweep": occupancy_sweep,
        "pallas_sweep": pallas_sweep,
        "serving": serving,
        "serving_load": serving_load,
        "serving_overhead": serving_overhead,
        "serving_events": serving_events,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    return record


def append_trajectory(record: dict, path) -> None:
    """Append one record to the JSON-array trajectory file (created if
    missing). Each PR's full run adds one point; CI smoke runs compare
    against the latest committed point of the same mode."""
    path = pathlib.Path(path)
    history = []
    if path.exists():
        text = path.read_text()
        try:
            history = json.loads(text)
        except json.JSONDecodeError:
            # pre-PR-3 --out wrote one JSON object per line; absorb those
            # rather than crashing after a multi-minute sweep
            history = [json.loads(line) for line in text.splitlines() if line]
        if not isinstance(history, list):
            history = [history]
    history.append(record)
    path.write_text(json.dumps(history, indent=1) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    # None = "not passed": lets --smoke shrink only unspecified values while
    # an explicit flag always wins
    ap.add_argument("--batch-size", type=int, default=None, help="default 8")
    ap.add_argument("--batches", type=int, default=None, help="default 4")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing windows per session; best wins")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config — CI gate that the sweep runs and "
                         "stays bit-exact, plus a coarse speedup ratio")
    ap.add_argument("--occupancy-only", action="store_true",
                    help="run ONLY the firing-rate sweep (dense vs "
                         "zero-chunk-skipping LUT) — the fast-CI sparsity "
                         "gate; no model compiles")
    ap.add_argument("--out", nargs="?", const=str(DEFAULT_OUT), default=None,
                    help="append the record to this JSON trajectory file "
                         f"(bare --out means {DEFAULT_OUT.name} at the "
                         "repo root)")
    args = ap.parse_args(argv)

    # smoke still times 4-batch windows: a 1-batch window measures a single
    # dispatch and its speedup ratio is pure noise, useless even with a
    # loose comparison tolerance
    small = (2, 4) if args.smoke else (8, 4)
    mode = "smoke" if args.smoke else "full"
    if args.occupancy_only:
        mode = "occupancy_smoke" if args.smoke else "occupancy"
    kw = dict(batch_size=small[0] if args.batch_size is None
              else args.batch_size,
              batches=small[1] if args.batches is None else args.batches,
              repeats=args.repeats, seed=args.seed, mode=mode,
              occupancy_only=args.occupancy_only)
    if args.smoke:
        kw.update(img_size=16, dim=32, depth=1, serving_requests=6,
                  serving_sweep=((4, "float32"),),
                  # still two arrival rates: the acceptance contract is
                  # serving-under-load rows at >= 2 rates, smoke included
                  load_rates=(40.0, 120.0), load_duration_s=0.75,
                  load_slo_ms=150.0, overhead_duration_s=1.0,
                  # smaller single-layer shape, but the SAME 10/20/30%
                  # rates — the sparse-beats-dense gate holds in smoke too
                  occupancy_shape=(256, 256, 128), occupancy_repeats=3)

    record = run(**kw)
    print(json.dumps(record))
    if args.out:
        append_trajectory(record, args.out)
    if not record["bit_exact"]:
        raise SystemExit("packed/reference logits diverged — see record")
    return record


if __name__ == "__main__":
    main()
