"""Inference throughput: packed-bit datapath vs float reference, end to end.

Times the jit-compiled fixed-batch ``InferenceSession`` forward for both
backends on the same reduced Spikformer config and random uint8 images, and
emits ONE JSON record (stdout, and --out FILE) so successive PRs accumulate a
perf trajectory. Also reports the activation-traffic ratio (the 8x/T-fold
packing win that holds on any backend) and verifies the two paths agree
bit-exactly before timing — a benchmark of a wrong path is worthless.

  PYTHONPATH=src python benchmarks/infer_bench.py [--batch-size 8] [--out f.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spikformer import SpikformerConfig, init as spik_init
from repro.infer import InferenceSession, benchmark_session


def run(*, batch_size: int = 8, batches: int = 4, seed: int = 0,
        img_size: int = 32, dim: int = 64, depth: int = 2) -> dict:
    cfg = SpikformerConfig().scaled(img_size=img_size, dim=dim, depth=depth)
    params = spik_init(jax.random.PRNGKey(seed), cfg)

    sessions = {
        name: InferenceSession(params, cfg, backend=name,
                               batch_size=batch_size)
        for name in ("packed", "reference")
    }

    # correctness gate: identical logits on one probe batch
    probe = jax.random.randint(jax.random.PRNGKey(seed + 1),
                               sessions["packed"].input_shape, 0, 256,
                               jnp.uint8)
    exact = bool((np.asarray(sessions["packed"].logits(probe))
                  == np.asarray(sessions["reference"].logits(probe))).all())

    results = {name: benchmark_session(s, batches=batches, seed=seed + 2)
               for name, s in sessions.items()}

    t = cfg.timesteps
    record = {
        "bench": "infer_spikformer",
        "backend_platform": jax.default_backend(),
        "machine": platform.machine(),
        "config": {"img_size": cfg.img_size, "dim": cfg.dim,
                   "depth": cfg.depth, "heads": cfg.heads, "timesteps": t,
                   "batch_size": batch_size, "batches": batches},
        "bit_exact": exact,
        "packed": results["packed"],
        "reference": results["reference"],
        "packed_speedup": round(results["packed"]["images_per_s"]
                                / results["reference"]["images_per_s"], 3),
        # storage bytes per activation element between layers:
        # float spikes carry T fp32 values, packed carries 1 uint8
        "activation_traffic_ratio": 4.0 * t,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also append JSON to FILE")
    args = ap.parse_args(argv)

    record = run(batch_size=args.batch_size, batches=args.batches,
                 seed=args.seed)
    line = json.dumps(record)
    print(line)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")
    return record


if __name__ == "__main__":
    main()
