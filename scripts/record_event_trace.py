#!/usr/bin/env python
"""Regenerate the committed synthetic DVS mini-trace fixture.

    PYTHONPATH=src python scripts/record_event_trace.py \
        --out benchmarks/traces/dvs_synth_mini.jsonl

The fixture is the deterministic synthetic trace the event-serving CI
smoke and the ``serving_events`` bench rows replay: a moving edge over
the first quarter (steady arrivals) followed by flicker bursts (ON/OFF
arrival bursts with silent gaps — empty windows are skipped at capture,
so the burstiness survives into the ARRIVAL process, which is the point).
Same seed → byte-identical file; the name says "synth" because it is —
a recorded-camera trace drops in whenever one lands, same format.
"""
from __future__ import annotations

import argparse
import json

from repro.events import record_trace
from repro.launch.serve_spikformer import synth_event_trace


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="benchmarks/traces/dvs_synth_mini.jsonl")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--height", type=int, default=16)
    ap.add_argument("--width", type=int, default=16)
    args = ap.parse_args(argv)

    trace = synth_event_trace(seed=args.seed, height=args.height,
                              width=args.width)
    n = record_trace(
        args.out, height=trace.height, width=trace.width,
        window_us=trace.window_us, bins=trace.bins, payload=trace.payload,
        arrivals=trace.arrivals,
        meta={"generator": "scripts/record_event_trace.py",
              "seed": args.seed})
    events = sum(len(a.events) for a in trace.arrivals)
    print(json.dumps({"out": args.out, "arrivals": n, "events": events,
                      "duration_s": trace.duration_s,
                      "sensor": [trace.height, trace.width]}))


if __name__ == "__main__":
    main()
