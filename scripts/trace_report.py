"""Summarize a serving trace (``--trace-out`` JSONL) on the terminal.

Reads the versioned span JSONL ``repro.obs.export.write_spans_jsonl``
emits and prints the three views a latency investigation starts with:

  * per-phase breakdown — count / total / mean wall time per span name
    (admit, queue, place, assemble, step, complete, window ops, layers),
  * the top-N slowest requests (the ``complete`` span IS the request's
    latency, so sorting them is the tail),
  * per-replica utilization — each replica's ``step`` time over the trace
    wall, the "is one replica dragging" readout for a fleet trace.

``--assert-complete`` turns the report into a gate (the CI trace-smoke
step): every admitted request must carry its full rid-scoped span chain
(``admit -> queue -> complete``; empty-payload admits legitimately skip
``queue`` — they never enter the queue) and the ring must not have
dropped spans. Exit 1 with the missing rids on violation.

  PYTHONPATH=src python scripts/trace_report.py trace.jsonl \
      [--top 5] [--assert-complete]
"""
from __future__ import annotations

import argparse
import collections
import sys

from repro.obs.export import load_spans_jsonl


def phase_breakdown(spans) -> dict:
    """{(category, name): {"count", "total_s", "mean_s"}} over every
    duration span (counters are instant samples, not phases)."""
    acc = collections.defaultdict(lambda: [0, 0.0])
    for s in spans:
        if s.category == "counter":
            continue
        a = acc[(s.category, s.name)]
        a[0] += 1
        a[1] += s.duration_s
    return {k: {"count": c, "total_s": tot, "mean_s": tot / c}
            for k, (c, tot) in sorted(acc.items())}


def slowest_requests(spans, n: int = 5) -> list:
    """The ``complete`` spans with the largest durations — each one is a
    request's submit-to-done latency."""
    done = [s for s in spans
            if s.category == "request" and s.name == "complete"]
    return sorted(done, key=lambda s: s.duration_s, reverse=True)[:n]


def replica_utilization(spans) -> dict:
    """{replica: step_time / trace_wall} — how much of the trace each
    replica spent inside ``model.step``. Replica None is the single-worker
    engine/runtime lane."""
    if not spans:
        return {}
    wall = (max(s.t1 for s in spans) - min(s.t0 for s in spans)) or 1.0
    busy = collections.defaultdict(float)
    for s in spans:
        if s.category == "batch" and s.name == "step":
            busy[s.replica] += s.duration_s
    return {rep: t / wall for rep, t in sorted(
        busy.items(), key=lambda kv: (kv[0] is None, kv[0]))}


def check_complete(spans, dropped_spans: int) -> list:
    """Every admitted request's rid-scoped chain must close. Returns the
    violations (empty list = the trace passes)."""
    by_rid = collections.defaultdict(set)
    admit_value = {}
    for s in spans:
        if s.category != "request" or s.rid is None:
            continue
        by_rid[s.rid].add(s.name)
        if s.name == "admit":
            admit_value[s.rid] = s.value
    problems = []
    if dropped_spans:
        problems.append(f"ring dropped {dropped_spans} spans — the trace "
                        "is lossy; raise the tracer capacity")
    for rid in sorted(r for r in by_rid if "admit" in by_rid[r]):
        names = by_rid[rid]
        missing = {"complete"} - names
        # a zero-image admit completes at the door and never queues
        if admit_value.get(rid):
            missing |= {"queue"} - names
        if missing:
            problems.append(
                f"rid {rid}: admitted but missing {sorted(missing)} "
                f"(has {sorted(names)})")
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="span JSONL from --trace-out")
    ap.add_argument("--top", type=int, default=5,
                    help="how many slowest requests to show")
    ap.add_argument("--assert-complete", action="store_true",
                    help="exit 1 unless every admitted request has a "
                         "complete span chain and zero spans were dropped")
    args = ap.parse_args(argv)

    header, spans = load_spans_jsonl(args.trace)
    dropped = int(header.get("dropped_spans", 0))
    print(f"{args.trace}: {len(spans)} spans, dropped_spans={dropped}")

    print("\nper-phase breakdown:")
    for (cat, name), row in phase_breakdown(spans).items():
        print(f"  {cat:>8s}/{name:<12s} n={row['count']:<6d} "
              f"total={row['total_s'] * 1e3:9.3f}ms "
              f"mean={row['mean_s'] * 1e3:8.3f}ms")

    slow = slowest_requests(spans, args.top)
    if slow:
        print(f"\ntop {len(slow)} slowest requests:")
        for s in slow:
            rep = "" if s.replica is None else f" replica={s.replica}"
            print(f"  rid={s.rid:<6} latency={s.duration_s * 1e3:8.3f}ms"
                  f"{rep}")

    util = replica_utilization(spans)
    if util:
        print("\nper-replica step utilization:")
        for rep, frac in util.items():
            lane = "worker" if rep is None else f"replica {rep}"
            print(f"  {lane:<10s} {frac * 100:6.2f}%")

    if args.assert_complete:
        problems = check_complete(spans, dropped)
        if problems:
            print("\nFAIL: incomplete trace", file=sys.stderr)
            for p in problems:
                print(f"  {p}", file=sys.stderr)
            return 1
        n_req = sum(1 for s in spans
                    if s.category == "request" and s.name == "admit")
        print(f"\nOK: all {n_req} admitted requests have complete span "
              "chains, 0 dropped")
    return 0


if __name__ == "__main__":
    sys.exit(main())
