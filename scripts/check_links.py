#!/usr/bin/env python3
"""Fail on broken *relative* links in the repo's markdown docs.

Scans README.md, docs/*.md and every README.md under src/ (plus any extra
paths given on argv) for ``[text](target)`` links, resolves relative targets
against the containing file, and exits 1 listing every target that does not
exist. http(s)/mailto links and pure #anchors are skipped — this is a
docs-rot gate for the file tree we control, not a network checker.

  python scripts/check_links.py [extra.md ...]
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def targets(md_path: pathlib.Path):
    for m in LINK_RE.finditer(md_path.read_text()):
        raw = m.group(1)
        if raw.startswith(SKIP_PREFIXES):
            continue
        yield raw, (md_path.parent / raw.split("#")[0]).resolve()


def main(argv):
    root = pathlib.Path(__file__).resolve().parent.parent
    files = [root / "README.md", *sorted((root / "docs").glob("*.md")),
             *sorted((root / "src").rglob("README.md")),
             *(pathlib.Path(a).resolve() for a in argv)]
    broken = []
    checked = 0
    for f in files:
        if not f.exists():
            broken.append((f, "(file itself missing)"))
            continue
        for raw, resolved in targets(f):
            checked += 1
            if not resolved.exists():
                rel = f.relative_to(root) if f.is_relative_to(root) else f
                broken.append((rel, raw))
    if broken:
        for f, raw in broken:
            print(f"BROKEN LINK in {f}: {raw}", file=sys.stderr)
        return 1
    print(f"check_links: {checked} relative links OK "
          f"across {len(files)} files")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
