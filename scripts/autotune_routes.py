"""Autotune the ``choose_route`` cost constants on this host.

The dispatch heuristic (``kernels/lut_matmul.py:choose_route``) compares a
cost model of the byte-LUT gather route against the unpack-then-dot route:

    lut_cost    = t*M*C*N * gather_cost * [cache_penalty]  +  G*M*K * transpose_cost
    unpack_cost = t*M*K * (N + unpack_cost)

in units of one dot FMA. The committed defaults were hand-fit to one
container's CPU; this script refits them FROM MEASUREMENT: it times both
routes of ``ops.spike_linear`` over a small (M, K, N, G) grid, solves the
model's coefficients by least squares (everything is linear in the
constants once normalized by the FMA unit), and emits the result as an
``ExecutionPlan`` JSON fragment — paste or ``--out`` it, then

    plan = ExecutionPlan.from_json(open("routes.json").read())
    model = compile(params, cfg, plan)

serves under the tuned dispatch. Only the *decisions* change; every route
stays bit-exact, so a bad fit costs throughput, never correctness.

``--pallas`` additionally times the Pallas kernel pair (VMEM byte-LUT
gather vs grouped unpack-dot) over a small grid and refits the
``choose_pallas_route`` constants (``pallas_gather_cost`` /
``pallas_dot_cost``) in the same FMA unit. On a CPU host those kernels
run under the Pallas interpreter — the samples are flagged and the fit
describes the interpreter, so refit on a TPU host before committing the
constants to a servable plan.

``--profile`` runs the OTHER measurement this script owns: instead of
timing isolated (M, K, N, G) grid points, it compiles a reduced
Spikformer and times every layer of one real forward in place
(``CompiledModel.profile_step`` — sync-barriered, eager ops), printing
the per-layer table and a per-route aggregate. The grid fit answers
"what should the cost constants be"; the profile answers "where does a
real step's time actually go under the routes those constants chose".

  PYTHONPATH=src python scripts/autotune_routes.py [--fast] [--pallas] \
      [--profile] [--out routes.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spike
from repro.kernels import ops
from repro.kernels import lut_matmul as lut
from repro.kernels.lut_matmul import RouteConstants

# (m, k, n, g) grid: spans the repo's real layer shapes (conv stem rows x
# small K through encoder linears) without taking minutes. t = 8*g keeps
# every plane live.
GRID = [
    (64, 32, 16, 1), (64, 64, 64, 1), (256, 32, 64, 1), (256, 64, 16, 1),
    (512, 32, 32, 1), (512, 64, 64, 1), (1024, 12, 8, 1), (1024, 64, 32, 2),
    (2048, 32, 16, 1), (256, 128, 128, 1),
]
FAST_GRID = GRID[:5]

# Pallas grid: small shapes with varied chunk counts (C in {2..5}) and a
# multi-group point. Deliberately tiny — on a CPU host every point runs
# under the Pallas interpreter, whose cost still scales with the same
# traffic volumes the cost model uses, just with a huge unit.
PALLAS_GRID = [
    (32, 16, 8, 1), (32, 32, 16, 1), (64, 16, 16, 1),
    (64, 40, 8, 1), (48, 24, 24, 2),
]


def time_call(fn, *args, repeats: int = 3, inner: int = 4) -> float:
    """Best-of-``repeats`` wall time of ``inner`` back-to-back calls,
    compile excluded (one untimed call first)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def measure_point(m: int, k: int, n: int, g: int, *, repeats: int = 3,
                  seed: int = 0) -> dict:
    """Time unpack vs LUT for one (M, K, N, G) shape. Returns a sample."""
    t = 8 * g
    key = jax.random.PRNGKey(seed)
    x = jax.random.randint(key, (g, m, k), 0, 256, jnp.uint8)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    table = lut.build_lut(w)

    unpack = jax.jit(lambda xx: ops.spike_linear(xx, w, t=t, pallas=False,
                                                 route="unpack"))
    gather = jax.jit(lambda xx: ops.spike_linear(xx, w, t=t, pallas=False,
                                                 route="lut", table=table))
    return {
        "m": m, "k": k, "n": n, "g": g, "t": t,
        "c": lut.num_k_chunks(k),
        "table_bytes": lut.table_bytes(k, n, False),
        "unpack_s": time_call(unpack, x, repeats=repeats),
        "lut_s": time_call(gather, x, repeats=repeats),
    }


def measure_grid(grid=GRID, *, repeats: int = 3, seed: int = 0) -> list:
    samples = []
    for m, k, n, g in grid:
        s = measure_point(m, k, n, g, repeats=repeats, seed=seed)
        print(json.dumps(s))
        samples.append(s)
    return samples


def measure_sparse_point(m: int, k: int, n: int, g: int, rate: float, *,
                         repeats: int = 3, seed: int = 0) -> dict | None:
    """Time the dense LUT route against the zero-chunk-skipping route on
    channel-structured spikes at firing rate ``rate``. Returns None when
    the measured chunk occupancy leaves no budget headroom (sparse route
    would just be the dense gather)."""
    t = 8 * g
    key = jax.random.PRNGKey(seed + 1000)
    x = spike.structured_spikes(key, t=t, shape=(m, k), rate=rate)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    table = lut.build_lut(w)
    c = lut.num_k_chunks(k)
    occ = float(jnp.mean(lut.plane_indices(x)[:t] != 0))
    budget = lut.sparse_budget(c, occ)
    if budget >= c:
        return None
    dense = jax.jit(lambda xx: ops.spike_linear(xx, w, t=t, pallas=False,
                                                route="lut", table=table))
    sparse = jax.jit(lambda xx: ops.spike_linear(
        xx, w, t=t, pallas=False, route="lut_sparse", table=table,
        occupancy=occ))
    return {
        "m": m, "k": k, "n": n, "g": g, "t": t, "c": c,
        "rate": rate, "occupancy": round(occ, 4), "budget": budget,
        "table_bytes": lut.table_bytes(k, n, False),
        "lut_s": time_call(dense, x, repeats=repeats),
        "sparse_s": time_call(sparse, x, repeats=repeats),
    }


def measure_sparse_grid(grid=GRID, rates=(0.1, 0.2, 0.3), *,
                        repeats: int = 3, seed: int = 0) -> list:
    samples = []
    for m, k, n, g in grid:
        if k % 8:                      # structured spikes need whole chunks
            continue
        for rate in rates:
            s = measure_sparse_point(m, k, n, g, rate,
                                     repeats=repeats, seed=seed)
            if s is not None:
                print(json.dumps(s))
                samples.append(s)
    return samples


def measure_pallas_point(m: int, k: int, n: int, g: int, *,
                         repeats: int = 3, seed: int = 0) -> dict:
    """Time the Pallas byte-LUT gather kernel against the Pallas grouped
    unpack-dot kernel for one (M, K, N, G) shape. ``interpret`` flags
    whether the kernels ran under the Pallas interpreter (any non-TPU
    host) — such timings calibrate the interpreter, not an accelerator."""
    t = 8 * g
    key = jax.random.PRNGKey(seed + 2000)
    x = jax.random.randint(key, (g, m, k), 0, 256, jnp.uint8)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    table = lut.build_lut(w)
    gather = jax.jit(lambda xx: ops.spike_linear(xx, w, t=t, pallas=True,
                                                 route="lut", table=table))
    dot = jax.jit(lambda xx: ops.spike_linear(xx, w, t=t, pallas=True,
                                              route="unpack"))
    return {
        "m": m, "k": k, "n": n, "g": g, "t": t,
        "c": lut.num_k_chunks(k),
        "interpret": not ops.on_tpu(),
        "pallas_lut_s": time_call(gather, x, repeats=repeats),
        "pallas_dot_s": time_call(dot, x, repeats=repeats),
    }


def measure_pallas_grid(grid=PALLAS_GRID, *, repeats: int = 3,
                        seed: int = 0) -> list:
    samples = []
    for m, k, n, g in grid:
        s = measure_pallas_point(m, k, n, g, repeats=repeats, seed=seed)
        print(json.dumps(s))
        samples.append(s)
    return samples


def _lstsq(X, y):
    """Raw least-squares coefficients — callers validate signs themselves
    (a negative unit cost means the sample set cannot identify the model,
    and the right answer is the committed defaults, not a clamp)."""
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    return coef


def fit_constants(samples: list, *,
                  base: RouteConstants = RouteConstants()) -> RouteConstants:
    """Fit (gather_cost, transpose_cost, unpack_cost) from measured route
    times; cache constants refit only when the grid spans the cache knee.

    unpack_s ~ alpha*(t*m*k*n) + alpha*unpack_cost*(t*m*k): a 2-coefficient
    linear fit gives the FMA unit ``alpha`` (seconds per FMA) and the
    unpack write cost in FMA units. lut_s ~ alpha*gather*(t*m*c*n) +
    alpha*transpose*(g*m*k) reuses that unit, so all constants land in the
    dimensionless form ``choose_route`` compares. Falls back to the
    committed defaults for anything the sample set cannot identify.
    """
    sm = [s for s in samples if s["unpack_s"] > 0 and s["lut_s"] > 0]
    if len(sm) < 3:
        return base

    fma = np.array([s["t"] * s["m"] * s["k"] * s["n"] for s in sm], float)
    wr = np.array([s["t"] * s["m"] * s["k"] for s in sm], float)
    uy = np.array([s["unpack_s"] for s in sm], float)
    a, b = _lstsq(np.stack([fma, wr], 1), uy)
    if not np.isfinite(a) or a <= 0:
        return base                     # FMA unit unidentifiable: keep defaults
    unpack_cost = float(b / a)

    small = [s for s in sm if s["table_bytes"] <= base.cache_bytes]
    large = [s for s in sm if s["table_bytes"] > base.cache_bytes]

    def fit_lut(subset):
        gath = np.array([s["t"] * s["m"] * s["c"] * s["n"] for s in subset],
                        float)
        tr = np.array([s["g"] * s["m"] * s["k"] for s in subset], float)
        ly = np.array([s["lut_s"] for s in subset], float)
        gc, tc = _lstsq(np.stack([gath, tr], 1), ly)
        return float(gc / a), float(tc / a)

    gather_cost, transpose_cost = fit_lut(small if len(small) >= 2 else sm)
    cache_penalty = base.cache_penalty
    if len(large) >= 2 and len(small) >= 2:
        g_large, _ = fit_lut(large)
        if gather_cost > 0:
            cache_penalty = float(np.clip(g_large / gather_cost, 1.0, 16.0))

    clip = lambda v, lo, hi, dflt: (float(np.clip(v, lo, hi))
                                    if np.isfinite(v) and v > 0 else dflt)
    return RouteConstants(
        gather_cost=clip(gather_cost, 0.1, 64.0, base.gather_cost),
        transpose_cost=clip(transpose_cost, 0.1, 64.0, base.transpose_cost),
        unpack_cost=clip(unpack_cost, 0.1, 256.0, base.unpack_cost),
        int_gather_discount=base.int_gather_discount,
        cache_bytes=base.cache_bytes,
        cache_penalty=cache_penalty,
    )


def fit_compact_cost(samples: list, sparse_samples: list, *,
                     base: RouteConstants) -> RouteConstants:
    """Fit the sparse route's per-(index byte x slot) compaction cost from
    measured sparse timings, reusing the dense/unpack fit for everything
    else.

    sparse_s ~ alpha * [t*m*budget*n*gather_cost*cache_penalty
                        + g*m*k*transpose_cost + t*m*c*budget*compact_cost]
    — every term but the last is pinned by ``base`` (the constants just
    fitted from the dense grid), so the residual over the compaction
    volume is a one-coefficient least squares. Falls back to ``base``
    whenever the samples cannot identify a positive cost.
    """
    sm = [s for s in samples if s["unpack_s"] > 0 and s["lut_s"] > 0]
    if len(sparse_samples) < 2 or len(sm) < 3:
        return base
    # re-derive the FMA unit (seconds per dot FMA) exactly as fit_constants
    fma = np.array([s["t"] * s["m"] * s["k"] * s["n"] for s in sm], float)
    wr = np.array([s["t"] * s["m"] * s["k"] for s in sm], float)
    uy = np.array([s["unpack_s"] for s in sm], float)
    alpha, _ = _lstsq(np.stack([fma, wr], 1), uy)
    if not np.isfinite(alpha) or alpha <= 0:
        return base
    resid, vol = [], []
    for s in sparse_samples:
        pen = (1.0 if s["table_bytes"] <= base.cache_bytes
               else base.cache_penalty)
        gather = (s["t"] * s["m"] * s["budget"] * s["n"]
                  * base.gather_cost * pen)
        transpose = s["g"] * s["m"] * s["k"] * base.transpose_cost
        resid.append(s["sparse_s"] / alpha - gather - transpose)
        vol.append(s["t"] * s["m"] * s["c"] * s["budget"])
    compact, = _lstsq(np.array(vol, float)[:, None], np.array(resid, float))
    if not np.isfinite(compact) or compact <= 0:
        return base
    return dataclasses.replace(
        base, compact_cost=float(np.clip(compact, 1.0, 256.0)))


def fit_pallas_constants(samples: list, pallas_samples: list, *,
                         base: RouteConstants) -> RouteConstants:
    """Fit (pallas_gather_cost, pallas_dot_cost) for ``choose_pallas_route``
    from measured Pallas kernel timings, expressed in the SAME FMA unit as
    the CPU fit (``alpha`` re-derived from the unpack samples, so the two
    cost models stay comparable in one RouteConstants). The bit-transpose
    term is pinned at ``base.transpose_cost``; each pallas constant is
    then a one-coefficient least squares over its traffic volume
    (t*M*C*N gathered elements, t*M*K*N dot FMAs). Falls back to ``base``
    whenever the samples cannot identify a positive cost."""
    sm = [s for s in samples if s["unpack_s"] > 0 and s["lut_s"] > 0]
    if len(pallas_samples) < 2 or len(sm) < 3:
        return base
    fma = np.array([s["t"] * s["m"] * s["k"] * s["n"] for s in sm], float)
    wr = np.array([s["t"] * s["m"] * s["k"] for s in sm], float)
    uy = np.array([s["unpack_s"] for s in sm], float)
    alpha, _ = _lstsq(np.stack([fma, wr], 1), uy)
    if not np.isfinite(alpha) or alpha <= 0:
        return base
    gvol = np.array([s["t"] * s["m"] * s["c"] * s["n"]
                     for s in pallas_samples], float)
    gres = np.array([s["pallas_lut_s"] / alpha
                     - s["g"] * s["m"] * s["k"] * base.transpose_cost
                     for s in pallas_samples], float)
    gc, = _lstsq(gvol[:, None], gres)
    dvol = np.array([s["t"] * s["m"] * s["k"] * s["n"]
                     for s in pallas_samples], float)
    dy = np.array([s["pallas_dot_s"] / alpha for s in pallas_samples], float)
    dc, = _lstsq(dvol[:, None], dy)
    # interpreter-fitted constants can be orders of magnitude above an
    # accelerator's; the cap only guards against a degenerate fit blowing
    # up the JSON, relative ordering is what the dispatch compares
    clip = lambda v, dflt: (float(np.clip(v, 0.05, 4096.0))
                            if np.isfinite(v) and v > 0 else dflt)
    return dataclasses.replace(
        base,
        pallas_gather_cost=clip(gc, base.pallas_gather_cost),
        pallas_dot_cost=clip(dc, base.pallas_dot_cost))


def profile_model(*, batch: int = 2, seed: int = 0) -> list:
    """Compile the reduced Spikformer and print ``profile_step``'s
    per-layer measured table plus a per-route aggregate. Returns the rows.

    The reduced config is the same one the test suite and bench harness
    compile, so the layer shapes (hence the route decisions being timed)
    are the repo's real ones, just at calibration scale."""
    from repro.core.spikformer import SpikformerConfig, init
    from repro.infer.compile import ExecutionPlan, compile as infer_compile

    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(seed), cfg)
    model = infer_compile(params, cfg, ExecutionPlan(
        batch_buckets=(batch,), weight_dtype="int8"))
    rows = model.profile_step()
    per_route = {}
    for r in rows:
        print(json.dumps({**r, "seconds": round(r["seconds"], 6)}))
        agg = per_route.setdefault(r["route"], [0, 0.0])
        agg[0] += 1
        agg[1] += r["seconds"]
    total = sum(r["seconds"] for r in rows) or 1.0
    print(json.dumps({
        "profile_batch": batch,
        "layers": len(rows),
        "total_s": round(total, 6),
        "per_route": {route: {"layers": n, "total_s": round(t, 6),
                              "share": round(t / total, 4)}
                      for route, (n, t) in sorted(per_route.items())},
    }, indent=1, sort_keys=True))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="half the grid, one repeat (CI/smoke)")
    ap.add_argument("--profile", action="store_true",
                    help="compile the reduced model and print the per-layer "
                         "measured table (CompiledModel.profile_step) "
                         "instead of fitting route constants")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pallas", action="store_true",
                    help="also time the Pallas kernel pair (interpret mode "
                         "off-TPU) and refit pallas_gather_cost / "
                         "pallas_dot_cost for choose_pallas_route")
    ap.add_argument("--firing-rates", default=None,
                    help="comma-separated firing rates (e.g. 0.1,0.2,0.3): "
                         "also measure the zero-chunk-skipping route on "
                         "structured spikes and fit compact_cost")
    ap.add_argument("--out", default=None,
                    help="write the ExecutionPlan JSON fragment here "
                         "(stdout always gets it)")
    args = ap.parse_args(argv)

    if args.profile:
        return profile_model(seed=args.seed)

    grid = FAST_GRID if args.fast else GRID
    repeats = args.repeats or (1 if args.fast else 3)
    samples = measure_grid(grid, repeats=repeats, seed=args.seed)
    constants = fit_constants(samples)
    sparse_samples = []
    if args.firing_rates:
        rates = tuple(float(r) for r in args.firing_rates.split(","))
        sparse_samples = measure_sparse_grid(grid, rates, repeats=repeats,
                                             seed=args.seed)
        constants = fit_compact_cost(samples, sparse_samples, base=constants)
    pallas_samples = []
    if args.pallas:
        p_grid = PALLAS_GRID[:3] if args.fast else PALLAS_GRID
        pallas_samples = measure_pallas_grid(p_grid, repeats=repeats,
                                             seed=args.seed)
        constants = fit_pallas_constants(samples, pallas_samples,
                                         base=constants)

    # the committable artifact: a fragment ExecutionPlan.from_json accepts
    fragment = {"route_constants": constants.to_dict()}
    text = json.dumps(fragment, indent=1, sort_keys=True)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")

    # sanity: how often the tuned model agrees with measurement on the grid
    agree = sum(
        (ops.choose_route(m=s["m"], k=s["k"], n=s["n"], g=s["g"], t=s["t"],
                          constants=constants) == "lut")
        == (s["lut_s"] < s["unpack_s"]) for s in samples)
    summary = {"grid_points": len(samples),
               "tuned_agreement": f"{agree}/{len(samples)}"}
    if sparse_samples:
        sagree = sum(
            (ops.choose_route(m=s["m"], k=s["k"], n=s["n"], g=s["g"],
                              t=s["t"], constants=constants,
                              occupancy=s["occupancy"]) == "lut_sparse")
            == (s["sparse_s"] < s["lut_s"]) for s in sparse_samples)
        summary["sparse_points"] = len(sparse_samples)
        summary["sparse_agreement"] = f"{sagree}/{len(sparse_samples)}"
    if pallas_samples:
        pagree = sum(
            (ops.choose_pallas_route(m=s["m"], k=s["k"], n=s["n"], g=s["g"],
                                     t=s["t"], constants=constants) == "lut")
            == (s["pallas_lut_s"] < s["pallas_dot_s"])
            for s in pallas_samples)
        summary["pallas_points"] = len(pallas_samples)
        summary["pallas_agreement"] = f"{pagree}/{len(pallas_samples)}"
        summary["pallas_interpret"] = bool(pallas_samples[0]["interpret"])
        if summary["pallas_interpret"]:
            print("note: pallas samples ran under the Pallas interpreter — "
                  "the fitted pallas constants describe this host's "
                  "interpreter; refit on a TPU before serving them",
                  file=sys.stderr)
    print(json.dumps(summary))
    return constants


if __name__ == "__main__":
    main()
