#!/usr/bin/env bash
# Tier-1 verify with an explicit pass/fail/collect-error summary.
#
#   scripts/tier1.sh            # full suite (the ROADMAP.md tier-1 command)
#   scripts/tier1.sh --fast     # skip @slow subprocess integration runs
#   scripts/tier1.sh <pytest args...>   # passed through
#
# Exit code is pytest's, EXCEPT that collection errors always fail loudly —
# a module that stops collecting silently removes its tests from the count,
# which is how the seed suite rotted (3 modules uncollected for a missing
# dependency went unnoticed).
set -u
cd "$(dirname "$0")/.."

ARGS=(-q)
if [[ "${1:-}" == "--fast" ]]; then
    ARGS+=(-m "not slow"); shift
fi

OUT=$(PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest "${ARGS[@]}" "$@" 2>&1)
CODE=$?
echo "$OUT"

TAIL=$(echo "$OUT" | tail -n 3)
ERRORS=$(echo "$OUT" | grep -c "^ERROR ")
echo
echo "=== tier1 summary ==="
echo "  result line : $(echo "$TAIL" | grep -E '(passed|failed|error)' | tail -n 1)"
echo "  collect errs: $ERRORS"
if [[ "$ERRORS" -gt 0 ]]; then
    echo "  status      : FAIL (collection errors — tests silently missing)"
    exit 2
elif [[ $CODE -eq 0 ]]; then
    echo "  status      : PASS"
else
    echo "  status      : FAIL (exit $CODE)"
fi
exit $CODE
