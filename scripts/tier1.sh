#!/usr/bin/env bash
# Tier-1 verify with an explicit pass/fail/collect-error summary.
#
#   scripts/tier1.sh            # full suite (the ROADMAP.md tier-1 command)
#   scripts/tier1.sh --fast     # skip @slow subprocess integration runs
#   scripts/tier1.sh <pytest args...>   # passed through
#
# Exit code is pytest's, EXCEPT that collection errors always fail loudly —
# a module that stops collecting silently removes its tests from the count,
# which is how the seed suite rotted (3 modules uncollected for a missing
# dependency went unnoticed).
#
# Emits a machine-readable tier1_summary.json next to this summary, and —
# when running under GitHub Actions — appends the gate table to
# $GITHUB_STEP_SUMMARY.
set -u
cd "$(dirname "$0")/.."

ARGS=(-q)
if [[ "${1:-}" == "--fast" ]]; then
    ARGS+=(-m "not slow"); shift
fi

# per-route pallas parity pass counts: tests/test_parity.py records them
# through the conftest PARITY_SUMMARY hook; merged below into
# tier1_summary.json and the CI step summary so a sweep that quietly stops
# covering a route reads as a dropped counter, not a green run
PARITY_JSON=parity_summary.json
rm -f "$PARITY_JSON"

T0=$SECONDS
OUT=$(PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} PARITY_SUMMARY="$PARITY_JSON" \
    python -m pytest "${ARGS[@]}" "$@" 2>&1)
CODE=$?
echo "$OUT"

RESULT_LINE=$(echo "$OUT" | tail -n 3 | grep -E '(passed|failed|error)' | tail -n 1)
ERRORS=$(echo "$OUT" | grep -c "^ERROR ")

# docs can't silently rot: every relative link in README.md, docs/*.md and
# src/**/README.md must resolve to a real file (check_links' default set)
python scripts/check_links.py
LINKS=$?

# the benchmark sweep (T in {4,16} x {float32,int8}) must run and stay
# bit-exact — the tiny smoke config, not a timing. Skipped when pytest
# already failed: no point compiling 12 sessions to decorate a red build.
# TIER1_BENCH_OUT=<file> additionally writes the record there so CI can
# reuse it for the trajectory comparison instead of running a second smoke.
BENCH=skipped
if [[ $CODE -eq 0 ]]; then
    BENCH_ARGS=(--smoke)
    if [[ -n "${TIER1_BENCH_OUT:-}" ]]; then
        rm -f "$TIER1_BENCH_OUT"
        BENCH_ARGS+=(--out "$TIER1_BENCH_OUT")
    fi
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python benchmarks/infer_bench.py "${BENCH_ARGS[@]}" > /dev/null
    BENCH=$?
fi

PARITY_TXT=none
if [[ -f "$PARITY_JSON" ]]; then
    PARITY_TXT=$(python - "$PARITY_JSON" <<'EOF'
import json, sys
p = json.load(open(sys.argv[1]))["parity_passes"]
print(f"{len(p)} routes / {sum(p.values())} passes")
EOF
)
fi

DURATION=$((SECONDS - T0))
LINKS_TXT=$([[ $LINKS -eq 0 ]] && echo OK || echo BROKEN)
BENCH_TXT=$([[ "$BENCH" == 0 ]] && echo OK || echo "$BENCH")
# pytest problems first — the doc/bench gates must never mask a red suite
if [[ "$ERRORS" -gt 0 ]]; then
    STATUS="FAIL (collection errors — tests silently missing)"; EXIT=2
elif [[ $CODE -ne 0 ]]; then
    STATUS="FAIL (pytest exit $CODE)"; EXIT=$CODE
elif [[ $LINKS -ne 0 ]]; then
    STATUS="FAIL (broken doc links)"; EXIT=3
elif [[ "$BENCH" != 0 ]]; then
    STATUS="FAIL (infer_bench --smoke)"; EXIT=4
else
    STATUS="PASS"; EXIT=0
fi

RESULT_LINE="$RESULT_LINE" ERRORS="$ERRORS" LINKS_TXT="$LINKS_TXT" \
BENCH_TXT="$BENCH_TXT" STATUS="$STATUS" EXIT_CODE="$EXIT" \
DURATION="$DURATION" PARITY_JSON="$PARITY_JSON" python - <<'EOF'
import json, os
summary = {
    "result_line": os.environ["RESULT_LINE"].strip(),
    "collect_errors": int(os.environ["ERRORS"]),
    "doc_links": os.environ["LINKS_TXT"],
    "bench_smoke": os.environ["BENCH_TXT"],
    "status": os.environ["STATUS"],
    "exit_code": int(os.environ["EXIT_CODE"]),
    "duration_s": int(os.environ["DURATION"]),
}
try:
    with open(os.environ["PARITY_JSON"]) as f:
        summary["parity_passes"] = json.load(f)["parity_passes"]
except (OSError, KeyError, ValueError):
    summary["parity_passes"] = {}
json.dump(summary, open("tier1_summary.json", "w"), indent=1)
EOF

echo
echo "=== tier1 summary ==="
echo "  result line : $RESULT_LINE"
echo "  collect errs: $ERRORS"
echo "  doc links   : $LINKS_TXT"
echo "  bench smoke : $BENCH_TXT"
echo "  parity      : $PARITY_TXT"
echo "  status      : $STATUS"

if [[ -n "${GITHUB_STEP_SUMMARY:-}" ]]; then
    {
        echo "### tier1 (${DURATION}s)"
        echo ""
        echo "| gate | result |"
        echo "|---|---|"
        echo "| pytest | ${RESULT_LINE:-?} |"
        echo "| collect errors | $ERRORS |"
        echo "| doc links | $LINKS_TXT |"
        echo "| bench smoke | $BENCH_TXT |"
        echo "| parity routes | $PARITY_TXT |"
        echo "| **status** | **$STATUS** |"
    } >> "$GITHUB_STEP_SUMMARY"
    if [[ -f "$PARITY_JSON" ]]; then
        {
            echo ""
            echo "#### pallas parity passes (interpret mode)"
            echo ""
            echo "| route | passes |"
            echo "|---|---|"
            python - "$PARITY_JSON" <<'EOF'
import json, sys
for k, v in sorted(json.load(open(sys.argv[1]))["parity_passes"].items()):
    print(f"| {k} | {v} |")
EOF
        } >> "$GITHUB_STEP_SUMMARY"
    fi
fi

exit $EXIT
