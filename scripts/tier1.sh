#!/usr/bin/env bash
# Tier-1 verify with an explicit pass/fail/collect-error summary.
#
#   scripts/tier1.sh            # full suite (the ROADMAP.md tier-1 command)
#   scripts/tier1.sh --fast     # skip @slow subprocess integration runs
#   scripts/tier1.sh <pytest args...>   # passed through
#
# Exit code is pytest's, EXCEPT that collection errors always fail loudly —
# a module that stops collecting silently removes its tests from the count,
# which is how the seed suite rotted (3 modules uncollected for a missing
# dependency went unnoticed).
set -u
cd "$(dirname "$0")/.."

ARGS=(-q)
if [[ "${1:-}" == "--fast" ]]; then
    ARGS+=(-m "not slow"); shift
fi

OUT=$(PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest "${ARGS[@]}" "$@" 2>&1)
CODE=$?
echo "$OUT"

TAIL=$(echo "$OUT" | tail -n 3)
ERRORS=$(echo "$OUT" | grep -c "^ERROR ")

# docs can't silently rot: every relative link in README.md / docs/*.md
# must resolve to a real file
python scripts/check_links.py src/repro/infer/README.md
LINKS=$?

# the benchmark sweep (T in {4,16} x {float32,int8}) must run and stay
# bit-exact — a tiny 1-repeat smoke, not a timing. Skipped when pytest
# already failed: no point compiling 8 sessions to decorate a red build.
BENCH=skipped
if [[ $CODE -eq 0 ]]; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python benchmarks/infer_bench.py --smoke > /dev/null
    BENCH=$?
fi

echo
echo "=== tier1 summary ==="
echo "  result line : $(echo "$TAIL" | grep -E '(passed|failed|error)' | tail -n 1)"
echo "  collect errs: $ERRORS"
echo "  doc links   : $([[ $LINKS -eq 0 ]] && echo OK || echo BROKEN)"
echo "  bench smoke : $([[ "$BENCH" == 0 ]] && echo OK || echo "$BENCH")"
# pytest problems first — the doc/bench gates must never mask a red suite
if [[ "$ERRORS" -gt 0 ]]; then
    echo "  status      : FAIL (collection errors — tests silently missing)"
    exit 2
elif [[ $CODE -ne 0 ]]; then
    echo "  status      : FAIL (exit $CODE)"
    exit $CODE
elif [[ $LINKS -ne 0 ]]; then
    echo "  status      : FAIL (broken doc links)"
    exit 3
elif [[ "$BENCH" != 0 ]]; then
    echo "  status      : FAIL (infer_bench --smoke)"
    exit 4
fi
echo "  status      : PASS"
exit 0
