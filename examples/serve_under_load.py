"""Serve a Spikformer under open-loop load — the numbers behind a
*real-time* claim.

VESTA's headline system property is a sustained ~30 fps service rate, which
is an open-loop statement: requests arrive on their own schedule whether or
not the server kept up. This example compiles one multi-bucket model, then
replays Poisson arrival traces at two rates through
``repro.serve.AsyncServeRuntime`` and reports what a closed-loop drain
cannot — goodput (within-SLO images/s), p99 latency, SLO attainment, and
explicit admission-control rejections.

  PYTHONPATH=src python examples/serve_under_load.py [--rates 40,160]
      [--duration 2] [--slo-ms 100]
"""
import argparse
import json

import jax

from repro.core.spikformer import SpikformerConfig, init
from repro.infer import ExecutionPlan, PAPER_FPS, compile
from repro.serve import (AsyncServeRuntime, ServePolicy, image_maker,
                         poisson_trace, run_open_loop)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="40,160",
                    help="comma-separated offered arrival rates (req/s)")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="seconds of open-loop arrivals per rate")
    ap.add_argument("--slo-ms", type=float, default=100.0)
    ap.add_argument("--max-wait-ms", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(args.seed), cfg)
    model = compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    print(json.dumps({"compile_s": round(model.warmup(), 3),
                      "buckets": list(model.buckets),
                      "paper_fps": PAPER_FPS}))

    for rps in (float(r) for r in args.rates.split(",")):
        policy = ServePolicy(max_wait_ms=args.max_wait_ms,
                             slo_ms=args.slo_ms, max_queue_images=256)
        trace = poisson_trace(rps=rps, duration_s=args.duration,
                              seed=args.seed + 1, images_per_request=(1, 3))
        with AsyncServeRuntime(model, policy=policy) as rt:
            metrics = run_open_loop(
                rt, trace,
                image_maker(model.input_shape()[1:], seed=args.seed + 2),
                slo_ms=args.slo_ms)
        print(json.dumps({
            "offered_rps": rps,
            "goodput_fps": metrics["goodput_fps"],
            "completed_fps": metrics["completed_fps"],
            "latency_p99_s": metrics["latency_p99_s"],
            "slo_attainment": metrics["slo_attainment"],
            "rejected": metrics["requests_rejected"],
            "dropped": metrics["requests_dropped"],
            "sustains_paper_rate":
                bool(metrics["completed_fps"] >= PAPER_FPS),
            "pad_waste": rt.stats()["pad_waste"],
        }))


if __name__ == "__main__":
    main()
