"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
synthetic data through the full production loop (sharded jit step, data
pipeline, async checkpointing, restart supervisor).

  PYTHONPATH=src python examples/train_lm_100m.py [--steps 300]

The config is the smollm-360m family scaled to ~100M params (16 layers,
d_model 768, GQA 12/4, vocab 32k); everything else — optimizer, remat,
grad accumulation, checkpointing — is exactly what the 512-chip launch uses.
"""
import argparse
import tempfile

from repro.configs.base import ArchConfig, register
from repro.launch import train

register(ArchConfig(
    name="lm-100m", family="dense",
    n_layers=16, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
    vocab=32000, tie_embeddings=True, remat=False,
))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="lm100m_ck_")
    train.main([
        "--arch", "lm-100m",
        "--steps", str(args.steps),
        "--seq", str(args.seq),
        "--global-batch", str(args.global_batch),
        "--microbatch", str(max(1, args.global_batch // 2)),
        "--lr", "6e-4", "--warmup", "50",
        "--ckpt-dir", ckpt, "--ckpt-every", "100",
        "--log-every", "10",
    ])


if __name__ == "__main__":
    main()
