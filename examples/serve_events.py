"""Serve a DVS event stream — synthetic camera to per-window predictions.

An event camera produces a sparse stream of (x, y, t_us, polarity)
events, not frames. This walkthrough runs the whole event workload end
to end on a synthetic stream:

1. generate a deterministic DVS stream (a moving edge + flicker bursts);
2. show the direct event→plane-group encoding and its occupancy readouts
   (the signal the sparse route calibrates from);
3. stream the events through an ``EventStreamSession`` over the async
   serving runtime — watermark windowing, per-window streaming labels,
   explicit shedding under backpressure;
4. capture the run as a versioned JSONL trace and replay it, verifying
   the replay reproduces the live run's labels bit for bit.

  PYTHONPATH=src python examples/serve_events.py [--window-ms 20]
      [--duration-ms 400] [--seed 0]
"""
import argparse
import dataclasses
import json
import tempfile

import jax

from repro.core.spikformer import SpikformerConfig, init
from repro.events import (EventStreamSession, encode_events_to_plane_groups,
                          flicker_burst_events, load_trace, merge_streams,
                          moving_edge_events, replay_trace, window_occupancy)
from repro.infer import ExecutionPlan, compile
from repro.serve import AsyncServeRuntime, ServePolicy

H = W = 16


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--window-ms", type=float, default=20.0,
                    help="serving window duration (sensor time)")
    ap.add_argument("--duration-ms", type=float, default=400.0,
                    help="synthetic stream duration (sensor time)")
    ap.add_argument("--slo-ms", type=float, default=2_000.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    window_us = int(args.window_ms * 1_000)
    duration_us = int(args.duration_ms * 1_000)

    # -- 1. a deterministic synthetic DVS stream ---------------------------
    stream = merge_streams(
        moving_edge_events(height=H, width=W, duration_us=duration_us,
                           seed=args.seed),
        flicker_burst_events(height=H, width=W, duration_us=duration_us,
                             seed=args.seed + 1, bursts=3))
    print(json.dumps({"events": len(stream), "sensor": [H, W],
                      "duration_ms": args.duration_ms}))

    # -- 2. direct encoding: events -> packed plane groups -----------------
    # one window, 8 time bins -> (1, H, W, 2) uint8; the dense (T, H, W, 2)
    # tensor never exists
    planes = encode_events_to_plane_groups(
        stream.slice_time(0, window_us), t=8, window_us=window_us // 8)
    print(json.dumps({"plane_groups": planes.shape[0],
                      "encoded_shape": list(planes.shape),
                      "chunk_occupancy":
                          round(window_occupancy(planes, t=8), 4)}))

    # -- 3. stream through the serving stack -------------------------------
    # a DVS-shaped model: 2 input channels (OFF/ON), sensor-sized
    cfg = dataclasses.replace(
        SpikformerConfig().scaled(img_size=H, dim=32, depth=1),
        in_channels=2)
    params = init(jax.random.PRNGKey(args.seed), cfg)
    model = compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    print(json.dumps({"compile_s": round(model.warmup(), 3),
                      "in_channels": cfg.in_channels}))

    policy = ServePolicy(max_wait_ms=10.0, slo_ms=args.slo_ms,
                         max_queue_images=64)
    with AsyncServeRuntime(model, policy=policy) as rt:
        session = EventStreamSession(
            rt, window_us=window_us, height=H, width=W, capture=True,
            on_window=lambda w, label: print(json.dumps(
                {"window": w, "label": label})))
        # feed in camera-sized chunks: the watermark closes and serves each
        # window as the stream moves past it
        chunk_us = max(1, duration_us // 10)
        for lo in range(0, duration_us, chunk_us):
            session.feed(stream.slice_time(lo, lo + chunk_us))
        session.close()
        live_labels = session.labels()
        print(json.dumps({"session": session.stats(),
                          "occupancy_trace": session.occupancy_trace(),
                          "queue_depth_peak":
                              rt.stats()["queue_depth_peak"]}))

        # -- 4. capture -> trace file -> replay ----------------------------
        with tempfile.NamedTemporaryFile(suffix=".jsonl",
                                         delete=False) as fh:
            trace_path = fh.name
        session.save_trace(trace_path, meta={"example": "serve_events"})

    with AsyncServeRuntime(model, policy=policy) as rt2:
        m = replay_trace(load_trace(trace_path), rt2, slo_ms=args.slo_ms)
    replay_labels = [lab[0] for lab in m["labels"]]
    match = replay_labels == [live_labels[w] for w in sorted(live_labels)]
    print(json.dumps({"replay": {
        "windows": m["windows"],
        "goodput_fps": m["goodput_fps"],
        "slo_attainment": m["slo_attainment"],
        "dispersion_index": m["dispersion_index"],
        "labels_sha": m["labels_sha"],
        "labels_match_live_run": match,
    }}))
    assert match, "replay must reproduce the live run's labels"


if __name__ == "__main__":
    main()
