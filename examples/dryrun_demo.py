"""Single-cell multi-pod dry-run demo: lower + compile qwen3-moe-30b-a3b
train_4k against the 2x16x16 (512-chip) production mesh on this CPU-only
container, then print the memory/cost/roofline record.

  PYTHONPATH=src python examples/dryrun_demo.py [--arch ...] [--shape ...]
"""
# The 512 placeholder devices MUST be configured before jax initializes —
# importing repro.launch.dryrun first does exactly that.
from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS at import)

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--single-pod", action="store_true")
    args = ap.parse_args()
    rec, compiled = dryrun.lower_cell(args.arch, args.shape,
                                      multi_pod=not args.single_pod)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
