"""Train Spikformer V2 (reduced) with surrogate-gradient BPTT on synthetic
class-conditional images — the model VESTA executes, trained end to end by
this framework (the paper's accelerator is inference-only; training is our
beyond-paper substrate).

  PYTHONPATH=src python examples/train_spikformer.py [--steps 300]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.spikformer import (SpikformerConfig, init, loss_fn,
                                   merge_bn_stats)
from repro.data.pipeline import DataConfig, image_batch
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--lr", type=float, default=2e-3)
    args = ap.parse_args()

    cfg = SpikformerConfig().scaled(img_size=32, dim=64, depth=2, heads=2,
                                    classes=args.classes)
    dcfg = DataConfig(global_batch=args.batch, kind="images", image_size=32,
                      n_classes=args.classes, seed=0)
    params = init(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.OptConfig(peak_lr=args.lr, warmup_steps=20,
                              decay_steps=args.steps, weight_decay=0.01)
    opt = adamw.init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, (acc, stats)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, train=True)
        params, opt, m = adamw.update(grads, opt, params, opt_cfg)
        params = merge_bn_stats(params, stats)
        return params, opt, loss, acc

    t0 = time.time()
    for i in range(args.steps):
        raw = image_batch(dcfg, i)
        batch = {"image": jnp.asarray(raw["image"]),
                 "label": jnp.asarray(raw["label"])}
        params, opt, loss, acc = step(params, opt, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(json.dumps({"step": i, "loss": round(float(loss), 4),
                              "acc": round(float(acc), 3),
                              "wall_s": round(time.time() - t0, 1)}),
                  flush=True)

    # eval on held-out steps
    correct = total = 0
    for i in range(args.steps, args.steps + 5):
        raw = image_batch(dcfg, i)
        l, (acc, _) = loss_fn(params, {"image": jnp.asarray(raw["image"]),
                                       "label": jnp.asarray(raw["label"])},
                              cfg, train=False)
        correct += float(acc) * args.batch
        total += args.batch
    print(json.dumps({"eval_acc": round(correct / total, 3),
                      "chance": round(1 / args.classes, 3)}))


if __name__ == "__main__":
    main()
