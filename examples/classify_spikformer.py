"""Classify images with the packed-bit Spikformer inference engine — the
paper's real-time workload (VESTA runs Spikformer V2 at ~30 fps): a short
surrogate-gradient training run on synthetic class-conditional images, then
BN-folded packed-uint8 inference through the compile/serve split
(``repro.infer.compile`` -> ``MicroBatchEngine``), checking the packed
path agrees with the float reference bit-for-bit and reporting fps, p95
latency and pad waste from the engine.

  PYTHONPATH=src python examples/classify_spikformer.py [--train-steps 60]
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spikformer import (SpikformerConfig, init, loss_fn,
                                   merge_bn_stats)
from repro.data.pipeline import DataConfig, image_batch
from repro.infer import ExecutionPlan, MicroBatchEngine, compile
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--eval-images", type=int, default=48)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="static inference batch")
    args = ap.parse_args()

    cfg = SpikformerConfig().scaled(classes=args.classes)
    dcfg = DataConfig(global_batch=args.batch, kind="images", image_size=32,
                      n_classes=args.classes, seed=0)
    params = init(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.OptConfig(peak_lr=2e-3, warmup_steps=10,
                              decay_steps=args.train_steps, weight_decay=0.01)
    opt = adamw.init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, (acc, stats)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, train=True)
        params, opt, _ = adamw.update(grads, opt, params, opt_cfg)
        return merge_bn_stats(params, stats), opt, loss

    for i in range(args.train_steps):
        raw = image_batch(dcfg, i)
        params, opt, loss = step(params, opt,
                                 {"image": jnp.asarray(raw["image"]),
                                  "label": jnp.asarray(raw["label"])})
        if i % 20 == 0:
            print(json.dumps({"train_step": i, "loss": round(float(loss), 4)}),
                  flush=True)

    # --- packed inference: compile once, serve through the engine -----------
    plan = ExecutionPlan(backend="packed",
                         batch_buckets=(max(1, args.batch_size // 4),
                                        args.batch_size))
    model = compile(params, cfg, plan)
    ref = compile(params, cfg, plan, backend="reference")
    compile_s = model.warmup()

    images, labels = [], []
    n_batches = -(-args.eval_images // args.batch)
    for i in range(args.train_steps, args.train_steps + n_batches):
        raw = image_batch(dcfg, i)
        images.append(np.asarray(raw["image"]))
        labels.append(np.asarray(raw["label"]))
    images = np.concatenate(images)[:args.eval_images]
    labels = np.concatenate(labels)[:args.eval_images]

    eng = MicroBatchEngine(model)
    for i in range(0, len(images), 3):     # requests of up to 3 images
        eng.submit(images[i:i + 3])
    done = sorted(eng.run(), key=lambda r: r.rid)
    pred = np.asarray([lab for r in done for lab in r.labels])
    stats = eng.stats()
    exact = bool((np.asarray(model.logits(images))
                  == np.asarray(ref.logits(images))).all())

    print(json.dumps({
        "eval_images": len(images),
        "accuracy": round(float((pred == labels).mean()), 3),
        "chance": round(1 / args.classes, 3),
        "compile_s": round(compile_s, 3),
        "fps": stats["fps"],
        "paper_target_fps": stats["paper_fps"],
        "latency_p95_s": stats["latency_p95_s"],
        "pad_waste": stats["pad_waste"],
        "packed_matches_reference_exactly": exact,
    }))


if __name__ == "__main__":
    main()
