"""Quickstart: the VESTA core in five snippets.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# --- 1. spikes are bits: pack 8 planes per byte -----------------------------
from repro.core.spike import pack_bits, unpack_bits

spikes = (jax.random.uniform(jax.random.PRNGKey(0), (4, 128)) < 0.3)
packed = pack_bits(spikes.astype(jnp.float32), axis=-1)  # 128 bits -> 16 B
print(f"1) spikes {spikes.shape} ({spikes.size} bits) packed -> "
      f"{packed.shape} uint8 = {packed.size} bytes (8x smaller than int8)")
assert bool((unpack_bits(packed) == spikes).all())

# --- 2. the unified PE: one kernel, four dataflows ---------------------------
from repro.kernels import ops

x_packed = jax.random.randint(jax.random.PRNGKey(1), (64, 96), 0, 256,
                              jnp.uint8)
w = jax.random.normal(jax.random.PRNGKey(2), (96, 32))
per_plane = ops.spike_matmul(x_packed, w, mode="per_plane")   # WSSL/ZSC/STDP
shift_sum = ops.spike_matmul(x_packed, w, mode="shift_sum")   # SSSC
print(f"2) unified PE: per_plane {per_plane.shape} (8 timestep-planes), "
      f"shift_sum {shift_sum.shape} (8-bit input reconstructed)")

# --- 3. TFLIF: BN folded into bias, spikes packed on the way out -------------
from repro.core.lif import fold_bn, bn_init

kern = jax.random.normal(jax.random.PRNGKey(3), (96, 32))
bn = bn_init(32)
kf, bf = fold_bn(kern, None, bn)
acc = jax.random.normal(jax.random.PRNGKey(4), (12, 32 * 64)) * 2
packed_out = ops.tflif_fused(acc, jnp.tile(bf, 64))
print(f"3) TFLIF: {acc.shape} accumulators -> {packed_out.shape} uint8 "
      f"plane groups (bit j of group g = timestep 8g+j; BN never ran as a "
      f"layer; T=12 -> ceil(12/8)=2 groups, membrane carried across)")

# --- 4. STDP: softmax-free attention, V consumed as produced -----------------
q = (jax.random.uniform(jax.random.PRNGKey(5), (8, 256, 64)) < 0.25
     ).astype(jnp.float32)
out = ops.stdp_attention(q, q, q, scale=0.125)
print(f"4) STDP attention {out.shape}: exact, tile-fused, no N x N scores "
      f"in memory")

# --- 5. Spikformer V2 end to end ---------------------------------------------
from repro.core.spikformer import SpikformerConfig, init, apply

cfg = SpikformerConfig().scaled()          # CPU-sized
params = init(jax.random.PRNGKey(6), cfg)
img = jax.random.randint(jax.random.PRNGKey(7), (2, 32, 32, 3), 0, 256,
                         jnp.uint8)
logits, _ = apply(params, img, cfg)
print(f"5) Spikformer V2 (reduced): image {img.shape} -> logits "
      f"{logits.shape}, all inter-layer traffic binary spikes")

# --- 6. packed inference: compile once under a plan, any T, int8 weights -----
from repro.infer import ExecutionPlan, compile

cfg16 = cfg.scaled(timesteps=16)           # T=16 -> 2 plane groups
plan = ExecutionPlan(backend="packed", weight_dtype="int8",
                     batch_buckets=(2,))
model = compile(params, cfg16, plan)
print(f"6) packed int8 inference at T=16: logits {model.logits(img).shape} "
      f"(uint8 plane-group activations, int8 weights, scale folded into "
      f"the LIF threshold; plan routes {len(model.plan.routes)} layers, "
      f"serializable via model.plan.to_json())")
print("quickstart OK")
