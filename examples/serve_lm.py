"""Serve a small LM with continuous batching: 12 requests of mixed prompt
lengths stream through a 4-slot pool; one fused decode step advances every
active sequence per iteration.

  PYTHONPATH=src python examples/serve_lm.py
"""
import json
import time

import jax

from repro.configs.base import get_config
from repro.launch.serve import Engine, Request
from repro.sharding.compat import set_mesh


def main():
    cfg = get_config("smollm-360m").reduced(
        n_layers=4, d_model=256, vocab=2048)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with set_mesh(mesh):
        eng = Engine(cfg, slots=4, cache_len=256, seed=0)
        rng = jax.random.PRNGKey(1)
        t0 = time.time()
        for i in range(12):
            rng, k = jax.random.split(rng)
            plen = int(8 + 24 * jax.random.uniform(k))
            prompt = jax.random.randint(k, (plen,), 0, cfg.vocab).tolist()
            eng.submit(Request(rid=i, prompt=prompt, max_new=24))
        it = 0
        while eng.queue or eng.active:
            n_active = eng.step()
            it += 1
            if it % 10 == 0:
                print(f"iter {it}: active={n_active} queued={len(eng.queue)} "
                      f"done={len(eng.done)}", flush=True)
        wall = time.time() - t0

    toks = sum(len(r.out) for r in eng.done)
    print(json.dumps({
        "requests": len(eng.done),
        "new_tokens": toks,
        "wall_s": round(wall, 2),
        "tok_per_s": round(toks / wall, 1),
        "mean_ttft_s": round(sum(r.t_first - r.t_arrival
                                 for r in eng.done) / len(eng.done), 3),
    }))


if __name__ == "__main__":
    main()
