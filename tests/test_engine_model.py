"""Paper-validation: the analytic VESTA engine model reproduces Tables I/II."""
import pytest

from repro.core.engine_model import (PE_TOTAL, PEAK_GSOPS, PAPER_TABLE2,
                                     macs_by_method, table2_distribution,
                                     frames_per_second, table1_summary,
                                     implied_utilization)


def test_peak_throughput_table1():
    assert PE_TOTAL == 4096
    assert PEAK_GSOPS == pytest.approx(4096.0)      # paper Table I


def test_table2_distribution_calibrated():
    """The calibrated cycle model reproduces the paper's Table II split for
    WSSL / STDP / SSSC. ZSC is the documented exception: our architectural
    reconstruction counts ~12x more ZSC MACs than the paper's 0.19% share
    implies even at utilization 1.0 — consistent with zero-spike skipping in
    the PE array (or narrower unpublished SCS widths); see EXPERIMENTS.md
    §Paper-validation."""
    dist = table2_distribution(calibrated=True)
    for k in ("WSSL", "STDP", "SSSC"):
        assert dist[k] == pytest.approx(PAPER_TABLE2[k], abs=1.5), (k, dist)
    assert dist["ZSC"] < 2.0   # capped at util=1.0; paper claims 0.19


def test_table2_ordering_uncalibrated():
    """Even the ideal (utilization=1) model gets the structural claim of
    Table II right: WSSL dominates and the conv stem is a small tail."""
    dist = table2_distribution(calibrated=False)
    assert dist["WSSL"] > 55.0
    assert dist["SSSC"] + dist["ZSC"] < 10.0


def test_fps_brackets_paper():
    """Ideal PEs give > 30 fps; calibrated matches the paper's 30 fps."""
    assert frames_per_second(calibrated=False) > 30.0
    assert frames_per_second(calibrated=True) == pytest.approx(30.0, rel=0.05)


def test_macs_scale():
    """Spikformer V2-8-512 @224px: total work is O(10) GMACs/frame
    (8 encoder blocks x ~196 tokens x 512 dim x T=4)."""
    total = sum(macs_by_method().values())
    assert 5e9 < total < 30e9


def test_implied_utilization_bounded():
    u = implied_utilization()
    for k, v in u.items():
        assert 0.0 < v <= 1.0, (k, v)
    # WSSL calibrates to ~0.36 — 512-row weight columns against 196-token
    # maps leave PE units idle between column switches; STDP/SSSC calibrate
    # low (buffer-bound, matching Table III's "reduce buffer" claims).
    assert 0.2 < u["WSSL"] < 0.6
    assert u["ZSC"] == 1.0   # capped (see test_table2_distribution_calibrated)


def test_table1_summary_fields():
    s = table1_summary()
    assert s["pe_number"] == 4096
    assert s["frequency_mhz"] == 500.0
    assert s["paper_fps"] == 30.0
