"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes/dtypes + seeded property sweeps (randomized shapes/seeds
derived deterministically from a parametrized seed — no hypothesis dep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.spike_matmul import spike_matmul
from repro.kernels.tflif import tflif_fused
from repro.kernels.stdp_attention import stdp_attention
from repro.kernels.flash_attention import flash_attention


# ---------------------------------------------------------------------------
# spike_matmul — the unified PE
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 16, 8),          # tiny, all < block
    (128, 256, 128),     # exactly one block
    (96, 200, 72),       # ragged: padding on every dim
    (300, 512, 256),     # multiple K blocks (accumulator loop)
])
@pytest.mark.parametrize("mode", ["per_plane", "shift_sum"])
def test_spike_matmul_shapes(m, k, n, mode):
    kx, kw = jax.random.split(jax.random.PRNGKey(42))
    x = jax.random.randint(kx, (m, k), 0, 256, jnp.uint8)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    got = spike_matmul(x, w, mode=mode, interpret=True)
    want = ref.spike_matmul_ref(x, w, mode=mode)
    # shift_sum carries values up to 255*sum|w| (magnitudes in the 1000s),
    # accumulated in a different order by the K-blocked kernel — absolute
    # error on near-cancelling elements scales with that magnitude
    rtol, atol = (1e-5, 1e-3) if mode == "per_plane" else (5e-3, 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_spike_matmul_weight_dtypes(wdtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.randint(kx, (64, 128), 0, 256, jnp.uint8)
    if wdtype == jnp.int8:
        w = jax.random.randint(kw, (128, 32), -127, 128, jnp.int32).astype(wdtype)
    else:
        w = jax.random.normal(kw, (128, 32)).astype(wdtype)
    got = spike_matmul(x, w, mode="per_plane", interpret=True)
    want = ref.spike_matmul_ref(x, w, mode="per_plane")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("seed", range(12))
def test_spike_matmul_property(seed):
    """Property: per_plane output scaled by 2^p and summed == shift_sum; both
    match the oracle for arbitrary shapes (shape drawn from the seed)."""
    rng = np.random.default_rng(seed)
    m, k, n = (int(rng.integers(1, 65)), int(rng.integers(1, 97)),
               int(rng.integers(1, 49)))
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (m, k), 0, 256, jnp.uint8)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    pp = spike_matmul(x, w, mode="per_plane", interpret=True)
    ss = spike_matmul(x, w, mode="shift_sum", interpret=True)
    scales = (2.0 ** np.arange(8)).reshape(8, 1, 1)
    np.testing.assert_allclose(np.asarray(pp * scales).sum(0), np.asarray(ss),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(pp), np.asarray(ref.spike_matmul_ref(x, w)), rtol=1e-5,
        atol=1e-4)


def test_spike_matmul_zero_and_saturated():
    """All-zero spikes -> zero output; all-ones (0xFF) -> row sums of W."""
    w = jnp.arange(24, dtype=jnp.float32).reshape(8, 3)
    z = spike_matmul(jnp.zeros((4, 8), jnp.uint8), w, interpret=True)
    assert float(jnp.abs(z).max()) == 0.0
    o = spike_matmul(jnp.full((4, 8), 255, jnp.uint8), w, interpret=True)
    want = jnp.broadcast_to(w.sum(0), (8, 4, 3))
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("g,m,k,n", [(1, 8, 16, 8), (2, 64, 96, 24),
                                     (3, 30, 200, 72)])
def test_spike_matmul_grouped(g, m, k, n):
    """(G, M, K) plane groups through the grouped grid == per-group calls of
    the 2D kernel and the oracle."""
    kx, kw = jax.random.split(jax.random.PRNGKey(13))
    x = jax.random.randint(kx, (g, m, k), 0, 256, jnp.uint8)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    got = spike_matmul(x, w, mode="per_plane", interpret=True)
    assert got.shape == (g, 8, m, n)
    want = ref.spike_matmul_ref(x, w, mode="per_plane")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    for gg in range(g):
        np.testing.assert_allclose(
            np.asarray(got[gg]),
            np.asarray(spike_matmul(x[gg], w, interpret=True)),
            rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# tflif — fused BN+LIF with packed spike output
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,m", [(4, 64), (4, 1000), (8, 64), (2, 3000),
                                 (1, 17), (12, 64), (16, 1000), (9, 33)])
def test_tflif_shapes(t, m):
    kx, kb = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(kx, (t, m)) * 2.0
    b = jax.random.normal(kb, (m,)) * 0.5
    got = tflif_fused(x, b, interpret=True)
    want = ref.tflif_ref(x, b)
    assert got.dtype == jnp.uint8
    assert got.shape == (-(-t // 8), m)
    assert bool((got == want).all())


@pytest.mark.parametrize("t", [4, 12])
def test_tflif_matches_training_lif(t):
    """The packed inference kernel fires exactly where the differentiable
    training LIF (core.lif.tflif) fires — including across the 8-timestep
    plane-group boundary (the membrane must not reset at t=8)."""
    from repro.core.lif import tflif as train_tflif
    x = jax.random.normal(jax.random.PRNGKey(5), (t, 256)) * 2.0
    spikes_train = train_tflif(x)                       # (T, 256) {0,1} float
    packed = ref.tflif_ref(x, None)                     # (G, 256)
    for tt in range(t):
        bit = (packed[tt // 8] >> (tt % 8)) & 1
        np.testing.assert_array_equal(np.asarray(bit),
                                      np.asarray(spikes_train[tt], np.uint8))


@pytest.mark.parametrize("seed", range(10))
def test_tflif_property_reset(seed):
    """Property: a neuron that fires at t has membrane reset — its potential
    contribution cannot leak into t+1 (checked via the oracle recurrence)."""
    rng = np.random.default_rng(100 + seed)
    t, m = int(rng.integers(1, 17)), int(rng.integers(1, 301))
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, m)) * 3.0
    got = tflif_fused(x, interpret=True)
    want = ref.tflif_ref(x)
    assert bool((got == want).all())
    # no bits above t-1 in the last group
    live = t - 8 * (got.shape[0] - 1)
    if live < 8:
        assert int(jnp.max(got[-1] >> live)) == 0


@pytest.mark.parametrize("seed", range(3))
def test_tflif_vector_threshold(seed):
    """(M,) per-neuron v_th (the int8 weight-scale fold) — Pallas kernel ==
    oracle, and a large threshold provably silences its neuron."""
    kx, kv = jax.random.split(jax.random.PRNGKey(40 + seed))
    x = jax.random.normal(kx, (12, 64)) * 2.0
    vth = jnp.abs(jax.random.normal(kv, (64,))) + 0.5
    vth = vth.at[0].set(1e9)
    got = tflif_fused(x, None, v_th=vth, interpret=True)
    want = ref.tflif_ref(x, None, v_th=vth)
    assert bool((got == want).all())
    assert int(got[:, 0].max()) == 0                   # silenced neuron


# ---------------------------------------------------------------------------
# stdp attention — softmax-free (Q K^T) V
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,n,dh", [(2, 64, 32), (6, 128, 64), (1, 300, 64),
                                     (4, 96, 128)])
def test_stdp_shapes(bh, n, dh):
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q, k, v = [(jax.random.uniform(kk, (bh, n, dh)) < 0.25).astype(jnp.float32)
               for kk in ks]
    got = stdp_attention(q, k, v, scale=0.125, interpret=True)
    want = ref.stdp_attention_ref(q, k, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_stdp_associativity_vs_kv_first():
    """STDP's streaming (QK^T)V must equal Q(K^TV) — the associativity that
    core.unified.stdp exploits (no softmax in between)."""
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q, k, v = [(jax.random.uniform(kk, (2, 128, 32)) < 0.3).astype(jnp.float32)
               for kk in ks]
    tile = stdp_attention(q, k, v, scale=1.0, interpret=True)
    kv_first = jnp.einsum("bnd,bnf->bdf", k, v)
    assoc = jnp.einsum("bnd,bdf->bnf", q, kv_first)
    np.testing.assert_allclose(np.asarray(tile), np.asarray(assoc),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("seed", range(8))
def test_stdp_property_spike_counts(seed):
    """Property: with binary q,k,v the output is a non-negative integer count
    (number of co-firing key/value pairs) scaled by `scale`."""
    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(8, 201))
    density = float(rng.uniform(0.05, 0.9))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = [(jax.random.uniform(kk, (1, n, 16)) < density).astype(jnp.float32)
               for kk in ks]
    out = stdp_attention(q, k, v, scale=1.0, interpret=True)
    arr = np.asarray(out)
    assert (arr >= 0).all()
    np.testing.assert_allclose(arr, np.round(arr), atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention — causal online softmax
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nq,nkv", [(128, 128), (64, 256), (1, 512),
                                    (200, 200), (100, 333)])
def test_flash_causal(nq, nkv):
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    q = jax.random.normal(ks[0], (3, nq, 64))
    k = jax.random.normal(ks[1], (3, nkv, 64))
    v = jax.random.normal(ks[2], (3, nkv, 64))
    got = flash_attention(q, k, v, scale=0.125, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=0.125, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(19), 3)
    q = jax.random.normal(ks[0], (2, 128, 32))
    k = jax.random.normal(ks[1], (2, 256, 32))
    v = jax.random.normal(ks[2], (2, 256, 32))
    got = flash_attention(q, k, v, scale=0.2, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=0.2, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("seed", range(6))
def test_flash_property_softmax_bounds(seed):
    """Property: attention output lies in the convex hull of V rows =>
    max|out| <= max|v| per batch-head."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (2, 96, 32)) * 3
    k = jax.random.normal(ks[1], (2, 96, 32)) * 3
    v = jax.random.normal(ks[2], (2, 96, 32))
    out = flash_attention(q, k, v, scale=0.5, causal=True, interpret=True)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


# ---------------------------------------------------------------------------
# dispatch wrappers
# ---------------------------------------------------------------------------

def test_ops_dispatch_cpu_uses_ref():
    """On CPU default (pallas=None) the wrappers route to the XLA reference;
    pallas=True forces interpret-mode Pallas. Both agree."""
    kx, kw = jax.random.split(jax.random.PRNGKey(23))
    x = jax.random.randint(kx, (32, 64), 0, 256, jnp.uint8)
    w = jax.random.normal(kw, (64, 16))
    a = ops.spike_matmul(x, w)               # ref path on CPU
    b = ops.spike_matmul(x, w, pallas=True)  # pallas interpret path
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-4)
