"""End-to-end integration: the train driver learns + resumes exactly; the
serving engine matches sequential generation; hlo analysis is calibrated."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_module(mod, *args, timeout=560):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", mod, *args], capture_output=True, text=True,
        env=env, timeout=timeout,
        cwd=os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# training driver
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    out = run_module(
        "repro.launch.train", "--arch", "smollm-360m", "--reduce",
        "--steps", "40", "--global-batch", "8", "--seq", "128",
        "--lr", "1e-3", "--log-every", "5",
        "--metrics-out", str(tmp_path / "m.json"))
    assert out.returncode == 0, out.stderr[-2000:]
    metrics = json.loads((tmp_path / "m.json").read_text())
    first, last = metrics[0]["loss"], metrics[-1]["loss"]
    assert last < first - 0.2, (first, last)


@pytest.mark.slow
def test_train_failure_injection_resumes(tmp_path):
    """A NodeFailure at step 15 restores from the step-10 checkpoint and
    completes; the final metrics line reports restarts=1."""
    out = run_module(
        "repro.launch.train", "--arch", "smollm-360m", "--reduce",
        "--steps", "25", "--global-batch", "4", "--seq", "64",
        "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "10",
        "--inject-failure-at", "15")
    assert out.returncode == 0, out.stderr[-2000:]
    lines = [json.loads(l) for l in out.stdout.splitlines()
             if l.startswith("{")]
    assert lines[-1]["result"] == {"restarts": 1, "completed": True}


@pytest.mark.slow
def test_moe_arch_trains(tmp_path):
    out = run_module(
        "repro.launch.train", "--arch", "qwen3-moe-30b-a3b", "--reduce",
        "--steps", "6", "--global-batch", "4", "--seq", "64",
        "--compression", "int8",
        "--metrics-out", str(tmp_path / "m.json"))
    assert out.returncode == 0, out.stderr[-2000:]
    metrics = json.loads((tmp_path / "m.json").read_text())
    assert all(np.isfinite(m["loss"]) for m in metrics)


# ---------------------------------------------------------------------------
# serving engine == sequential reference
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_matches_sequential_generation():
    from repro.configs.base import get_config
    from repro.launch.serve import Engine, Request
    from repro.sharding.compat import set_mesh
    from repro.nn import transformer as T

    cfg = get_config("smollm-360m").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with set_mesh(mesh):
        # fp32 end-to-end: greedy argmax on an UNTRAINED model is otherwise
        # numerically unstable (logit gaps < bf16 eps flip between batchings)
        eng = Engine(cfg, slots=2, cache_len=64, seed=0,
                     compute_dtype=jnp.float32, cache_dtype=jnp.float32)
        prompts = [[5, 9, 2, 14, 3], [7, 7, 1, 30, 11, 2]]
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_new=6))
        done = sorted(eng.run(), key=lambda r: r.rid)

        # sequential reference: greedy argmax with a fresh cache per prompt
        for req, prompt in zip(done, prompts):
            cache = T.init_cache(cfg, 1, 64, dtype=jnp.float32)
            toks = jnp.asarray(prompt, jnp.int32)[None]
            logits, cache, _ = T.model_apply(
                eng.params, {"tokens": toks, "cache_pos": jnp.int32(0)},
                cfg, mode="prefill", cache=cache,
                compute_dtype=jnp.float32)
            seq = [int(jnp.argmax(logits[0, -1]))]
            pos = len(prompt)
            for _ in range(5):
                logits, cache, _ = T.model_apply(
                    eng.params,
                    {"tokens": jnp.asarray([[seq[-1]]], jnp.int32),
                     "cache_pos": jnp.int32(pos)},
                    cfg, mode="decode", cache=cache,
                    compute_dtype=jnp.float32)
                seq.append(int(jnp.argmax(logits[0, -1])))
                pos += 1
            assert req.out == seq, (req.rid, req.out, seq)


# ---------------------------------------------------------------------------
# hlo analysis calibration
# ---------------------------------------------------------------------------

def test_hlo_flops_scan_known():
    M = K = N = 128
    TRIPS = 7

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=TRIPS)
        return y

    from repro.launch.hlo_analysis import analyze
    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((K, N), jnp.float32),
                               jax.ShapeDtypeStruct((M, K), jnp.float32))
    text = lowered.compile().as_text()
    cost = analyze(text)
    expect = TRIPS * 2 * M * K * N
    assert expect * 0.95 < cost.flops < expect * 1.2


def test_hlo_collective_bytes_psum():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.hlo_analysis import analyze

    mesh = jax.make_mesh((1,), ("x",))
    n = 4096

    def f(x):
        return jax.lax.psum(x, "x")

    sf = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())
    text = jax.jit(sf).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32)).compile().as_text()
    cost = analyze(text)
    # single-device all-reduce may be optimized away; accept 0 or 2x payload
    assert cost.coll_bytes["all-reduce"] in (0.0, 2.0 * 4 * n)


def test_hlo_nested_scan_multiplies():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        z, _ = jax.lax.scan(outer, x, None, length=5)
        return z

    from repro.launch.hlo_analysis import analyze
    text = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile().as_text()
    cost = analyze(text)
    expect = 15 * 2 * 64 ** 3
    assert expect * 0.95 < cost.flops < expect * 1.3
