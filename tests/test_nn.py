"""Substrate correctness: RoPE/M-RoPE, GQA + chunked attention, KV caches
(linear + ring, per-row positions), MoE dispatch, SSD chunked-vs-recurrent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.nn import layers
from repro.nn.attention import (attn_init, attn_apply, chunked_attention,
                                init_kv_cache, cache_update)
from repro.nn.moe import moe_init, moe_apply, capacity
from repro.nn.ssm import ssm_init, ssm_apply, init_ssm_state, ssd_chunked


def mini_cfg(**kw):
    base = dict(name="mini", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
                attn_chunk=16, remat=False)
    base.update(kw)
    return ArchConfig(**base)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = layers.apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property():
    """<RoPE(q,m), RoPE(k,n)> depends only on m-n."""
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))

    def dot_at(m, n):
        qm = layers.apply_rope(q, jnp.array([[m]]))
        kn = layers.apply_rope(k, jnp.array([[n]]))
        return float((qm * kn).sum())

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(5, 5) - dot_at(0, 0)) < 1e-4


def test_partial_rope_leaves_tail_untouched():
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(4), (1, 4))
    y = layers.apply_rope(x, pos, rotary_frac=0.5)
    np.testing.assert_array_equal(np.asarray(y[..., 16:]),
                                  np.asarray(x[..., 16:]))


def test_mrope_sections_drive_distinct_frequencies():
    """Identical (t,h,w) position streams == plain full-dim rotation; unequal
    streams rotate their sections differently."""
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 1, 32))
    same = jnp.broadcast_to(jnp.arange(4), (3, 1, 4))
    ya = layers.apply_mrope(x, same, (4, 6, 6))
    diff = same.at[1].set(0)
    yb = layers.apply_mrope(x, diff, (4, 6, 6))
    # temporal section (first 4 freq slots of each half) unchanged
    np.testing.assert_allclose(np.asarray(ya[..., :4]), np.asarray(yb[..., :4]),
                               rtol=1e-5, atol=1e-6)
    assert float(jnp.abs(ya[..., 4:10] - yb[..., 4:10]).max()) > 1e-3


# ---------------------------------------------------------------------------
# chunked attention == naive reference
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal=True, scale, window=None,
                    q_positions=None, k_positions=None):
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq) + (skv - sq), (b, sq))
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(skv), (b, skv))
    q_positions = jnp.broadcast_to(jnp.atleast_2d(q_positions), (b, sq))
    k_positions = jnp.broadcast_to(jnp.atleast_2d(k_positions), (b, skv))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = q_positions[:, None, :, None]
    kp = k_positions[:, None, None, :]
    mask = jnp.ones_like(s, bool)
    if causal:
        mask = qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    mask &= kp >= 0
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_attention_matches_naive(chunk):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 4, 64, 16))
    k = jax.random.normal(ks[1], (2, 2, 64, 16))
    v = jax.random.normal(ks[2], (2, 2, 64, 16))
    got = chunked_attention(q, k, v, scale=0.25, chunk=chunk)
    kk = jnp.repeat(k, 2, axis=1)
    vv = jnp.repeat(v, 2, axis=1)
    want = naive_attention(q, kk, vv, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_chunked_attention_sliding_window():
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 8))
    k = jax.random.normal(ks[1], (1, 2, 32, 8))
    v = jax.random.normal(ks[2], (1, 2, 32, 8))
    got = chunked_attention(q, k, v, scale=0.35, chunk=8, window=4)
    want = naive_attention(q, k, v, scale=0.35, window=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_chunked_attention_per_row_positions():
    """Rows at different offsets (continuous batching) mask independently."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 2, 1, 8))
    k = jax.random.normal(ks[1], (2, 2, 16, 8))
    v = jax.random.normal(ks[2], (2, 2, 16, 8))
    kpos = jnp.stack([jnp.arange(16),
                      jnp.where(jnp.arange(16) < 5, jnp.arange(16), -1)])
    qpos = jnp.array([[15], [4]])
    got = chunked_attention(q, k, v, scale=0.3, q_positions=qpos,
                            k_positions=kpos, chunk=1)
    want = naive_attention(q, k, v, scale=0.3, q_positions=qpos,
                           k_positions=kpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def test_cache_update_scalar_and_vector_pos():
    c = init_kv_cache(2, 1, 8, 4, jnp.float32)
    k1 = jnp.ones((2, 1, 2, 4))
    c = cache_update(c, k1, k1, 0)
    np.testing.assert_array_equal(np.asarray(c["positions"][:, :3]),
                                  [[0, 1, -1], [0, 1, -1]])
    # vector positions: row 0 appends at 2, row 1 at 5
    k2 = jnp.full((2, 1, 1, 4), 2.0)
    c = cache_update(c, k2, k2, jnp.array([2, 5]))
    assert c["positions"][0, 2] == 2 and c["positions"][1, 5] == 5
    assert float(c["k"][1, 0, 5, 0]) == 2.0


def test_ring_cache_wraps():
    c = init_kv_cache(1, 1, 4, 2, jnp.float32)
    for pos in range(6):
        knew = jnp.full((1, 1, 1, 2), float(pos))
        c = cache_update(c, knew, knew, pos, ring=True)
    # slots hold positions 4,5,2,3 (wrapped)
    np.testing.assert_array_equal(np.asarray(c["positions"][0]), [4, 5, 2, 3])


def test_decode_matches_prefill_attention():
    """Incremental decode through the cache == full-sequence attention."""
    cfg = mini_cfg()
    p = attn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64))
    positions = jnp.broadcast_to(jnp.arange(12), (2, 12))
    full, _ = attn_apply(p, x, cfg, positions=positions,
                         compute_dtype=jnp.float32, chunk=4)

    cache = init_kv_cache(2, cfg.n_kv_heads, 12, cfg.head_dim, jnp.float32)
    outs = []
    for t in range(12):
        xt = x[:, t:t + 1]
        pos_t = positions[:, t:t + 1]
        out, cache = attn_apply(p, xt, cfg, positions=pos_t, cache=cache,
                                cache_pos=jnp.int32(t),
                                compute_dtype=jnp.float32, chunk=1)
        outs.append(out)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_matches_dense_computation():
    """Sort-based dispatch == explicit per-token expert sum (ample capacity)."""
    cfg = mini_cfg(family="moe", n_experts=4, top_k=2, moe_d_ff=32,
                   moe_capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    got, aux = moe_apply(p, x, cfg, compute_dtype=jnp.float32)

    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)

    def ffn(e, v):
        h = jax.nn.silu(v @ p["w_gate"][e]) * (v @ p["w_up"][e])
        return h @ p["w_down"][e]

    want = np.zeros((2, 16, 64), np.float32)
    for b in range(2):
        for t in range(16):
            for j in range(2):
                e = int(idx[b, t, j])
                want[b, t] += float(gates[b, t, j]) * np.asarray(
                    ffn(e, x[b, t].astype(jnp.float32)))
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=2e-3, atol=2e-3)
    assert float(aux["load_balance"]) > 0


def test_moe_capacity_drops_overflow():
    """With capacity 1 most tokens drop (output rows become zero)."""
    cfg = mini_cfg(family="moe", n_experts=2, top_k=1, moe_d_ff=32,
                   moe_capacity_factor=0.01)
    assert capacity(16, 1, 2, 0.01) == 1
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 64))
    out, _ = moe_apply(p, x, cfg, compute_dtype=jnp.float32)
    rows = np.abs(np.asarray(out[0])).sum(-1)
    assert (rows == 0).sum() >= 14  # 16 tokens, <=2 slots


@pytest.mark.parametrize("seed", range(8))
def test_moe_gates_bounded(seed):
    cfg = mini_cfg(family="moe", n_experts=4, top_k=2, moe_d_ff=32)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, 64))
    out, aux = moe_apply(p, x, cfg, compute_dtype=jnp.float32)
    assert not bool(jnp.isnan(out).any())
    # Switch LB loss is ~1 at perfect balance IN EXPECTATION; random logits
    # on tiny batches dip slightly below
    assert float(aux["load_balance"]) >= 0.5


# ---------------------------------------------------------------------------
# SSM (Mamba2 / SSD)
# ---------------------------------------------------------------------------

def ssd_recurrent_ref(x, dt, a, b_mat, c_mat):
    """O(S) recurrence: state' = exp(dt a) state + dt B x; y = C state."""
    bsz, s, h, p_dim = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    state = np.zeros((bsz, h, p_dim, n), np.float32)
    ys = []
    for t in range(s):
        da = np.exp(np.asarray(dt[:, t]) * np.asarray(a))       # (B,H)
        bh = np.repeat(np.asarray(b_mat[:, t]), rep, axis=1)    # (B,H,N)
        ch = np.repeat(np.asarray(c_mat[:, t]), rep, axis=1)
        xt = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        state = state * da[:, :, None, None] + \
            np.einsum("bhn,bhp->bhpn", bh, xt)
        ys.append(np.einsum("bhn,bhpn->bhp", ch, state))
    return np.stack(ys, 1), state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_equals_recurrent(chunk):
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    bsz, s, h, p_dim, g, n = 2, 16, 4, 8, 2, 4
    x = jax.random.normal(ks[0], (bsz, s, h, p_dim))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    b_mat = jax.random.normal(ks[3], (bsz, s, g, n))
    c_mat = jax.random.normal(ks[4], (bsz, s, g, n))
    got, final = ssd_chunked(x, dt, a, b_mat, c_mat, chunk=chunk)
    want, want_state = ssd_recurrent_ref(x, dt, a, b_mat, c_mat)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), want_state, rtol=2e-3,
                               atol=2e-3)


def test_ssm_decode_matches_prefill():
    """Prefill then N recurrent decode steps == one long prefill."""
    cfg = mini_cfg(family="ssm", n_heads=0, n_kv_heads=0, d_ff=0,
                   ssm_state=8, ssm_head_dim=16, ssm_expand=2)
    p = ssm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64))

    y_full, _, _ = ssm_apply(p, x, cfg, chunk=4, compute_dtype=jnp.float32)

    y_pre, st, cv = ssm_apply(p, x[:, :8], cfg, state=None, conv_state=None,
                              chunk=4, compute_dtype=jnp.float32)
    outs = [y_pre]
    for t in range(8, 12):
        y_t, st, cv = ssm_apply(p, x[:, t:t + 1], cfg, state=st,
                                conv_state=cv, decode=True,
                                compute_dtype=jnp.float32)
        outs.append(y_t)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=5e-3, atol=5e-3)
