"""Sharding rules: spec validity, coverage, divisibility fallbacks, and a
real sharded-vs-single-device equivalence run on a CPU mesh."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.nn import transformer as T
from repro.sharding import rules
from repro.sharding.compat import set_mesh
from repro.sharding.hints import shard_hint
from repro.launch import steps


def fake_mesh(data=4, model=2, pod=None):
    """An abstract mesh over fake devices (no allocation) for rule tests."""
    if pod:
        return rules.abstract_mesh((pod, data, model),
                                   ("pod", "data", "model"))
    return rules.abstract_mesh((data, model), ("data", "model"))


# AbstractMesh lacks .devices; spec_for only uses .shape/.axis_names, so this
# adapter works for rule-level tests.
class MeshShim:
    def __init__(self, am):
        self.shape = dict(am.shape)
        self.axis_names = am.axis_names


def test_spec_divisibility_fallback():
    mesh = MeshShim(fake_mesh(data=4, model=2))
    # 2nd dim 10 not divisible by model=2? it is; use 7 => must drop axis
    spec = rules.spec_for("x/wq/kernel", (12, 7), mesh)
    assert spec == P("data", None)
    spec = rules.spec_for("x/wq/kernel", (12, 8), mesh)
    assert spec == P("data", "model")


def test_multi_pod_dp_group():
    mesh = MeshShim(fake_mesh(data=4, model=2, pod=2))
    spec = rules.spec_for("a/mlp/up/kernel", (16, 8), mesh)
    assert spec == P(("pod", "data"), "model")


def test_stacked_layer_leading_dims_padded():
    mesh = MeshShim(fake_mesh())
    spec = rules.spec_for("layers/attn/wq/kernel", (8, 16, 8), mesh)
    assert spec == P(None, "data", "model")


def test_moe_expert_sharding():
    mesh = MeshShim(fake_mesh())
    spec = rules.spec_for("layers/moe/w_gate", (2, 8, 16, 8), mesh)
    assert spec == P(None, "data", None, "model")    # E over data = EP


def test_every_param_leaf_gets_a_spec():
    """No leaf may error; 2-D+ leaves of each arch should mostly shard."""
    mesh = MeshShim(fake_mesh())
    for arch in ("smollm-360m", "qwen3-moe-30b-a3b", "mamba2-130m",
                 "hymba-1.5b", "whisper-large-v3"):
        cfg = get_config(arch).reduced()
        shapes = jax.eval_shape(lambda: T.init_model(jax.random.PRNGKey(0), cfg))
        from repro.nn.module import map_with_path
        specs = []
        map_with_path(lambda p, l: specs.append(
            rules.spec_for(p, l.shape, mesh)) or l, shapes)
        assert all(isinstance(s, P) for s in specs)


def test_shard_hint_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shard_hint(x, "dp", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sharded_train_step_matches_unsharded():
    """jit with explicit shardings on a 1-device mesh == plain execution
    (numerical path identity for the full train step)."""
    cfg = get_config("smollm-360m").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    batch_shapes = {
        "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32),
    }
    ts = steps.TrainSettings(microbatch=2)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    from repro.optim import adamw
    opt = adamw.init(params, ts.opt)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab)}

    plain = steps.make_train_step(cfg, ts)
    p2, o2, m2 = jax.jit(plain)(params, opt, batch)

    with set_mesh(mesh):
        # donate_argnums consumes params/opt — run the plain step first
        step_sharded, _, _ = steps.jit_train_step(cfg, mesh, ts, batch_shapes)
        p1, o1, m1 = step_sharded(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


def test_batch_and_cache_shardings_build():
    cfg = get_config("hymba-1.5b")
    mesh_real = jax.make_mesh((1, 1), ("data", "model"))
    cache_shapes = jax.eval_shape(lambda: T.init_cache(cfg, 4, 4096))
    c_sh = rules.cache_shardings(mesh_real, cache_shapes)
    for leaf in jax.tree_util.tree_leaves(
            c_sh, is_leaf=lambda x: isinstance(x, NamedSharding)):
        assert isinstance(leaf, NamedSharding)


# ---------------------------------------------------------------------------
# the public import surface and the serving fleet's placement axis
# ---------------------------------------------------------------------------

def test_public_import_surface():
    """``repro.sharding`` is a real public API: everything the serving
    fleet (and training) consumes is importable from the package root and
    declared in __all__."""
    import repro.sharding as sharding
    for name in ("rules", "hints", "compat", "dp_axes", "spec_for",
                 "param_shardings", "opt_state_shardings",
                 "batch_shardings", "cache_shardings", "serving_mesh",
                 "replica_devices", "shard_hint", "set_mesh",
                 "get_abstract_mesh", "abstract_mesh"):
        assert name in sharding.__all__, name
        assert getattr(sharding, name) is not None
    # the package re-export is the module symbol, not a copy
    assert sharding.replica_devices is rules.replica_devices
    assert sharding.spec_for is rules.spec_for


def test_serving_mesh_and_replica_devices():
    mesh = rules.serving_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.devices.size == len(jax.devices())
    with pytest.raises(ValueError, match="at least one device"):
        rules.serving_mesh(devices=[])
    with pytest.raises(ValueError, match="n >= 1"):
        rules.replica_devices(0)
    devs = rules.replica_devices(3)
    assert len(devs) == 3
    if len(jax.devices()) <= 1:
        # single-device host: thread-backed fleet, no pointless device_put
        assert devs == [None, None, None]
    else:
        # replicas round-robin the data axis
        flat = list(np.asarray(mesh.devices).flat)
        assert devs == [flat[i % len(flat)] for i in range(3)]
