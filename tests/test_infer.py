"""Packed-bit inference parity: for each of the four unified dataflows
(WSSL/ZSC/SSSC/STDP) the packed path must match the ``core.unified`` float
reference BIT-EXACTLY on random binary/uint8 inputs — spikes are binary, so
no tolerance — including the T-fold across ``ceil(T/8)`` plane groups and
the SSSC bit-plane 2^k bookkeeping. The int8-weight route is held to the
same standard against its float-emulation oracle (FloatBackend over the
quantized tree). Plus: compiled-model end-to-end equality over
T in {4, 8, 12, 16} x {float32, int8}, static-shape batching, and the
micro-batching serve engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import unified
from repro.core.lif import V_TH, tflif
from repro.core.spike import (num_plane_groups, pack_timesteps,
                              unpack_timesteps, space_to_depth)
from repro.core.spikformer import (SpikformerConfig, init, apply,
                                   fold_inference_params, forward_folded)
from repro.infer import (ExecutionPlan, FloatBackend, PackedBackend,
                         compile as infer_compile, quantize_folded,
                         quantize_layer)
from repro.kernels import ops

TS = [1, 4, 8, 12, 16]


def exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def bern(key, shape, p=0.3):
    return (jax.random.uniform(key, shape) < p).astype(jnp.float32)


# ---------------------------------------------------------------------------
# per-dataflow parity (packed entry points vs core.unified, bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("t", TS)
def test_wssl_packed_parity(seed, t):
    """Temporal T-fold: packed per-plane matmul == float wssl, exactly,
    across plane groups."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    s = bern(ks[0], (t, 2, 10, 16))
    w = jax.random.normal(ks[1], (16, 8))
    b = jax.random.normal(ks[2], (8,))
    exact(ops.spike_linear(pack_timesteps(s), w, b, t=t),
          unified.wssl(s, w, b))


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("t", [4, 12])
def test_zsc_packed_parity(seed, t):
    """Space-to-depth on packed plane groups == space-to-depth on planes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    s = bern(ks[0], (t, 2, 8, 8, 3), 0.4)
    kern = jax.random.normal(ks[1], (2, 2, 3, 5))
    want = unified.zsc(s, kern)
    got = ops.spike_linear(space_to_depth(pack_timesteps(s), 2),
                           kern.reshape(-1, 5), t=t)
    exact(got, want)


@pytest.mark.parametrize("seed", range(5))
def test_sssc_packed_parity(seed):
    """Bit-plane 2^k bookkeeping: shift-and-sum over uint8 value planes ==
    float sssc, exactly (the uint8 tensor IS the packing)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    img = jax.random.randint(ks[0], (2, 8, 8, 3), 0, 256, jnp.uint8)
    kern = jax.random.normal(ks[1], (2, 2, 3, 4))
    bias = jax.random.normal(ks[2], (4,))
    got = ops.sssc_linear(space_to_depth(img, 2), kern.reshape(-1, 4), bias)
    exact(got, unified.sssc(img, kern, bias))


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("t", TS)
def test_stdp_packed_parity(seed, t):
    """Softmax-free attention on packed plane groups == float stdp. Binary
    q/k/v make every score an exact integer, so associativity cannot break
    this."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = [bern(kk, (t, 1, 2, 32, 16)) for kk in ks]
    got = ops.stdp_attention_packed(pack_timesteps(q), pack_timesteps(k),
                                    pack_timesteps(v), t=t, scale=0.125)
    exact(got, unified.stdp(q, k, v, scale=0.125))


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("t", [4, 12, 16])
def test_tflif_pack_parity(seed, t):
    """Packed TFLIF output bits == the differentiable training LIF spikes —
    the membrane state must survive the 8-timestep group boundary."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    acc = jax.random.normal(ks[0], (t, 2, 10, 8)) * 2.0
    bias = jax.random.normal(ks[1], (8,)) * 0.5
    exact(ops.tflif_pack(acc, bias), pack_timesteps(tflif(acc + bias)))


@pytest.mark.parametrize("seed", range(3))
def test_tflif_pack_per_channel_vth(seed):
    """Vector v_th (the int8 scale fold) == running the scaled dynamics."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    acc = jax.random.normal(ks[0], (12, 2, 8)) * 2.0
    vth = jnp.abs(jax.random.normal(ks[1], (8,))) + 0.5
    got = ops.tflif_pack(acc, None, v_th=vth)
    want = pack_timesteps(tflif(acc, v_th=vth))
    exact(got, want)


@pytest.mark.parametrize("t", [4, 16])
def test_batched_entry_points_pallas_route(t):
    """The forced-Pallas (interpret) route of the batched packed entry points
    agrees with the CPU oracle route (tolerance: blocked accumulation)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    s = bern(ks[0], (t, 2, 6, 16))
    w = jax.random.normal(ks[1], (16, 8))
    b = jax.random.normal(ks[2], (8,))
    p = pack_timesteps(s)
    np.testing.assert_allclose(
        np.asarray(ops.spike_linear(p, w, b, t=t, pallas=True)),
        np.asarray(ops.spike_linear(p, w, b, t=t)), rtol=1e-5, atol=1e-4)
    acc = jax.random.normal(ks[0], (t, 2, 6, 8)) * 2.0
    exact(ops.tflif_pack(acc, b, pallas=True), ops.tflif_pack(acc, b))
    xu = jax.random.randint(ks[1], (2, 6, 12), 0, 256, jnp.uint8)
    w2 = jax.random.normal(ks[2], (12, 5))
    np.testing.assert_allclose(
        np.asarray(ops.sssc_linear(xu, w2, pallas=True)),
        np.asarray(ops.sssc_linear(xu, w2)), rtol=5e-3, atol=0.5)


@pytest.mark.parametrize("t", TS)
def test_pack_timesteps_roundtrip_and_bit_layout(t):
    s = bern(jax.random.PRNGKey(0), (t, 3, 7), 0.5)
    p = pack_timesteps(s)
    g = num_plane_groups(t)
    assert p.dtype == jnp.uint8 and p.shape == (g, 3, 7)
    exact(unpack_timesteps(p, t), s)
    # bit j of group tt//8 holds timestep tt (tflif_ref convention)
    for tt in range(t):
        exact((p[tt // 8] >> (tt % 8)) & 1, s[tt].astype(jnp.uint8))
    # bits past T-1 in the last group are zero
    live_last = t - 8 * (g - 1)
    if live_last < 8:
        assert int(jnp.max(p[g - 1] >> live_last)) == 0


@pytest.mark.parametrize("t", [4, 12])
def test_packed_iand_residual_matches_float(t):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    a, b = bern(ks[0], (t, 50), 0.5), bern(ks[1], (t, 50), 0.5)
    got = PackedBackend().residual(pack_timesteps(a), pack_timesteps(b),
                                   "iand")
    exact(got, pack_timesteps((1.0 - a) * b))


# ---------------------------------------------------------------------------
# int8 weight quantization (the scale-folded threshold route)
# ---------------------------------------------------------------------------

def test_quantize_layer_roundtrip_bound():
    """|w - wq*s| <= s/2 per element, wq in [-127, 127], scale > 0."""
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8)) * 3.0
    q = quantize_layer({"kernel": w, "bias": jnp.zeros((8,))})
    assert q["kernel"].dtype == jnp.int8
    wq = np.asarray(q["kernel"], np.float32)
    s = np.asarray(q["scale"])
    assert (np.abs(wq) <= 127).all() and (s > 0).all()
    bound = np.broadcast_to(s / 2 + 1e-7, wq.shape)
    np.testing.assert_array_less(np.abs(np.asarray(w) - wq * s), bound)


def test_quantize_idempotent_on_grid():
    """Weights already on the int8 grid re-quantize to themselves (every
    channel max rounds to exactly +-127, so the recovered scale matches)."""
    w = jax.random.normal(jax.random.PRNGKey(3), (16, 8)) * 2.0
    q1 = quantize_layer({"kernel": w, "bias": jnp.zeros((8,))})
    deq = q1["kernel"].astype(jnp.float32) * q1["scale"]
    q2 = quantize_layer({"kernel": deq, "bias": jnp.zeros((8,))})
    exact(q1["kernel"], q2["kernel"])
    np.testing.assert_allclose(np.asarray(q1["scale"]),
                               np.asarray(q2["scale"]), rtol=1e-6)


def test_quantize_layer_zero_column_safe():
    """An all-zero output channel must not divide by zero."""
    w = jnp.zeros((6, 3)).at[:, 1].set(1.0)
    q = quantize_layer({"kernel": w, "bias": jnp.zeros((3,))})
    assert bool(jnp.all(jnp.isfinite(q["scale"])))
    assert int(q["kernel"][0, 0]) == 0


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("t", [4, 12])
def test_wssl_int8_scale_fold_parity(seed, t):
    """Packed int8 WSSL+LIF (integer accumulators, threshold v_th/s) ==
    the float emulation of the identical quantized math."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    s = bern(ks[0], (t, 2, 10, 16))
    w = jax.random.normal(ks[1], (16, 8))
    b = jax.random.normal(ks[2], (8,))
    q = quantize_layer({"kernel": w, "bias": b})
    got = PackedBackend().wssl_lif(pack_timesteps(s), q["kernel"], q["bias"],
                                   t=t, scale=q["scale"])
    want = pack_timesteps(FloatBackend().wssl_lif(
        s, q["kernel"], q["bias"], t=t, scale=q["scale"]))
    exact(got, want)


# ---------------------------------------------------------------------------
# end-to-end: compiled packed == float reference == training graph
# ---------------------------------------------------------------------------

def _compiled(params, cfg, *, backend="packed", batch_size=2,
              weight_dtype=None, folded=False, jit=True):
    """One-bucket compile() — the parity pair constructor."""
    return infer_compile(params, cfg,
                         ExecutionPlan(backend=backend,
                                       weight_dtype=weight_dtype,
                                       batch_buckets=(int(batch_size),)),
                         folded=folded, jit=jit)


@pytest.fixture(scope="module")
def small():
    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(0), cfg)
    img = jax.random.randint(jax.random.PRNGKey(1), (5, 32, 32, 3), 0, 256,
                             jnp.uint8)
    return cfg, params, img


@pytest.mark.parametrize("t", [4, 8, 12, 16])
@pytest.mark.parametrize("weight_dtype", ["float32", "int8"])
def test_compiled_packed_matches_reference_exactly(small, t, weight_dtype):
    """The acceptance sweep: multi-group T and int8 weights, all four
    dataflows end to end, packed logits == reference logits bit for bit."""
    cfg, params, img = small
    cfg = dataclasses.replace(cfg, timesteps=t)
    packed = _compiled(params, cfg, backend="packed",
                       weight_dtype=weight_dtype)
    ref = _compiled(params, cfg, backend="reference",
                    weight_dtype=weight_dtype)
    lp, lr = packed.logits(img), ref.logits(img)
    assert lp.shape == (5, cfg.num_classes)
    exact(lp, lr)


def test_compiled_close_to_training_graph(small):
    """The folded inference graph tracks the unfolded train-mode graph (BN
    folding is float-associative, so this one is allclose, not exact)."""
    cfg, params, img = small
    model = _compiled(params, cfg, backend="packed", batch_size=5)
    want, _ = apply(params, img, cfg, train=False)
    np.testing.assert_allclose(np.asarray(model.logits(img)),
                               np.asarray(want), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("t", [4, 12])
def test_int8_lossless_on_grid_weights(t):
    """Weights exactly representable on the int8 grid fire the same spikes
    through the int8 scale-folded route as through the float route (the
    quantization error is zero, so any spike flip would be a datapath bug;
    thresholds are nowhere near float-rounding distance for these seeds)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    s = bern(ks[0], (t, 2, 10, 16))
    w = quantize_layer({"kernel": jax.random.normal(ks[1], (16, 8)),
                        "bias": jnp.zeros((8,))})
    deq = w["kernel"].astype(jnp.float32) * w["scale"]
    b = jax.random.normal(ks[2], (8,)) * 0.5
    via_float = PackedBackend().wssl_lif(pack_timesteps(s), deq, b, t=t)
    via_int8 = PackedBackend().wssl_lif(pack_timesteps(s), w["kernel"], b,
                                        t=t, scale=w["scale"])
    exact(via_float, via_int8)


def test_compiled_static_batching_invariant(small):
    """Any request size through the fixed-shape step == one whole-batch run
    (pad rows must not leak into real outputs)."""
    cfg, params, img = small
    model = _compiled(params, cfg, backend="packed", batch_size=2)
    whole = _compiled(params, cfg, backend="packed", batch_size=5)
    exact(model.logits(img), whole.logits(img))
    exact(model.logits(img[:1]), whole.logits(img)[:1])
    labs = model.classify(img)
    assert labs.shape == (5,) and labs.dtype == jnp.int32


@pytest.mark.parametrize("weight_dtype", ["float32", "int8"])
def test_forward_folded_backends_agree(small, weight_dtype):
    """forward_folded (the core driver, below the compile layer) produces
    identical logits through the float and packed backends."""
    cfg, params, img = small
    folded = fold_inference_params(params, cfg)
    if weight_dtype == "int8":
        folded = quantize_folded(folded)
    got = forward_folded(folded, img, cfg, backend=PackedBackend())
    want = forward_folded(folded, img, cfg, backend=FloatBackend())
    exact(got, want)


def test_compiled_rejects_unknown_weight_dtype(small):
    cfg, params, _ = small
    with pytest.raises(ValueError, match="weight_dtype"):
        _compiled(params, cfg, weight_dtype="int4")


def test_compiled_weight_dtype_vs_prequantized_tree(small):
    """A pre-quantized folded tree: default dtype auto-reports int8; an
    explicit float32 request must fail loudly, not silently run int8."""
    cfg, params, img = small
    qtree = quantize_folded(fold_inference_params(params, cfg))
    auto = _compiled(qtree, cfg, folded=True, batch_size=5)
    assert auto.weight_dtype == "int8"
    direct = _compiled(params, cfg, batch_size=5, weight_dtype="int8")
    exact(auto.logits(img), direct.logits(img))
    with pytest.raises(ValueError, match="already int8-quantized"):
        _compiled(qtree, cfg, folded=True, weight_dtype="float32")


def test_packed_backend_rejects_add_residual(small):
    cfg, params, img = small
    cfg_add = dataclasses.replace(cfg, residual="add")
    model = _compiled(params, cfg_add, backend="packed", batch_size=5,
                      jit=False)
    with pytest.raises(ValueError, match="binary"):
        model.logits(img)


def test_serve_engine_matches_compiled(small):
    """The micro-batching engine (images from different requests fused into
    one step) classifies identically to a direct compiled-model call."""
    from repro.launch.serve_spikformer import SpikformerEngine, ImageRequest
    cfg, params, img = small
    eng = SpikformerEngine(params, cfg, batch_size=4, backend="packed")
    imgs = np.asarray(img)
    eng.submit(ImageRequest(rid=0, images=imgs[:3]))
    eng.submit(ImageRequest(rid=1, images=imgs[3:]))
    done = sorted(eng.run(), key=lambda r: r.rid)
    got = [lab for r in done for lab in r.labels]
    want = np.asarray(eng.session.classify(imgs)).tolist()
    assert got == want
