"""Packed-bit inference parity: for each of the four unified dataflows
(WSSL/ZSC/SSSC/STDP) the packed path must match the ``core.unified`` float
reference BIT-EXACTLY on random binary/uint8 inputs — spikes are binary, so
no tolerance — including the T-fold and the SSSC bit-plane 2^k bookkeeping.
Plus: InferenceSession end-to-end equality, static-shape batching, and the
micro-batching serve engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import unified
from repro.core.lif import tflif
from repro.core.spike import (pack_timesteps, unpack_timesteps,
                              space_to_depth)
from repro.core.spikformer import (SpikformerConfig, init, apply,
                                   fold_inference_params, forward_folded)
from repro.infer import FloatBackend, PackedBackend, InferenceSession
from repro.kernels import ops


def exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def bern(key, shape, p=0.3):
    return (jax.random.uniform(key, shape) < p).astype(jnp.float32)


# ---------------------------------------------------------------------------
# per-dataflow parity (packed entry points vs core.unified, bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("t", [1, 4, 8])
def test_wssl_packed_parity(seed, t):
    """Temporal T-fold: packed per-plane matmul == float wssl, exactly."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    s = bern(ks[0], (t, 2, 10, 16))
    w = jax.random.normal(ks[1], (16, 8))
    b = jax.random.normal(ks[2], (8,))
    exact(ops.spike_linear(pack_timesteps(s), w, b, t=t),
          unified.wssl(s, w, b))


@pytest.mark.parametrize("seed", range(3))
def test_zsc_packed_parity(seed):
    """Space-to-depth on packed bytes == space-to-depth on spike planes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    s = bern(ks[0], (4, 2, 8, 8, 3), 0.4)
    kern = jax.random.normal(ks[1], (2, 2, 3, 5))
    want = unified.zsc(s, kern)
    got = ops.spike_linear(space_to_depth(pack_timesteps(s), 2),
                           kern.reshape(-1, 5), t=4)
    exact(got, want)


@pytest.mark.parametrize("seed", range(5))
def test_sssc_packed_parity(seed):
    """Bit-plane 2^k bookkeeping: shift-and-sum over uint8 value planes ==
    float sssc, exactly (the uint8 tensor IS the packing)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    img = jax.random.randint(ks[0], (2, 8, 8, 3), 0, 256, jnp.uint8)
    kern = jax.random.normal(ks[1], (2, 2, 3, 4))
    bias = jax.random.normal(ks[2], (4,))
    got = ops.sssc_linear(space_to_depth(img, 2), kern.reshape(-1, 4), bias)
    exact(got, unified.sssc(img, kern, bias))


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("t", [1, 4, 8])
def test_stdp_packed_parity(seed, t):
    """Softmax-free attention on packed spikes == float stdp. Binary q/k/v
    make every score an exact integer, so associativity cannot break this."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = [bern(kk, (t, 1, 2, 32, 16)) for kk in ks]
    got = ops.stdp_attention_packed(pack_timesteps(q), pack_timesteps(k),
                                    pack_timesteps(v), t=t, scale=0.125)
    exact(got, unified.stdp(q, k, v, scale=0.125))


@pytest.mark.parametrize("seed", range(5))
def test_tflif_pack_parity(seed):
    """Packed TFLIF output bits == the differentiable training LIF spikes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    acc = jax.random.normal(ks[0], (4, 2, 10, 8)) * 2.0
    bias = jax.random.normal(ks[1], (8,)) * 0.5
    exact(ops.tflif_pack(acc, bias), pack_timesteps(tflif(acc + bias)))


def test_batched_entry_points_pallas_route():
    """The forced-Pallas (interpret) route of the batched packed entry points
    agrees with the CPU oracle route (tolerance: blocked accumulation)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    s = bern(ks[0], (4, 2, 6, 16))
    w = jax.random.normal(ks[1], (16, 8))
    b = jax.random.normal(ks[2], (8,))
    p = pack_timesteps(s)
    np.testing.assert_allclose(
        np.asarray(ops.spike_linear(p, w, b, t=4, pallas=True)),
        np.asarray(ops.spike_linear(p, w, b, t=4)), rtol=1e-5, atol=1e-4)
    acc = jax.random.normal(ks[0], (4, 2, 6, 8)) * 2.0
    exact(ops.tflif_pack(acc, b, pallas=True), ops.tflif_pack(acc, b))
    xu = jax.random.randint(ks[1], (2, 6, 12), 0, 256, jnp.uint8)
    w2 = jax.random.normal(ks[2], (12, 5))
    np.testing.assert_allclose(
        np.asarray(ops.sssc_linear(xu, w2, pallas=True)),
        np.asarray(ops.sssc_linear(xu, w2)), rtol=5e-3, atol=0.5)


def test_pack_timesteps_roundtrip_and_bit_layout():
    s = bern(jax.random.PRNGKey(0), (5, 3, 7), 0.5)
    p = pack_timesteps(s)
    assert p.dtype == jnp.uint8 and p.shape == (3, 7)
    exact(unpack_timesteps(p, 5), s)
    # bit t holds timestep t (tflif_ref convention); bits >= T are zero
    for t in range(5):
        exact((p >> t) & 1, s[t].astype(jnp.uint8))
    assert int(jnp.max(p >> 5)) == 0


def test_packed_iand_residual_matches_float():
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    a, b = bern(ks[0], (4, 50), 0.5), bern(ks[1], (4, 50), 0.5)
    got = PackedBackend().residual(pack_timesteps(a), pack_timesteps(b),
                                   "iand")
    exact(got, pack_timesteps((1.0 - a) * b))


# ---------------------------------------------------------------------------
# end-to-end: InferenceSession packed == float reference == training graph
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(0), cfg)
    img = jax.random.randint(jax.random.PRNGKey(1), (5, 32, 32, 3), 0, 256,
                             jnp.uint8)
    return cfg, params, img


def test_session_packed_matches_reference_exactly(small):
    cfg, params, img = small
    packed = InferenceSession(params, cfg, backend="packed", batch_size=2)
    ref = InferenceSession(params, cfg, backend="reference", batch_size=2)
    lp, lr = packed.logits(img), ref.logits(img)
    assert lp.shape == (5, cfg.num_classes)
    exact(lp, lr)


def test_session_close_to_training_graph(small):
    """The folded inference graph tracks the unfolded train-mode graph (BN
    folding is float-associative, so this one is allclose, not exact)."""
    cfg, params, img = small
    sess = InferenceSession(params, cfg, backend="packed", batch_size=5)
    want, _ = apply(params, img, cfg, train=False)
    np.testing.assert_allclose(np.asarray(sess.logits(img)),
                               np.asarray(want), rtol=1e-3, atol=1e-3)


def test_session_static_batching_invariant(small):
    """Any request size through the fixed-shape step == one whole-batch run
    (pad rows must not leak into real outputs)."""
    cfg, params, img = small
    sess = InferenceSession(params, cfg, backend="packed", batch_size=2)
    whole = InferenceSession(params, cfg, backend="packed", batch_size=5)
    exact(sess.logits(img), whole.logits(img))
    exact(sess.logits(img[:1]), whole.logits(img)[:1])
    labs = sess.classify(img)
    assert labs.shape == (5,) and labs.dtype == jnp.int32


def test_forward_folded_backends_agree(small):
    """forward_folded (the core driver, below the session layer) produces
    identical logits through the float and packed backends."""
    cfg, params, img = small
    folded = fold_inference_params(params, cfg)
    got = forward_folded(folded, img, cfg, backend=PackedBackend())
    want = forward_folded(folded, img, cfg, backend=FloatBackend())
    exact(got, want)


def test_packed_backend_rejects_add_residual(small):
    cfg, params, img = small
    import dataclasses
    cfg_add = dataclasses.replace(cfg, residual="add")
    sess = InferenceSession(params, cfg_add, backend="packed", batch_size=5,
                            jit=False)
    with pytest.raises(ValueError, match="binary"):
        sess.logits(img)


def test_serve_engine_matches_session(small):
    """The micro-batching engine (images from different requests fused into
    one step) classifies identically to a direct session call."""
    from repro.launch.serve_spikformer import SpikformerEngine, ImageRequest
    cfg, params, img = small
    eng = SpikformerEngine(params, cfg, batch_size=4, backend="packed")
    imgs = np.asarray(img)
    eng.submit(ImageRequest(rid=0, images=imgs[:3]))
    eng.submit(ImageRequest(rid=1, images=imgs[3:]))
    done = sorted(eng.run(), key=lambda r: r.rid)
    got = [lab for r in done for lab in r.labels]
    want = np.asarray(eng.session.classify(imgs)).tolist()
    assert got == want
