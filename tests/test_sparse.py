"""Spike sparsity end to end: the zero-chunk-skipping gather, the
occupancy-aware route choice, calibration, and the serving telemetry that
feeds measured occupancy back into scheduling.

Contract under test (see kernels/lut_matmul.py and infer/compile.py):

  * ``lut_matmul_sparse`` is bit-identical to the dense ``lut_matmul`` for
    EVERY input and EVERY budget — when a row's nonzero chunks exceed the
    budget the kernel falls back to the dense gather inside a ``lax.cond``,
    so a stale calibration costs throughput, never correctness. Empty
    budget slots gather ``table[0, 0, :]`` = the all-zero chunk's subset
    sum = exact zero, the same identity the dense fold adds.
  * ``choose_route`` never returns "lut_sparse" without a calibrated
    occupancy: sparsity claims must be measured, not assumed.
  * ``ExecutionPlan.layer_occupancy`` round-trips through JSON and replays
    pinned "lut_sparse" routes bit-exactly.
  * The engine/runtime measure per-step batch occupancy and the scheduler
    conditions its SLO service estimate on it.

Plus the serving-correctness regressions fixed alongside: multi-chunk SLO
budgeting, submit(rid=) conflicts, and microsecond latency reporting.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spike import pack_timesteps, structured_spikes
from repro.core.spikformer import SpikformerConfig, init
from repro.infer import (ExecutionPlan, MicroBatchEngine, OccupancyRecorder,
                         batch_occupancy, calibrate_layer_occupancy,
                         chunk_occupancy, compile as infer_compile,
                         linear_layer_paths, value_chunk_occupancy)
from repro.infer.compile import plan_chunks
from repro.infer.engine import Request, StepAccounting, latency_summary
from repro.kernels import lut_matmul as lut
from repro.kernels import ops
from repro.kernels.lut_matmul import RouteConstants
from repro.serve import (AsyncServeRuntime, ContinuousBatchingScheduler,
                         ServePolicy)

AWKWARD_TS = [1, 9, 17]


def exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def bern(key, shape, p=0.35):
    return (jax.random.uniform(key, shape) < p).astype(jnp.float32)


def int8_w(key, shape):
    return jax.random.randint(key, shape, -127, 128, jnp.int8)


@pytest.fixture(scope="module")
def small():
    cfg = SpikformerConfig().scaled(img_size=16, dim=32, depth=1)
    params = init(jax.random.PRNGKey(0), cfg)
    imgs = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (6, 16, 16, 3), 0, 256, "uint8"))
    return cfg, params, imgs


# ---------------------------------------------------------------------------
# sparse_budget: the static trace-time gather budget
# ---------------------------------------------------------------------------

def test_sparse_budget_units_and_bounds():
    # occupancy is a FRACTION of nonzero chunk-index bytes, budget a CHUNK
    # count: ceil(occ*c) plus one slack chunk for calibration jitter
    assert lut.sparse_budget(32, 0.0) == 1
    assert lut.sparse_budget(32, 0.1) == 5          # ceil(3.2) + 1
    assert lut.sparse_budget(32, 1.0) == 32         # never exceeds c
    assert lut.sparse_budget(4, 0.9) == 4
    assert lut.sparse_budget(1, 0.5) == 1
    prev = 0
    for occ in np.linspace(0.0, 1.0, 21):
        b = lut.sparse_budget(32, float(occ))
        assert 1 <= b <= 32 and b >= prev           # monotone in occupancy
        prev = b


# ---------------------------------------------------------------------------
# lut_matmul_sparse: bit-exact at every budget, for every input
# ---------------------------------------------------------------------------

def sparse_idx(key, t, m, k, rate=0.15):
    """Chunk-index planes from channel-structured spikes (some chunks all
    zero, some dense — the distribution the sparse route exists for)."""
    x = structured_spikes(key, t=t, shape=(m, k), rate=rate)
    return lut.plane_indices(x)[:t]


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_lut_matmul_sparse_every_budget_bit_exact(dtype):
    key = jax.random.PRNGKey(0)
    t, m, k = 8, 16, 64
    idx = sparse_idx(key, t, m, k)
    if dtype == "int8":
        w = int8_w(jax.random.fold_in(key, 1), (k, 9))
    else:
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, 9))
    tbl = lut.build_lut(w)
    want = lut.lut_matmul(idx, tbl)
    c = tbl.shape[0]
    for budget in range(1, c + 1):
        exact(lut.lut_matmul_sparse(idx, tbl, max_chunks=budget), want)


def test_lut_matmul_sparse_all_zero_planes():
    # the degenerate best case: every slot gathers the zero identity
    t, m, k = 8, 5, 40
    idx = jnp.zeros((t, m, lut.num_k_chunks(k)), jnp.uint8)
    w = jax.random.normal(jax.random.PRNGKey(2), (k, 7))
    tbl = lut.build_lut(w)
    got = lut.lut_matmul_sparse(idx, tbl, max_chunks=2)
    exact(got, jnp.zeros((t, m, 7), jnp.float32))
    exact(got, lut.lut_matmul(idx, tbl))


def test_lut_matmul_sparse_single_spike_planes():
    # exactly one nonzero chunk per row: budget 1 must already be exact
    t, m, c = 4, 6, 8
    k = 8 * c
    rows = jax.random.randint(jax.random.PRNGKey(3), (t, m), 0, c)
    vals = jax.random.randint(jax.random.PRNGKey(4), (t, m), 1, 256,
                              jnp.uint8)
    idx = jnp.zeros((t, m, c), jnp.uint8).at[
        jnp.arange(t)[:, None], jnp.arange(m)[None, :], rows].set(vals)
    w = jax.random.normal(jax.random.PRNGKey(5), (k, 11))
    tbl = lut.build_lut(w)
    exact(lut.lut_matmul_sparse(idx, tbl, max_chunks=1),
          lut.lut_matmul(idx, tbl))


@pytest.mark.parametrize("t", AWKWARD_TS)
def test_spike_linear_sparse_tail_k_awkward_t(t):
    """K=21 (tail chunk live on 5 of 8 lanes) through the op-level route,
    int8 weights: sparse == dense LUT == unpack, bit for bit."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    s = bern(ks[0], (t, 2, 6, 21), p=0.1)
    w = int8_w(ks[1], (21, 9))
    b = jax.random.normal(ks[2], (9,))
    p = pack_timesteps(s)
    occ = chunk_occupancy(p, t)
    got = ops.spike_linear(p, w, b, t=t, route="lut_sparse", occupancy=occ)
    exact(got, ops.spike_linear(p, w, b, t=t, route="lut"))
    exact(got, ops.spike_linear(p, w, b, t=t, route="unpack"))


def test_spike_linear_sparse_float32_matches_fold_oracle():
    t, m, k, n = 8, 12, 64, 9
    key = jax.random.PRNGKey(7)
    x = structured_spikes(key, t=t, shape=(m, k), rate=0.15)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    occ = chunk_occupancy(x, t)
    got = ops.spike_linear(x, w, None, t=t, route="lut_sparse",
                           occupancy=occ)
    exact(got, ops.spike_linear(x, w, None, t=t, route="lut"))
    from repro.core.spike import unpack_timesteps
    planes = unpack_timesteps(x, t)
    exact(got, lut.lut_matmul_planes(planes, w))


def test_sssc_linear_sparse_route_parity():
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    x = jax.random.randint(ks[0], (5, 24), 0, 4, jnp.uint8)  # dark pixels
    w = int8_w(ks[1], (24, 7))
    occ = value_chunk_occupancy(x)
    exact(ops.sssc_linear(x, w, None, route="lut_sparse", occupancy=occ),
          ops.sssc_linear(x, w, None, route="lut"))


def test_lut_matmul_sparse_block_n_tiling_is_exact():
    idx = sparse_idx(jax.random.PRNGKey(9), 8, 7, 40)
    w = jax.random.normal(jax.random.PRNGKey(10), (40, 33))
    tbl = lut.build_lut(w)
    exact(lut.lut_matmul_sparse(idx, tbl, max_chunks=2, block_n=8),
          lut.lut_matmul_sparse(idx, tbl, max_chunks=2))


def test_sparse_path_actually_executes():
    """Guard against the sparse route silently degenerating into the dense
    gather: under budget the lowering must carry the runtime nnz check
    (a ``cond``), and at full budget it must NOT (plain dense gather)."""
    idx = sparse_idx(jax.random.PRNGKey(11), 8, 4, 32)
    tbl = lut.build_lut(jax.random.normal(jax.random.PRNGKey(12), (32, 5)))
    sparse = str(jax.make_jaxpr(
        lambda i: lut.lut_matmul_sparse(i, tbl, max_chunks=2))(idx))
    dense = str(jax.make_jaxpr(
        lambda i: lut.lut_matmul_sparse(i, tbl,
                                        max_chunks=tbl.shape[0]))(idx))
    assert "cond" in sparse
    assert "cond" not in dense


# ---------------------------------------------------------------------------
# choose_route: occupancy-aware dispatch
# ---------------------------------------------------------------------------

def test_choose_route_requires_measured_occupancy():
    shape = dict(m=512, k=256, n=256, g=1, t=8)
    # no calibration -> sparsity is never assumed
    assert lut.choose_route(**shape) != "lut_sparse"
    # calibrated low occupancy on a cache-spilling shape: sparse wins
    assert lut.choose_route(**shape, occupancy=0.05) == "lut_sparse"
    # near-dense traffic leaves no budget headroom -> same as uncalibrated
    assert lut.choose_route(**shape, occupancy=0.95) == \
        lut.choose_route(**shape)


def test_choose_route_sparse_loses_when_compaction_dominates():
    # tiny N: the N-independent compaction term swamps the gather saving
    shape = dict(m=64, k=32, n=16, g=1, t=8)
    assert lut.choose_route(**shape, occupancy=0.4) != "lut_sparse"


def test_ops_resolve_route_guards():
    x = structured_spikes(jax.random.PRNGKey(13), t=8, shape=(4, 32),
                          rate=0.1)
    w = jax.random.normal(jax.random.PRNGKey(14), (32, 5))
    with pytest.raises(ValueError, match="occupancy"):
        ops.spike_linear(x, w, None, t=8, route="lut_sparse")


# ---------------------------------------------------------------------------
# ExecutionPlan: layer_occupancy as data
# ---------------------------------------------------------------------------

def test_plan_layer_occupancy_json_roundtrip_and_validation():
    occ = {"scs/conv0": 0.12, "blocks/b0/mlp/fc1": 0.4}
    p = ExecutionPlan(batch_buckets=(2,), layer_occupancy=occ)
    q = ExecutionPlan.from_json(p.to_json())
    assert q.layer_occupancy == occ
    assert q == p
    with pytest.raises(ValueError, match="occupancy"):
        ExecutionPlan(layer_occupancy={"scs/conv0": 1.5})
    with pytest.raises(ValueError, match="occupancy"):
        ExecutionPlan(layer_occupancy={"scs/conv0": -0.1})


def test_calibrate_layer_occupancy_covers_every_linear(small):
    cfg, params, imgs = small
    occ = calibrate_layer_occupancy(params, cfg, imgs[:2])
    assert sorted(occ) == sorted(linear_layer_paths(cfg))
    assert all(0.0 <= v <= 1.0 for v in occ.values())
    # the recorder trace it is built from has one sample per linear
    rec = OccupancyRecorder()
    assert rec.trace == []


def sparse_plan(paths, *, weight_dtype="int8"):
    """A plan that routes every calibrated layer sparse: low calibrated
    occupancy + constants that make the compaction free and the unpack
    route prohibitive, so the cost model picks "lut_sparse" wherever a
    budget exists. Correctness never depends on these being realistic."""
    return ExecutionPlan(
        batch_buckets=(2,), weight_dtype=weight_dtype,
        route_constants=RouteConstants(compact_cost=1e-6, unpack_cost=1e6),
        layer_occupancy={p: 0.05 for p in paths})


def test_compile_sparse_plan_end_to_end_bit_exact(small):
    """The acceptance property: a compiled model whose layers route through
    the zero-chunk-skipping gather classifies bit-identically to the dense
    plan — on ordinary (not especially sparse) images, where per-row nnz
    routinely overflows the budget and the cond fallback must carry it."""
    cfg, params, imgs = small
    sp = sparse_plan(linear_layer_paths(cfg))
    m_sparse = infer_compile(params, cfg, sp)
    assert "lut_sparse" in m_sparse.plan.routes.values()
    m_dense = infer_compile(params, cfg, ExecutionPlan(
        batch_buckets=(2,), weight_dtype="int8",
        route_constants=RouteConstants(unpack_cost=1e6)))
    assert "lut_sparse" not in m_dense.plan.routes.values()
    exact(m_sparse.classify(imgs), m_dense.classify(imgs))
    # and against the float-oracle emulation backend: the repo-wide
    # packed == reference bit-identity must survive sparse routing
    m_ref = infer_compile(params, cfg, ExecutionPlan(
        batch_buckets=(2,), weight_dtype="int8", backend="reference"))
    exact(m_sparse.classify(imgs), m_ref.classify(imgs))


def test_pinned_lut_sparse_replays_from_json(small):
    cfg, params, imgs = small
    m1 = infer_compile(params, cfg, sparse_plan(linear_layer_paths(cfg)))
    replay = ExecutionPlan.from_json(m1.plan.to_json())
    assert replay.routes == m1.plan.routes
    m2 = infer_compile(params, cfg, replay)
    exact(m1.classify(imgs), m2.classify(imgs))


def test_pinned_lut_sparse_without_occupancy_fails_loud(small):
    cfg, params, _ = small
    m1 = infer_compile(params, cfg, sparse_plan(linear_layer_paths(cfg)))
    stripped = dataclasses.replace(m1.plan, layer_occupancy=None)
    with pytest.raises(ValueError, match="occupancy"):
        infer_compile(params, cfg, stripped)


# ---------------------------------------------------------------------------
# structured_spikes: the sparsity the benchmarks measure is the one asked for
# ---------------------------------------------------------------------------

def test_structured_spikes_rate_and_chunk_occupancy():
    t, shape = 8, (64, 256)
    for rate in (0.1, 0.3):
        x = structured_spikes(jax.random.PRNGKey(15), t=t, shape=shape,
                              rate=rate)
        fired = float(jnp.mean(jnp.unpackbits(np.asarray(x).reshape(-1))))
        assert fired == pytest.approx(rate, abs=0.05)
        # chunk occupancy tracks the firing rate ~1:1 (the point of the
        # channel-structured distribution), not ~2x like iid spikes
        occ = chunk_occupancy(x, t)
        assert occ == pytest.approx(rate / 0.9, abs=0.08)
    z = structured_spikes(jax.random.PRNGKey(16), t=t, shape=shape,
                          rate=0.0)
    assert not np.asarray(z).any()
    with pytest.raises(AssertionError):
        structured_spikes(jax.random.PRNGKey(17), t=t, shape=(4, 12),
                          rate=0.1)   # channels not a multiple of 8


# ---------------------------------------------------------------------------
# serving telemetry: occupancy through accounting, stats and the scheduler
# ---------------------------------------------------------------------------

def test_step_accounting_occupancy_rows_weighted():
    acct = StepAccounting()
    assert acct.occupancy is None                    # absence, not 0.0
    acct.record_step(rows=2, bucket=2, busy_s=0.0, wall_s=0.0)
    assert acct.occupancy is None                    # unmeasured step
    acct.record_step(rows=2, bucket=2, busy_s=0.0, wall_s=0.0,
                     occupancy=0.5)
    acct.record_step(rows=6, bucket=8, busy_s=0.0, wall_s=0.0,
                     occupancy=0.25)
    assert acct.occupancy == pytest.approx((0.5 * 2 + 0.25 * 6) / 8)


def test_batch_occupancy_counts_set_bits():
    assert batch_occupancy(np.zeros((2, 2, 2, 1), np.uint8)) == 0.0
    assert batch_occupancy(np.full((1, 1, 1, 1), 255, np.uint8)) == 1.0
    assert batch_occupancy(np.zeros((0, 2, 2, 1), np.uint8)) == 0.0


def test_engine_and_runtime_stats_report_occupancy(small):
    cfg, params, imgs = small
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2,)))
    eng = MicroBatchEngine(model)
    assert eng.stats()["occupancy"] is None          # nothing measured yet
    eng.submit(imgs[:2])
    eng.run()
    occ = eng.stats()["occupancy"]
    assert occ == pytest.approx(batch_occupancy(imgs[:2]), abs=1e-4)
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        rt.submit(imgs[:2]).result(timeout=30)
        assert rt.stats()["occupancy"] is not None


def test_scheduler_conditions_estimate_on_occupancy():
    s = ContinuousBatchingScheduler(
        (2, 8), ServePolicy(sparse_occupancy=0.35))
    s.observe_step(2, 0.03, occupancy=0.8)           # dense sample
    s.observe_step(2, 0.01, occupancy=0.1)           # sparse sample
    assert s.service_estimate(2, occupancy=0.1) == pytest.approx(0.01)
    assert s.service_estimate(2, occupancy=0.9) == pytest.approx(0.03)
    # no explicit occupancy: the running EWMA (dense-leaning here) decides
    assert s.service_estimate(2) == pytest.approx(0.03)
    # split disabled: one EWMA regardless of occupancy
    s2 = ContinuousBatchingScheduler(
        (2, 8), ServePolicy(sparse_occupancy=None))
    s2.observe_step(2, 0.03, occupancy=0.8)
    s2.observe_step(2, 0.01, occupancy=0.1)
    assert s2.service_estimate(2, occupancy=0.1) == \
        s2.service_estimate(2, occupancy=0.9)


def test_serve_policy_validates_sparse_occupancy():
    with pytest.raises(ValueError, match="sparse_occupancy"):
        ServePolicy(sparse_occupancy=0.0)
    with pytest.raises(ValueError, match="sparse_occupancy"):
        ServePolicy(sparse_occupancy=1.5)
    assert ServePolicy(sparse_occupancy=None).sparse_occupancy is None


# ---------------------------------------------------------------------------
# serving-correctness regressions (each failed before the fix)
# ---------------------------------------------------------------------------

def test_decide_slo_budgets_the_whole_split():
    """SLO pressure must reserve service time for EVERY chunk of the
    pad-minimizing split, not just the first: the oldest request's last
    image may land in the final chunk. Before the fix this scenario kept
    the window open ('wait') because one 4 ms step fit the budget."""
    s = ContinuousBatchingScheduler(
        (2, 8), ServePolicy(max_wait_ms=10.0, slo_ms=20.0))
    s.observe_step(2, 0.004)
    chunks = plan_chunks(6, s.buckets)
    assert len(chunks) > 1                           # scenario sanity
    d = s.decide(backlog=6, oldest_submit_s=0.0, now_s=0.009)
    assert (d.action, d.reason) == ("dispatch", "SLO pressure")
    # inside the full-split deadline the window stays open
    d = s.decide(backlog=6, oldest_submit_s=0.0, now_s=0.007)
    assert d.action == "wait"


def test_submit_rid_conflict_is_rejected(small):
    """submit(Request, rid=) with a disagreeing rid used to silently keep
    the Request's own id — the caller polled an id that never completes."""
    cfg, params, imgs = small
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2,)))
    eng = MicroBatchEngine(model)
    req = Request(rid=5, images=imgs[:1])
    with pytest.raises(ValueError, match="conflicts"):
        eng.submit(req, rid=6)
    assert eng.submit(req, rid=5) is req             # agreeing rid is fine
    assert req.latency_s is None                     # in flight: no latency
    eng.run()
    assert req.latency_s is not None and req.latency_s >= 0.0


def test_latency_summary_keeps_microsecond_precision():
    """Sub-millisecond latencies used to be rounded to 4 decimals, which
    collapsed every serving step on a small model into 0.0001 or 0.0002."""
    out = latency_summary([0.0001234])
    assert out["latency_p50_s"] == 0.000123
    assert out["latency_mean_s"] == 0.000123
    empty = latency_summary([])
    assert empty["latency_p50_s"] is None
