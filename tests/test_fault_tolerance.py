"""Control-plane fault tolerance: heartbeats, stragglers, restart budget,
loss guard, and the supervisor's restore loop with injected failures."""
import math

import pytest

from repro.runtime.fault_tolerance import (HeartbeatMonitor, StragglerDetector,
                                           RestartPolicy, LossGuard,
                                           TrainSupervisor, NodeFailure)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------

def test_heartbeat_detects_silence():
    clk = FakeClock()
    hb = HeartbeatMonitor(n_nodes=4, timeout_s=10.0, clock=clk)
    clk.advance(5)
    for n in (0, 1, 3):
        hb.beat(n)
    clk.advance(7)
    assert hb.dead_nodes() == [2]
    assert not hb.healthy()
    hb.beat(2)
    assert hb.healthy() is False or hb.dead_nodes() == []  # node 2 revived
    assert 2 not in hb.dead_nodes()


def test_straggler_needs_patience():
    det = StragglerDetector(n_nodes=8, z_thresh=4.0, patience=3)
    base = [1.0] * 8
    assert det.update(base) == []
    slow = base.copy()
    slow[5] = 3.0
    assert det.update(slow) == []       # strike 1
    assert det.update(slow) == []       # strike 2
    assert det.update(slow) == [5]      # strike 3 => flagged


def test_straggler_recovers():
    det = StragglerDetector(n_nodes=4, patience=3)
    det.update([1, 1, 1, 5.0])
    for _ in range(16):                 # EWMA decays back toward the median
        out = det.update([1, 1, 1, 1.0])
    assert out == []


def test_restart_policy_budget_window():
    clk = FakeClock()
    pol = RestartPolicy(max_restarts=2, window_s=100, backoff_s=1,
                        clock=clk)
    assert pol.record_failure()
    assert pol.record_failure()
    assert not pol.record_failure()       # budget exhausted
    clk.advance(200)                      # window rolls over
    assert pol.record_failure()


def test_restart_backoff_grows_and_caps():
    pol = RestartPolicy(backoff_s=2, backoff_mult=3, max_backoff_s=10)
    pol.record_failure()
    assert pol.next_delay() == 2
    pol.record_failure()
    assert pol.next_delay() == 6
    pol.record_failure()
    assert pol.next_delay() == 10   # capped


def test_loss_guard():
    g = LossGuard(spike_mult=5.0, warmup=2)
    assert g.check(4.0) and g.check(3.0) and g.check(2.0)
    assert not g.check(float("nan"))
    assert g.check(3.0)
    assert not g.check(11.0)        # > 5 x best(2.0)


def test_supervisor_restores_and_completes():
    """Segment fails twice mid-run; supervisor restores from 'checkpoint'
    (the captured step) and finishes."""
    log = []
    ckpt = {"step": 0}

    def make_state(restore):
        if restore is None:
            return {"step": 0}
        log.append(("restore", ckpt["step"]))
        return {"step": ckpt["step"]}

    fails = {5: True, 8: True}

    def run_segment(state):
        for step in range(state["step"], 12):
            if fails.pop(step, False):
                raise NodeFailure(step)
            ckpt["step"] = step + 1
            log.append(("step", step))
        return None

    sup = TrainSupervisor(RestartPolicy(backoff_s=0), make_state, run_segment,
                          sleep=lambda s: None)
    out = sup.run()
    assert out == {"restarts": 2, "completed": True}
    steps = [s for kind, s in log if kind == "step"]
    assert steps == sorted(steps) and steps[-1] == 11
    assert ("restore", 5) in log and ("restore", 8) in log


def test_supervisor_gives_up_when_budget_spent():
    def make_state(restore):
        return {}

    def run_segment(state):
        raise NodeFailure("always")

    sup = TrainSupervisor(RestartPolicy(max_restarts=3, backoff_s=0),
                          make_state, run_segment, sleep=lambda s: None)
    out = sup.run()
    assert out["completed"] is False
    assert out["restarts"] == 3
