"""Byte-LUT packed matmul: bit-exactness of the unpack-free route.

Contract under test (see kernels/lut_matmul.py):
  * int8 weights — every partial sum is an exact small integer, so the LUT
    route must equal the unpack route (and the float emulation) bit for bit.
  * float32 weights — float sums are not reorderable, so the LUT route is
    held bit-exact against its *fold-order oracle* ``lut_matmul_planes``
    (what FloatBackend executes for LUT-planned layers), and allclose
    against the single-dot unpack route.
  * STDP — binary q/k/v make every accumulator an exact integer: LUT ==
    unpack bitwise regardless of order.
  * tail bits — at awkward T (1, 9, 17) the planes past T-1 are all-zero
    bytes and must stay invisible to every route.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spike import (num_plane_groups, pack_timesteps,
                              unpack_timesteps, space_to_depth)
from repro.core.spikformer import SpikformerConfig, init
from repro.infer import (ExecutionPlan, FloatBackend, PackedBackend,
                         compile as infer_compile)
from repro.infer.compile import plan_route_tables
from repro.core.spikformer import fold_inference_params
from repro.infer.quant import quantize_layer
from repro.kernels import ops
from repro.kernels import lut_matmul as lut

AWKWARD_TS = [1, 9, 17]


def exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def bern(key, shape, p=0.35):
    return (jax.random.uniform(key, shape) < p).astype(jnp.float32)


def int8_w(key, shape):
    return jax.random.randint(key, shape, -127, 128, jnp.int8)


# ---------------------------------------------------------------------------
# primitives: bit transpose, plane indices, table build
# ---------------------------------------------------------------------------

def test_bit_transpose8_matches_naive_and_is_involution():
    b = jax.random.randint(jax.random.PRNGKey(0), (5, 3, 8), 0, 256,
                           jnp.uint8)
    got = np.asarray(lut.bit_transpose8(b))
    bb = np.asarray(b)
    want = np.zeros_like(bb)
    for j in range(8):
        for i in range(8):
            want[..., j] |= (((bb[..., i] >> j) & 1) << i).astype(np.uint8)
    np.testing.assert_array_equal(got, want)
    exact(lut.bit_transpose8(lut.bit_transpose8(b)), b)


@pytest.mark.parametrize("t", AWKWARD_TS)
@pytest.mark.parametrize("k", [5, 8, 19])
def test_plane_indices_bit_layout_and_dead_planes(t, k):
    """idx[p, ..., c] bit i == spike at plane p of input 8c+i; planes past
    t-1 are all-zero bytes (the tail-bit invariant carried through the
    transpose)."""
    s = bern(jax.random.PRNGKey(1), (t, 3, k))
    packed = pack_timesteps(s)                  # (G, 3, k)
    idx = lut.plane_indices(packed)             # (G*8, 3, C)
    g, c = num_plane_groups(t), lut.num_k_chunks(k)
    assert idx.shape == (g * 8, 3, c) and idx.dtype == jnp.uint8
    sn = np.asarray(s, np.uint8)
    got = np.asarray(idx)
    for p in range(g * 8):
        for cc in range(c):
            for i in range(8):
                kk = 8 * cc + i
                want = sn[p, :, kk] if (p < t and kk < k) else 0
                np.testing.assert_array_equal((got[p, :, cc] >> i) & 1, want)
    assert not got[t:].any(), "dead planes must stay all-zero bytes"


def test_build_lut_entries_are_chunk_subset_sums_int8():
    w = int8_w(jax.random.PRNGKey(2), (19, 6))
    tbl = lut.build_lut(w)
    assert tbl.dtype == jnp.int16
    assert tbl.shape == (3, 256, 6)
    wn = np.asarray(w, np.int32)
    wn = np.concatenate([wn, np.zeros((5, 6), np.int32)])   # pad K -> 24
    for c in range(3):
        for b in (0, 1, 0x80, 0xA5, 0xFF):
            want = sum(((b >> i) & 1) * wn[8 * c + i] for i in range(8))
            np.testing.assert_array_equal(np.asarray(tbl)[c, b], want)


def test_lut_matmul_block_n_tiling_is_exact():
    key = jax.random.PRNGKey(3)
    idx = jax.random.randint(key, (4, 7, 5), 0, 256, jnp.uint8)
    w = jax.random.normal(key, (40, 33))
    tbl = lut.build_lut(w)
    exact(lut.lut_matmul(idx, tbl),
          lut.lut_matmul(idx, tbl, block_n=8))


# ---------------------------------------------------------------------------
# per-dataflow route parity at awkward T
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", AWKWARD_TS)
def test_wssl_lut_int8_bit_exact_vs_unpack(t):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    s = bern(ks[0], (t, 2, 6, 21))
    w = int8_w(ks[1], (21, 9))
    b = jax.random.normal(ks[2], (9,))
    p = pack_timesteps(s)
    exact(ops.spike_linear(p, w, b, t=t, route="lut"),
          ops.spike_linear(p, w, b, t=t, route="unpack"))


@pytest.mark.parametrize("t", AWKWARD_TS)
def test_wssl_lut_float32_bit_exact_vs_fold_oracle(t):
    """Float32: the LUT gather must replay lut_matmul_planes' reduction tree
    bit for bit (and track the single-dot unpack route to float tolerance —
    same subset sums, different association)."""
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    s = bern(ks[0], (t, 2, 6, 21))
    w = jax.random.normal(ks[1], (21, 9))
    p = pack_timesteps(s)
    got = ops.spike_linear(p, w, None, t=t, route="lut")
    planes = s.reshape(t, 12, 21)
    want = lut.lut_matmul_planes(planes, w).reshape(t, 2, 6, 9)
    exact(got, want)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(ops.spike_linear(p, w, None, t=t, route="unpack")),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("t", AWKWARD_TS)
def test_zsc_lut_int8_bit_exact_vs_unpack(t):
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    s = bern(ks[0], (t, 2, 6, 6, 3))
    w = int8_w(ks[1], (12, 7))
    p = space_to_depth(pack_timesteps(s), 2)
    exact(ops.spike_linear(p, w, None, t=t, route="lut"),
          ops.spike_linear(p, w, None, t=t, route="unpack"))


def test_sssc_lut_int8_bit_exact_vs_unpack():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    img = jax.random.randint(ks[0], (2, 6, 6, 3), 0, 256, jnp.uint8)
    w = int8_w(ks[1], (12, 5))
    b = jax.random.normal(ks[2], (5,))
    x = space_to_depth(img, 2)
    exact(ops.sssc_linear(x, w, b, route="lut"),
          ops.sssc_linear(x, w, b, route="unpack"))


def test_sssc_lut_float32_bit_exact_vs_fold_oracle():
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    img = jax.random.randint(ks[0], (2, 6, 6, 3), 0, 256, jnp.uint8)
    w = jax.random.normal(ks[1], (12, 5))
    x = space_to_depth(img, 2)
    got = ops.sssc_linear(x, w, None, route="lut")
    want = FloatBackend._sssc_emu(img, w)
    exact(got, want)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(ops.sssc_linear(x, w, None, route="unpack")),
        rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("t", AWKWARD_TS)
def test_stdp_lut_bit_exact_vs_unpack(t):
    """Binary q/k/v: every score and context value is an exact integer, so
    the LUT score path equals the einsum path bitwise at any T."""
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q, k, v = [bern(kk, (t, 1, 2, 12, 16)) for kk in ks]
    qp, kp, vp = pack_timesteps(q), pack_timesteps(k), pack_timesteps(v)
    exact(ops.stdp_attention_packed(qp, kp, vp, t=t, scale=0.25,
                                    route="lut"),
          ops.stdp_attention_packed(qp, kp, vp, t=t, scale=0.25,
                                    route="unpack"))


@pytest.mark.parametrize("t", AWKWARD_TS)
def test_pack_roundtrip_and_tail_zero_awkward_t(t):
    """pack/unpack round-trip at T in {1, 9, 17} and the last-group zero-bit
    invariant the LUT transpose relies on."""
    s = bern(jax.random.PRNGKey(10), (t, 4, 9), 0.5)
    p = pack_timesteps(s)
    g = num_plane_groups(t)
    assert p.shape == (g, 4, 9)
    exact(unpack_timesteps(p, t), s)
    live_last = t - 8 * (g - 1)
    if live_last < 8:
        assert int(jnp.max(p[g - 1] >> live_last)) == 0


# ---------------------------------------------------------------------------
# int8 scale-folded LIF through the LUT route
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", [1, 9])
def test_wssl_lif_int8_lut_table_matches_float_emulation(t):
    """The planner's cached int16 table through the full matmul+LIF stage ==
    FloatBackend's scale-folded emulation, bit for bit."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    s = bern(ks[0], (t, 2, 6, 16))
    q = quantize_layer({"kernel": jax.random.normal(ks[1], (16, 8)),
                        "bias": jax.random.normal(ks[2], (8,))})
    table = lut.build_lut(q["kernel"])
    got = PackedBackend().wssl_lif(pack_timesteps(s), q["kernel"], q["bias"],
                                   t=t, scale=q["scale"], lut=table)
    want = pack_timesteps(FloatBackend().wssl_lif(
        s, q["kernel"], q["bias"], t=t, scale=q["scale"], lut=table))
    exact(got, want)


@pytest.mark.parametrize("t", [4, 9])
def test_popcount_rate_matches_float_reference(t):
    s = bern(jax.random.PRNGKey(12), (t, 3, 5, 7), 0.5)
    exact(PackedBackend().rate(pack_timesteps(s), t=t),
          FloatBackend().rate(s, t=t))


# ---------------------------------------------------------------------------
# dispatch heuristic + planner
# ---------------------------------------------------------------------------

def test_choose_route_respects_table_cap():
    assert ops.choose_route(m=512, k=64, n=64, g=1, t=4,
                            max_table_bytes=1024) == "unpack"


def test_choose_route_picks_lut_at_bench_layer_shapes():
    # the encoder linears and conv stem of the benchmark config
    for m, k, n in [(32, 64, 256), (512, 32, 16), (2048, 12, 8)]:
        assert ops.choose_route(m=m, k=k, n=n, g=1, t=4) == "lut", (m, k, n)


def _compiled(params, cfg, *, backend="packed", batch_size=2,
              weight_dtype=None, route="auto", folded=False, pallas=None,
              jit=True):
    """One-bucket compile() under the historical session argument names —
    keeps the parity tests reading like serving call sites."""
    options = {} if pallas is None else {"pallas": pallas}
    plan = ExecutionPlan(backend=backend, weight_dtype=weight_dtype,
                         batch_buckets=(int(batch_size),), route=route,
                         backend_options=options)
    return infer_compile(params, cfg, plan, folded=folded, jit=jit)


def test_plan_routes_annotates_tables_and_paths():
    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(0), cfg)
    folded = fold_inference_params(params, cfg)
    tree, plan = plan_route_tables(folded, cfg, batch_size=2)
    assert set(plan) >= {"scs/conv0", "blocks/b0/mlp/fc1"}
    for path, route in plan.items():
        parts = path.split("/")
        layer = tree
        for p in parts:
            layer = layer[p]
        if route == "lut":
            k, n = layer["kernel"].shape
            assert layer["lut"].shape == (lut.num_k_chunks(k), 256, n)
            assert layer["lut"].dtype == jnp.float32
        else:
            assert "lut" not in layer
    # the original tree is not mutated
    assert "lut" not in folded["scs"]["conv0"]


# ---------------------------------------------------------------------------
# end-to-end at awkward T: the acceptance property under the new route
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,weight_dtype", [(1, "float32"), (9, "int8"),
                                            (17, "float32"), (9, "float32"),
                                            (17, "int8")])
def test_compiled_lut_planned_parity_awkward_t(t, weight_dtype):
    """Packed (LUT-planned) logits == reference logits bit for bit at
    T in {1, 9, 17} — the last-group zero-bit invariant under the new route,
    end to end through all four dataflows."""
    cfg = dataclasses.replace(SpikformerConfig().scaled(), timesteps=t)
    params = init(jax.random.PRNGKey(0), cfg)
    img = jax.random.randint(jax.random.PRNGKey(1), (2, 32, 32, 3), 0, 256,
                             jnp.uint8)
    packed = _compiled(params, cfg, backend="packed",
                       weight_dtype=weight_dtype)
    ref = _compiled(params, cfg, backend="reference",
                    weight_dtype=weight_dtype)
    assert any(r == "lut" for r in packed.plan.routes.values())
    exact(packed.logits(img), ref.logits(img))


def test_compiled_route_unpack_pins_oracle_route():
    """route='unpack' disables planning; for int8 weights the two routes are
    bit-identical end to end (exact integer accumulators), which pins the
    LUT route against the legacy oracle through the whole network."""
    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(0), cfg)
    img = jax.random.randint(jax.random.PRNGKey(1), (2, 32, 32, 3), 0, 256,
                             jnp.uint8)
    auto = _compiled(params, cfg, backend="packed", weight_dtype="int8")
    pinned = _compiled(params, cfg, backend="packed", weight_dtype="int8",
                       route="unpack")
    assert pinned.plan.routes == {} and \
        any(r == "lut" for r in auto.plan.routes.values())
    exact(auto.logits(img), pinned.logits(img))


def test_compiled_rejects_unknown_route():
    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="route"):
        _compiled(params, cfg, route="fused")


def test_route_unpack_strips_stale_lut_annotations():
    """A pre-annotated folded tree through route='unpack' must actually run
    the unpack route — stale 'lut' leaves would silently keep the LUT route
    alive and break the documented pin."""
    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(0), cfg)
    img = jax.random.randint(jax.random.PRNGKey(1), (2, 32, 32, 3), 0, 256,
                             jnp.uint8)
    auto = _compiled(params, cfg, backend="packed")
    pinned = _compiled(auto.folded, cfg, folded=True, backend="packed",
                       route="unpack")

    def lut_leaves(tree):
        found = []
        jax.tree_util.tree_map_with_path(
            lambda p, _: found.append(p) if "lut" in str(p) else None, tree)
        return found

    assert lut_leaves(auto.folded) and not lut_leaves(pinned.folded)
    fresh = _compiled(params, cfg, backend="packed", route="unpack")
    exact(pinned.logits(img), fresh.logits(img))


def test_reference_skips_and_pallas_builds_tables():
    """The table capability follows who gathers: the float reference never
    does (its LUT layers carry a cheap boolean plan flag), while a
    Pallas-pinned packed model DOES — its byte-LUT kernel gathers the
    (C,256,N) tables from VMEM, so planning must build them."""
    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(0), cfg)
    ref = _compiled(params, cfg, backend="reference")
    pal = _compiled(params, cfg, backend="packed", pallas=True, jit=False)

    def lut_layers(model):
        for path, route in model.plan.routes.items():
            if route == "lut":
                layer = model.folded
                for p in path.split("/"):
                    layer = layer[p]
                yield layer

    seen = 0
    for layer in lut_layers(ref):
        assert layer["lut"] is True            # flag, never a table
        seen += 1
    assert seen
    seen = 0
    for layer in lut_layers(pal):
        assert layer["lut"].ndim == 3          # a real gather table
        assert layer["lut"].shape[1] == 256
        seen += 1
    assert seen


def test_compare_bench_gate():
    import sys
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent
                           / "benchmarks"))
    import compare_bench

    def rec(points, exact_ok=True):
        return {"bit_exact": exact_ok,
                "sweep": [{"timesteps": t, "weight_dtype": wd,
                           "packed_speedup": s} for t, wd, s in points]}

    base = rec([(4, "float32", 1.0), (16, "int8", 2.0)])
    # healthy: geomean of (0.9, 1.1) ~ 1.0
    assert compare_bench.compare(
        rec([(4, "float32", 0.9), (16, "int8", 2.2)]), base,
        min_ratio=0.4) == []
    # cliff: every point halves -> geomean 0.25 < 0.4
    assert compare_bench.compare(
        rec([(4, "float32", 0.25), (16, "int8", 0.5)]), base,
        min_ratio=0.4)
    # bit-exactness is a hard gate
    assert compare_bench.compare(
        rec([(4, "float32", 1.0)], exact_ok=False), base, min_ratio=0.4)
    # zero overlapping points must fail loudly, not pass silently
    assert compare_bench.compare(
        rec([(8, "float32", 1.0)]), base, min_ratio=0.4)
