"""Optimizer + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.compression import ef_init, ef_compress, compressed_psum_int8


def test_schedule_warmup_peak_decay():
    cfg = adamw.OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 50, 100, 200)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-8          # mid warmup
    assert abs(lrs[2] - 1e-3) < 1e-8          # peak
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 1e-4) < 1e-7          # floor
    assert abs(lrs[5] - 1e-4) < 1e-7


def test_adamw_converges_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3, 1))}   # 2-D so weight decay path runs
    cfg = adamw.OptConfig(peak_lr=0.1, warmup_steps=5, decay_steps=200,
                          weight_decay=0.0)
    state = adamw.init(params, cfg)

    def loss(p):
        return jnp.sum((p["w"][:, 0] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_clip_norm_applied():
    params = {"w": jnp.zeros((2, 2))}
    cfg = adamw.OptConfig(clip_norm=1.0, peak_lr=1.0, warmup_steps=0,
                          decay_steps=10)
    state = adamw.init(params, cfg)
    g = {"w": jnp.full((2, 2), 100.0)}
    _, _, m = adamw.update(g, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_bf16_moments():
    params = {"w": jnp.zeros((4, 4))}
    cfg = adamw.OptConfig(state_dtype=jnp.bfloat16)
    state = adamw.init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4))}
    _, s2, _ = adamw.update(g, state, params, cfg)
    assert s2["m"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_compression_error_feedback_unbiased():
    """Error feedback: repeated compression of a CONSTANT gradient delivers
    the true mean in the long run (sum of deq -> n*g)."""
    g = {"w": jnp.array([[0.3, -0.7], [0.001, 1.2]])}
    ef = ef_init(g)
    total = jnp.zeros_like(g["w"])
    for _ in range(50):
        deq, ef = ef_compress(g, ef, method="int8")
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g["w"]),
                               rtol=1e-2, atol=1e-3)


def test_topk_keeps_largest():
    g = {"w": jnp.array([[10.0, 0.1], [0.2, -20.0]])}
    ef = ef_init(g)
    deq, ef2 = ef_compress(g, ef, method="topk", topk_frac=0.5)
    arr = np.asarray(deq["w"])
    assert arr[0, 0] == 10.0 and arr[1, 1] == -20.0
    assert arr[0, 1] == 0.0 and arr[1, 0] == 0.0
    # dropped mass retained in the error buffer
    np.testing.assert_allclose(np.asarray(ef2["w"]),
                               [[0.0, 0.1], [0.2, 0.0]], atol=1e-6)


def test_compressed_psum_matches_mean():
    """shard_map int8 all-reduce == fp32 mean within quantization error."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("x",))
    x = jnp.array([[1.0, -2.0, 3.0, 0.5]])

    f = shard_map(lambda v: compressed_psum_int8(v[0], "x")[None],
                  mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    got = f(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=2e-2,
                               atol=2e-2)


def test_int8_training_still_converges():
    """End-to-end: quadratic fit with int8-compressed grads + EF converges."""
    target = jnp.array([0.5, -1.5])
    params = {"w": jnp.zeros((2, 1))}
    cfg = adamw.OptConfig(peak_lr=0.05, warmup_steps=0, decay_steps=300,
                          weight_decay=0.0)
    state = adamw.init(params, cfg)
    ef = ef_init(params)

    def loss(p):
        return jnp.sum((p["w"][:, 0] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        g, ef = ef_compress(g, ef, method="int8")
        params, state, _ = adamw.update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2
