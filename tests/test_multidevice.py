"""Multi-device numerical equivalence: the sharded train/serve steps on an
8-device (2x4) CPU mesh must match single-device execution. Runs in a
subprocess because the device count must be set before jax initializes."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import get_config
    from repro.nn import transformer as T
    from repro.launch import steps
    from repro.optim import adamw
    from repro.sharding.compat import set_mesh

    cfg = get_config("smollm-360m").reduced(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, vocab=512)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    B, S = 8, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    ts = steps.TrainSettings(microbatch=4)
    opt = adamw.init(params, ts.opt)

    # single device reference
    plain = jax.jit(steps.make_train_step(cfg, ts))
    p_ref, o_ref, m_ref = plain(params, opt, batch)

    # sharded on 2x4
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    bs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    with set_mesh(mesh):
        sharded, _, in_sh = steps.jit_train_step(cfg, mesh, ts, bs)
        # shard + donate COPIES (x.copy() — device_put alone may alias the
        # origin buffer for replicated leaves, and donation deletes it)
        p_cp = jax.tree.map(lambda x, s: jax.device_put(x.copy(), s),
                            params, in_sh[0])
        o_cp = jax.tree.map(lambda x, s: jax.device_put(x.copy(), s),
                            opt, in_sh[1])
        p_sh, o_sh, m_sh = sharded(p_cp, o_cp, batch)

    np.testing.assert_allclose(float(m_ref["loss"]), float(m_sh["loss"]),
                               rtol=2e-4)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-3, atol=3e-3)
    print("TRAIN_OK")

    # decode parity: sharded serve step vs single-device decode
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
    toks = batch["tokens"][:, :1]
    dec_batch = {"tokens": toks, "cache_pos": jnp.int32(0)}
    ref_logits, _, _ = T.model_apply(params, dec_batch, cfg, mode="decode",
                                     cache=cache, compute_dtype=jnp.float32)
    with set_mesh(mesh):
        cache_sh = jax.eval_shape(lambda: T.init_cache(cfg, B, S,
                                                       dtype=jnp.float32))
        fn, _, in_sh2 = steps.jit_serve_step(
            cfg, mesh, cache_sh,
            {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
             "cache_pos": jax.ShapeDtypeStruct((), jnp.int32)})
        p_put = jax.tree.map(lambda x, s: jax.device_put(x.copy(), s),
                             params, in_sh2[0])
        c_put = jax.tree.map(lambda x, s: jax.device_put(x, s),
                             T.init_cache(cfg, B, S, dtype=jnp.float32),
                             in_sh2[1])
        tok_sh, _ = fn(p_put, c_put, dec_batch)
    ref_tok = jnp.argmax(ref_logits[:, -1], -1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(ref_tok), np.asarray(tok_sh))
    print("DECODE_OK")
""" % SRC)


@pytest.mark.slow
def test_sharded_equals_single_device():
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "TRAIN_OK" in out.stdout and "DECODE_OK" in out.stdout
