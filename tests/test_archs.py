"""Per-assigned-architecture smoke tests: a REDUCED config of the same family
runs one forward + one train step on CPU; output shapes are right and finite.
Also: decode == prefill parity per family, and full-config invariants
(exact dims from the brief)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, cell_applicable
from repro.nn import transformer as T

FULL_DIMS = {  # (layers, d_model, heads, kv, d_ff, vocab) from the brief
    "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
    "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
    "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
    "smollm-360m": (32, 960, 15, 5, 2560, 49152),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_brief(arch):
    cfg = get_config(arch)
    ly, d, h, kv, ff, v = FULL_DIMS[arch]
    assert cfg.n_layers == ly and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab == v


def test_param_counts_in_band():
    """Analytic parameter counts should be near the advertised sizes."""
    bands = {"smollm-360m": (0.3e9, 0.5e9), "mamba2-130m": (0.1e9, 0.2e9),
             "glm4-9b": (8e9, 11e9), "stablelm-12b": (10e9, 14e9),
             "qwen1.5-110b": (95e9, 125e9), "arctic-480b": (380e9, 520e9),
             "qwen3-moe-30b-a3b": (25e9, 36e9), "qwen2-vl-7b": (6e9, 9e9),
             "hymba-1.5b": (1.2e9, 2.2e9), "whisper-large-v3": (1.2e9, 2.2e9)}
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def _smoke_batch(cfg, b=2, s=16):
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros((b, cfg.img_tokens, cfg.d_model),
                                          jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        batch["mrope_positions"] = jnp.broadcast_to(pos[None], (3, b, s))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    logits, _, _ = T.model_apply(params, batch, cfg, mode="train")
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    (loss, aux), grads = jax.value_and_grad(T.lm_loss, has_aux=True)(
        params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_matches_prefill(arch):
    """serve path parity: prefill(s tokens) then decode 3 == forward(s+3)."""
    cfg = get_config(arch).reduced()
    if cfg.family == "encdec":
        pytest.skip("encdec decode exercised in test_whisper_decode below")
    params = T.init_model(jax.random.PRNGKey(1), cfg)
    b, s, extra = 2, 8, 3
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + extra), 0,
                              cfg.vocab)

    batch_full = dict(_smoke_batch(cfg, b, s + extra), tokens=toks)
    batch_full.pop("labels")
    logits_full, _, _ = T.model_apply(
        params, batch_full, cfg, mode="train", compute_dtype=jnp.float32)

    cache = T.init_cache(cfg, b, s + extra, dtype=jnp.float32)
    batch_pre = dict(_smoke_batch(cfg, b, s), tokens=toks[:, :s],
                     cache_pos=jnp.int32(0))
    batch_pre.pop("labels")
    logits, cache, _ = T.model_apply(params, batch_pre, cfg, mode="prefill",
                                     cache=cache, compute_dtype=jnp.float32)
    got = [logits[:, -1]]
    for t in range(s, s + extra - 1):
        bd = {"tokens": toks[:, t:t + 1], "cache_pos": jnp.int32(t)}
        if cfg.family == "vlm":
            pos = jnp.full((b, 1), t)
            bd["mrope_positions"] = jnp.broadcast_to(pos[None], (3, b, 1))
        logits, cache, _ = T.model_apply(params, bd, cfg, mode="decode",
                                         cache=cache,
                                         compute_dtype=jnp.float32)
        got.append(logits[:, -1])
    want = logits_full[:, s - 1:s + extra - 1]
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_whisper_decode():
    """enc-dec: prefill caches cross-KV from the encoder; decode continues."""
    cfg = get_config("whisper-large-v3").reduced()
    params = T.init_model(jax.random.PRNGKey(1), cfg)
    b, s = 2, 8
    batch = _smoke_batch(cfg, b, s)
    batch.pop("labels")
    cache = T.init_cache(cfg, b, s + 2, dtype=jnp.float32)
    logits, cache, _ = T.model_apply(params, dict(batch, cache_pos=jnp.int32(0)),
                                     cfg, mode="prefill", cache=cache,
                                     compute_dtype=jnp.float32)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    bd = {"tokens": jnp.full((b, 1), 3), "cache_pos": jnp.int32(s)}
    logits2, cache, _ = T.model_apply(params, bd, cfg, mode="decode",
                                      cache=cache, compute_dtype=jnp.float32)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())


def test_long500k_applicability_rules():
    runs = [a for a in ARCH_IDS
            if cell_applicable(get_config(a), SHAPES["long_500k"])[0]]
    assert sorted(runs) == ["hymba-1.5b", "mamba2-130m"]


def test_hymba_global_vs_window_layers():
    """Hymba's 3 global layers carry full caches; windowed layers ring-sized."""
    cfg = get_config("hymba-1.5b")
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 2, 4096))
    assert isinstance(cache, list)
    lens = [c["kv"]["k"].shape[2] for c in cache]
    assert lens[0] == 4096 and lens[15] == 4096 and lens[31] == 4096
    assert lens[1] == cfg.sliding_window
