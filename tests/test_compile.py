"""The compile/serve split: backend registry, ExecutionPlan JSON
round-trip, the pass pipeline, multi-bucket engine parity, replica
placement (``replicate_model``), and the retirement of the old
InferenceSession shim (the surface is gone AND the package imports
warning-free).

The exactness standard is inherited from tests/test_infer.py: packed and
reference logits are bit-identical on CPU — including when requests reach
the compiled model through different batch buckets, and when the route
plan was deserialized from JSON or built from autotuned constants."""
import dataclasses
import json
import sys
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spikformer import SpikformerConfig, init, fold_inference_params
from repro.infer import (CompiledModel, ExecutionPlan, MicroBatchEngine,
                         Request, backend_spec, compile as infer_compile,
                         list_backends, quantize_weights, register_backend,
                         replicate_model, unregister_backend)
from repro.infer.compile import fold_bn, plan_route_tables
from repro.kernels.lut_matmul import RouteConstants
from repro.kernels import ops

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "scripts"))


def exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.fixture(scope="module")
def small():
    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(0), cfg)
    img = jax.random.randint(jax.random.PRNGKey(1), (5, 32, 32, 3), 0, 256,
                             jnp.uint8)
    return cfg, params, img


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

def test_builtin_backends_registered():
    assert set(list_backends()) >= {"packed", "reference"}
    spec = backend_spec("reference")
    assert spec.wants_lut_tables is False
    assert backend_spec("float").name == "reference"   # alias resolves


def test_register_backend_and_capability_filtering():
    register_backend("test_f32only", lambda **kw: object(),
                     weight_dtypes=("float32",), device_kinds=("tpu",))
    try:
        assert "test_f32only" in list_backends()
        assert "test_f32only" in list_backends(weight_dtype="float32")
        assert "test_f32only" not in list_backends(weight_dtype="int8")
        assert "test_f32only" not in list_backends(device_kind="cpu")
        assert "test_f32only" in list_backends(device_kind="tpu")
    finally:
        unregister_backend("test_f32only")
    assert "test_f32only" not in list_backends()


def test_register_backend_refuses_silent_shadowing():
    with pytest.raises(ValueError, match="already registered"):
        register_backend("packed", lambda **kw: object())


def test_register_backend_overwrite_takes_over_alias():
    """Overwriting an alias must actually reroute it (and detach it from
    its old owner without removing the owner)."""
    sentinel = object()
    register_backend("float", lambda **kw: sentinel, overwrite=True)
    try:
        from repro.infer import get_backend
        assert get_backend("float") is sentinel
        assert backend_spec("reference").aliases == ()   # owner survives
    finally:
        unregister_backend("float")
        register_backend("reference",
                         backend_spec("reference").factory,
                         weight_dtypes=("float32", "int8"),
                         wants_lut_tables=False, aliases=("float",),
                         overwrite=True)
    assert backend_spec("float").name == "reference"     # restored


def test_packed_pallas_backend_registered_and_compiles(small):
    """The registration path the registry docstring promises, exercised
    end-to-end: "packed_pallas" (alias "pallas") resolves through
    ``compile()`` to a Pallas-pinned PackedBackend, declares TPU device
    kind (enforced: a CPU host needs the interpret escape hatch), and —
    capability-declared — gets REAL (C,256,N) gather tables built into
    its LUT-planned layers: the Pallas byte-LUT kernel consumes them from
    VMEM."""
    cfg, params, _ = small
    spec = backend_spec("packed_pallas")
    assert backend_spec("pallas").name == "packed_pallas"   # alias resolves
    assert spec.device_kinds == ("tpu",)
    assert spec.wants_lut_tables is True
    assert "packed_pallas" in list_backends(device_kind="tpu")
    assert "packed_pallas" not in list_backends(device_kind="cpu")

    model = infer_compile(params, cfg,
                          ExecutionPlan(backend="pallas", batch_buckets=(2,),
                                        backend_options={"interpret": True}))
    assert model.backend.pallas is True
    assert model.plan.routes                   # planning ran
    luts = [p for p, r in model.plan.routes.items() if r == "lut"]
    assert luts                                # pallas cost model picks LUTs
    for path in luts:
        layer = model.folded
        for p in path.split("/"):
            layer = layer[p]
        assert layer["lut"].ndim == 3          # a real table, not a flag
        assert layer["lut"].shape[1] == 256
    # the pin is real: a pallas=False override is rejected at the door
    # (this registration IS the Pallas pin; "packed" is the CPU route)
    with pytest.raises(ValueError, match="pins pallas=True"):
        infer_compile(params, cfg,
                      ExecutionPlan(backend="pallas",
                                    backend_options={"pallas": False,
                                                     "interpret": True}))


def test_pallas_backend_device_gate_names_escape_hatch(small):
    """Asking for the TPU-only backend on this CPU host fails up front,
    naming the backend's device kinds, the available platforms, and the
    ``interpret`` escape hatch — not deep inside a kernel trace."""
    cfg, params, _ = small
    if jax.default_backend() == "tpu":
        pytest.skip("device gate only fires off-TPU")
    with pytest.raises(ValueError) as ei:
        infer_compile(params, cfg, ExecutionPlan(backend="packed_pallas"))
    msg = str(ei.value)
    assert "'packed_pallas'" in msg and "tpu" in msg
    assert jax.default_backend() in msg        # what this host has
    assert "interpret" in msg                  # and the way out


def test_unknown_backend_name_errors(small):
    cfg, params, _ = small
    with pytest.raises(ValueError, match="unknown inference backend"):
        infer_compile(params, cfg, ExecutionPlan(backend="no_such"))


def test_compile_rejects_unsupported_weight_dtype(small):
    cfg, params, _ = small
    register_backend("test_nof32", lambda **kw: object(),
                     weight_dtypes=("int8",))
    try:
        with pytest.raises(ValueError, match="does not support weight_dtype"):
            infer_compile(params, cfg,
                          ExecutionPlan(backend="test_nof32",
                                        weight_dtype="float32"))
    finally:
        unregister_backend("test_nof32")


# ---------------------------------------------------------------------------
# ExecutionPlan: validation + JSON round-trip
# ---------------------------------------------------------------------------

def test_plan_validates_fields():
    with pytest.raises(ValueError, match="route"):
        ExecutionPlan(route="fused")
    with pytest.raises(ValueError, match="weight_dtype"):
        ExecutionPlan(weight_dtype="int4")
    with pytest.raises(ValueError, match="batch_buckets"):
        ExecutionPlan(batch_buckets=())
    # buckets are sorted + deduped; plan_batch is the largest
    p = ExecutionPlan(batch_buckets=(8, 2, 8))
    assert p.batch_buckets == (2, 8) and p.plan_batch == 8


def test_plan_json_roundtrip_identity():
    p = ExecutionPlan(backend="packed", weight_dtype="int8",
                      batch_buckets=(2, 8), max_table_bytes=1 << 20,
                      route_constants=RouteConstants(gather_cost=3.25),
                      routes={"scs/conv0": "lut", "blocks/b0/mlp/fc1":
                              "unpack"})
    q = ExecutionPlan.from_json(p.to_json())
    assert q == p


def test_plan_json_fragment_fills_defaults():
    q = ExecutionPlan.from_json(json.dumps(
        {"route_constants": {"gather_cost": 2.0}}))
    assert q.route_constants.gather_cost == 2.0
    assert q.route_constants.transpose_cost == \
        RouteConstants().transpose_cost
    assert q.backend == "packed" and q.batch_buckets == (8,)


def test_plan_json_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown ExecutionPlan keys"):
        ExecutionPlan.from_json('{"batch_size": 8}')
    with pytest.raises(ValueError, match="route-constant keys"):
        ExecutionPlan.from_json('{"route_constants": {"gatherr": 1.0}}')


def test_compiled_plan_roundtrip_reproduces_route_plan(small):
    """The acceptance property: serialize the resolved plan, recompile from
    JSON, get the identical per-layer route plan AND identical logits."""
    cfg, params, img = small
    m1 = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    assert m1.plan.routes                      # resolved, non-empty
    m2 = infer_compile(params, cfg, ExecutionPlan.from_json(m1.plan.to_json()))
    assert m2.plan.routes == m1.plan.routes
    exact(m1.logits(img), m2.logits(img))


def test_pallas_plan_json_roundtrip_replays_pinned_routes(small):
    """A pallas-compiled plan is a committable artifact: its JSON
    round-trips with the routes pinned, recompiling from it replays the
    same per-layer routes through the Pallas kernels with bit-identical
    logits — and the same plan stripped of its ``interpret`` escape hatch
    fails loudly on a host without the backend's device, instead of
    quietly serving through some other backend."""
    cfg, params, img = small
    cfg = dataclasses.replace(cfg, depth=1)
    params1 = init(jax.random.PRNGKey(0), cfg)
    m1 = infer_compile(params1, cfg,
                       ExecutionPlan(backend="packed_pallas",
                                     batch_buckets=(2,),
                                     backend_options={"interpret": True}))
    plan2 = ExecutionPlan.from_json(m1.plan.to_json())
    assert plan2.backend == "packed_pallas"
    assert plan2.routes == m1.plan.routes and plan2.routes
    m2 = infer_compile(params1, cfg, plan2)
    assert m2.plan.routes == m1.plan.routes    # replayed, not re-derived
    exact(m1.logits(img[:2]), m2.logits(img[:2]))
    if jax.default_backend() != "tpu":
        bare = dataclasses.replace(plan2, backend_options={})
        with pytest.raises(ValueError, match="interpret"):
            infer_compile(params1, cfg, bare)


def test_pinned_routes_reject_foreign_config(small):
    """A deserialized plan for a different architecture must fail loudly,
    not plan a fresh heuristic."""
    cfg, params, _ = small
    m1 = infer_compile(params, cfg)
    deep = dataclasses.replace(cfg, depth=3)
    params3 = init(jax.random.PRNGKey(0), deep)
    with pytest.raises(ValueError, match="no entry for layer"):
        infer_compile(params3, deep,
                      dataclasses.replace(m1.plan, batch_buckets=(8,)))


# ---------------------------------------------------------------------------
# pass pipeline in isolation
# ---------------------------------------------------------------------------

def test_quantize_weights_pass(small):
    cfg, params, _ = small
    tree = fold_bn(params, cfg)
    t8, d8 = quantize_weights(tree, "int8")
    assert d8 == "int8" and "scale" in t8["scs"]["conv0"]
    # None resolves from the tree
    _, dN = quantize_weights(t8, None)
    assert dN == "int8"
    _, dF = quantize_weights(tree, None)
    assert dF == "float32"
    with pytest.raises(ValueError, match="already int8-quantized"):
        quantize_weights(t8, "float32")


def test_plan_route_tables_pinned_replay(small):
    """plan_route_tables under pinned routes applies them verbatim —
    including a deliberately non-heuristic choice."""
    cfg, params, _ = small
    tree = fold_bn(params, cfg)
    _, auto = plan_route_tables(tree, cfg, batch_size=8)
    flipped = {p: ("unpack" if r == "lut" else r) for p, r in auto.items()}
    t2, replay = plan_route_tables(tree, cfg, batch_size=8, routes=flipped)
    assert replay == flipped
    assert all("lut" not in t2["scs"][n] for n in t2["scs"])


def test_route_constants_change_decisions():
    """The constants are real plan inputs: an absurd gather cost flips every
    borderline shape to unpack."""
    expensive = RouteConstants(gather_cost=1e9)
    for m, k, n in [(32, 64, 256), (512, 32, 16), (2048, 12, 8)]:
        assert ops.choose_route(m=m, k=k, n=n, g=1, t=4) == "lut"
        assert ops.choose_route(m=m, k=k, n=n, g=1, t=4,
                                constants=expensive) == "unpack"


# ---------------------------------------------------------------------------
# multi-bucket CompiledModel + engine parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,weight_dtype", [(4, "float32"), (4, "int8"),
                                            (16, "float32"), (16, "int8")])
def test_compile_packed_matches_reference_across_buckets(small, t,
                                                         weight_dtype):
    """The acceptance sweep through the new API: packed == reference
    bit-for-bit, with requests served through DIFFERENT buckets."""
    cfg, params, img = small
    cfg = dataclasses.replace(cfg, timesteps=t)
    plan = ExecutionPlan(weight_dtype=weight_dtype, batch_buckets=(2, 8))
    packed = infer_compile(params, cfg, plan, backend="packed")
    ref = infer_compile(params, cfg, plan, backend="reference")
    lp = packed.logits(img)                    # 5 rows -> 2+2+2-pad steps
    exact(lp, ref.logits(img))
    # bucket invariance: the same image through the 2-bucket and the
    # 8-bucket produces identical rows
    big = jnp.concatenate([img, img[:3]])      # 8 rows -> one 8-bucket step
    exact(packed.logits(big)[:5], lp)
    eng = MicroBatchEngine(packed)
    eng.submit(np.asarray(img[:2]))            # backlog 2 -> bucket 2
    eng.run()
    eng.submit(np.asarray(big))                # backlog 8 -> bucket 8
    done = sorted(eng.run(), key=lambda r: r.rid)
    assert {eng.pick_bucket(2), eng.pick_bucket(8)} == {2, 8}
    want = np.asarray(packed.classify(big)).tolist()
    assert [int(x) for x in done[0].labels] == want[:2]
    assert [int(x) for x in done[1].labels] == want


def test_compiled_step_rejects_non_bucket_batch(small):
    cfg, params, img = small
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    with pytest.raises(ValueError, match="not a compiled bucket"):
        model.step(np.asarray(img)[:3])


def test_engine_pad_waste_accounting(small):
    """Multi-bucket dispatch cuts pad waste, and the engine reports it:
    3 images over buckets (2, 8) pad 3->8 single-bucket but 2+1->2+2
    multi-bucket."""
    cfg, params, img = small
    imgs = np.asarray(img)[:3]
    single = MicroBatchEngine(
        infer_compile(params, cfg, ExecutionPlan(batch_buckets=(8,))))
    multi = MicroBatchEngine(
        infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8))))
    for eng in (single, multi):
        for i in range(3):                     # one image per request
            eng.submit(imgs[i:i + 1])
        eng.run()
    assert single.total_rows == 8 and single.padded_rows == 5
    assert multi.total_rows == 4 and multi.padded_rows == 1
    assert multi.pad_waste < single.pad_waste
    s = multi.stats()
    assert s["pad_waste"] == 0.25 and s["padded_rows"] == 1
    assert s["images"] == 3 and s["requests"] == 3
    assert s["latency_p95_s"] is not None


def test_engine_rejects_inflight_rid_and_completes_empty(small):
    cfg, params, img = small
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2,)))
    eng = MicroBatchEngine(model)
    imgs = np.asarray(img)
    eng.submit(Request(rid=0, images=imgs[:2]))
    with pytest.raises(ValueError, match="already in flight"):
        eng.submit(Request(rid=0, images=imgs[2:]))
    eng.run()
    eng.submit(Request(rid=0, images=imgs[:2]))   # completed rid reusable
    # a zero-image request completes immediately, with no queue entry
    empty = eng.submit(imgs[:0])
    assert empty in eng.done and empty.labels == []
    done = eng.run()
    assert eng.stats()["requests"] == len(done) == 3


def test_engine_mixed_requests_match_direct_classify(small):
    cfg, params, img = small
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2, 4)))
    eng = MicroBatchEngine(model)
    imgs = np.asarray(img)
    eng.submit(Request(rid=0, images=imgs[:3]))
    eng.submit(Request(rid=1, images=imgs[3:]))
    done = sorted(eng.run(), key=lambda r: r.rid)
    got = [lab for r in done for lab in r.labels]
    assert got == np.asarray(model.classify(imgs)).tolist()


# ---------------------------------------------------------------------------
# autotuned constants, end to end
# ---------------------------------------------------------------------------

def test_autotune_fit_and_plan_accepted_end_to_end(small):
    """fit_constants on synthetic timings (generated FROM a known cost
    model) recovers constants that reproduce its decisions, and the
    resulting ExecutionPlan compiles and serves bit-exactly."""
    from autotune_routes import fit_constants

    true = RouteConstants(gather_cost=6.0, transpose_cost=1.5,
                          unpack_cost=12.0)
    alpha = 1e-9                                # seconds per FMA
    samples = []
    for m, k, n, g in [(64, 32, 16, 1), (256, 64, 64, 1), (512, 32, 32, 1),
                       (1024, 64, 32, 2), (2048, 32, 16, 1),
                       (256, 128, 128, 1)]:
        t = 8 * g
        c = -(-k // 8)
        samples.append({
            "m": m, "k": k, "n": n, "g": g, "t": t, "c": c,
            "table_bytes": 32 * k * n,
            "unpack_s": alpha * t * m * k * (n + true.unpack_cost),
            "lut_s": alpha * (t * m * c * n * true.gather_cost
                              + g * m * k * true.transpose_cost),
        })
    fitted = fit_constants(samples)
    assert fitted.gather_cost == pytest.approx(true.gather_cost, rel=0.05)
    assert fitted.unpack_cost == pytest.approx(true.unpack_cost, rel=0.15)

    cfg, params, img = small
    plan = ExecutionPlan.from_json(json.dumps(
        {"route_constants": fitted.to_dict(), "batch_buckets": [2, 8]}))
    packed = infer_compile(params, cfg, plan, backend="packed")
    ref = infer_compile(params, cfg, plan, backend="reference")
    exact(packed.logits(img), ref.logits(img))


# ---------------------------------------------------------------------------
# the shim is gone: the old name is unimportable and nothing in the
# package warms up with a DeprecationWarning
# ---------------------------------------------------------------------------

def test_session_shim_removed():
    with pytest.raises(ImportError):
        from repro.infer import InferenceSession  # noqa: F401
    assert not (pathlib.Path(__file__).resolve().parent.parent
                / "src/repro/infer/session.py").exists()


def test_infer_package_compiles_without_deprecation_warnings(small):
    cfg, params, img = small
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", DeprecationWarning)
        model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2,)))
        model.classify(img)


# ---------------------------------------------------------------------------
# replica placement
# ---------------------------------------------------------------------------

def test_replicate_model_shares_plan_and_math(small):
    cfg, params, img = small
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2,)))
    twin = replicate_model(model)
    # thread-backed replica: same resolved plan and folded tree verbatim,
    # same jitted step (no recompile for a same-device copy)
    assert twin.plan is model.plan
    assert twin.folded is model.folded
    assert twin._fwd is model._fwd
    exact(twin.logits(img), model.logits(img))


def test_replicate_model_onto_device_recompiles_bit_exact(small):
    cfg, params, img = small
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2,)))
    dev = jax.devices()[0]
    placed = replicate_model(model, device=dev)
    assert placed.plan is model.plan
    assert placed._fwd is not model._fwd    # per-device executable
    exact(placed.logits(img), model.logits(img))


def test_replicate_model_preserves_jit_choice(small):
    """A jit=False template replicates to jit=False steps — a replica must
    behave like the model it replicates, on or off device."""
    cfg, params, img = small
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2,)),
                          jit=False)
    assert model.jit is False
    twin = replicate_model(model)
    placed = replicate_model(model, device=jax.devices()[0])
    assert twin.jit is False and placed.jit is False
    exact(placed.logits(img[:2]), model.logits(img[:2]))
