"""Data pipeline determinism/restart + checkpointer atomicity/elasticity."""
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (DataConfig, DataPipeline, synthetic_lm_batch,
                                 image_batch, TokenFileSource)
from repro.checkpoint.checkpointer import Checkpointer


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_deterministic():
    cfg = DataConfig(seq=32, global_batch=4, vocab=100, seed=7)
    a = synthetic_lm_batch(cfg, step=3)
    b = synthetic_lm_batch(cfg, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_lm_batch(cfg, step=4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(seq=32, global_batch=2, vocab=100)
    b = synthetic_lm_batch(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_disjoint_and_consistent():
    """2 hosts each produce half the global batch; together they equal the
    1-host global batch (elastic data semantics)."""
    g = DataConfig(seq=16, global_batch=4, vocab=50, seed=1)
    h0 = DataConfig(seq=16, global_batch=4, vocab=50, seed=1, host_id=0,
                    n_hosts=2)
    h1 = DataConfig(seq=16, global_batch=4, vocab=50, seed=1, host_id=1,
                    n_hosts=2)
    full = synthetic_lm_batch(g, 5)["tokens"]
    part0 = synthetic_lm_batch(h0, 5)["tokens"]
    part1 = synthetic_lm_batch(h1, 5)["tokens"]
    np.testing.assert_array_equal(np.concatenate([part0, part1]), full)


def test_pipeline_restart_exact():
    cfg = DataConfig(seq=16, global_batch=2, vocab=64, seed=3, prefetch=1)
    p = DataPipeline(cfg)
    seen = [next(p) for _ in range(5)]
    state = p.state_dict()
    nxt = next(p)
    p.close()

    q = DataPipeline.restore(cfg, state)
    resumed = next(q)
    q.close()
    np.testing.assert_array_equal(np.asarray(nxt["tokens"]),
                                  np.asarray(resumed["tokens"]))


def test_token_file_source(tmp_path):
    path = tmp_path / "toks.bin"
    np.arange(1000, dtype=np.uint32).tofile(path)
    cfg = DataConfig(seq=9, global_batch=2, kind="token_file", path=str(path))
    src = TokenFileSource(cfg)
    b = src.batch(0)
    assert b["tokens"].shape == (2, 9)
    np.testing.assert_array_equal(b["tokens"][0], np.arange(9))
    np.testing.assert_array_equal(b["labels"][0], np.arange(1, 10))


def test_image_batch_learnable_structure():
    cfg = DataConfig(global_batch=8, kind="images", image_size=16, n_classes=4)
    b = image_batch(cfg, 0)
    assert b["image"].shape == (8, 16, 16, 3) and b["image"].dtype == np.uint8
    assert set(np.unique(b["label"])) <= set(range(4))


# ---------------------------------------------------------------------------
# checkpointer
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (4, 4)),
                      "b": jnp.zeros((4,))},
            "step_count": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(10, tree, extra={"data": {"step": 10, "seed": 0}}, block=True)
    assert ck.latest_step() == 10
    skel = jax.eval_shape(lambda: tree)
    got, extra = ck.restore(skeleton=skel)
    np.testing.assert_allclose(np.asarray(got["layer"]["w"]),
                               np.asarray(tree["layer"]["w"]))
    assert extra["data"]["step"] == 10


def test_atomicity_tmp_dirs_invisible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    # a crashed half-write: tmp dir with files but no commit rename
    bad = tmp_path / "step_00000099.tmp"
    bad.mkdir()
    (bad / "x.npy").write_bytes(b"junk")
    # and a dir missing its manifest
    bad2 = tmp_path / "step_00000098"
    bad2.mkdir()
    assert ck.latest_step() is None
    ck.save(5, _tree(), block=True)
    assert ck.latest_step() == 5


def test_gc_keeps_newest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(), block=True)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checksum_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), block=True)
    # corrupt one leaf
    f = next((tmp_path / "step_00000001").glob("layer.w.npy"))
    arr = np.load(f)
    arr[0, 0] += 1.0
    np.save(f, arr)
    with pytest.raises(IOError, match="checksum"):
        ck.restore(skeleton=jax.eval_shape(_tree))


def test_async_save_does_not_block(tmp_path):
    ck = Checkpointer(str(tmp_path))
    big = {"w": jnp.zeros((2000, 2000))}
    t0 = time.time()
    ck.save(1, big)
    t_return = time.time() - t0
    ck.wait()
    assert t_return < 1.0
    assert ck.latest_step() == 1


def test_elastic_restore_onto_mesh(tmp_path):
    """Save unsharded, restore with explicit NamedShardings for a 1-device
    mesh (the elastic path: same call works for any target device count)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(2, tree, block=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: tree))
    got, _ = ck.restore(skeleton=jax.eval_shape(lambda: tree), shardings=sh)
    assert got["layer"]["w"].sharding == NamedSharding(mesh, P())
