"""The async continuous-batching serving runtime (``repro.serve``).

Three layers, three standards of proof:

* the SCHEDULER is pure — its full wait-vs-dispatch decision table is
  pinned under an injected clock, no threads, no sleeps;
* the RUNTIME is checked against the sync engine: an identical request
  trace must produce bit-identical labels through ``MicroBatchEngine``
  and ``AsyncServeRuntime`` (per-image math is row-independent and
  bucket-invariant, so batching happenstance cannot leak into labels);
* the LOADGEN is deterministic from its seed and measures the open-loop
  contract: every accepted request completes (zero dropped);
* the FLEET is held to all three at once: placement decisions replay
  from a pinned table through the pure ``FleetScheduler``, an identical
  trace through 1 and N replicas yields bit-identical labels, the
  lifecycle (warmup/probe/drain/swap) never drops an accepted request,
  and all three serving surfaces satisfy the one ``ServeClient``
  protocol with the shared versioned stats schema.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.spikformer import SpikformerConfig, init
from repro.infer import (ExecutionPlan, MicroBatchEngine, SERVE_STATS_VERSION,
                         ServeClient, compile as infer_compile)
from repro.infer.compile import plan_chunks
from repro.infer.engine import (StepAccounting, assemble_batch,
                                latency_summary, validate_images)
from repro.serve import (Arrival, AsyncServeRuntime, burst_trace, burstiness,
                         ContinuousBatchingScheduler, FleetScheduler,
                         QueueFull, ServeFleet, ServePolicy, image_maker,
                         poisson_trace, replay_decisions, run_open_loop,
                         run_replica_sweep, validate_trace)


def exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.fixture(scope="module")
def small():
    cfg = SpikformerConfig().scaled(img_size=16, dim=32, depth=1)
    params = init(jax.random.PRNGKey(0), cfg)
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    model.warmup()
    imgs = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (11, 16, 16, 3), 0, 256, "uint8"))
    return cfg, model, imgs


# ---------------------------------------------------------------------------
# scheduler: the pinned decision table (pure, injected clock)
# ---------------------------------------------------------------------------

def sched(max_wait_ms=10.0, slo_ms=None, depth=512, buckets=(2, 8)):
    return ContinuousBatchingScheduler(
        buckets, ServePolicy(max_wait_ms=max_wait_ms, slo_ms=slo_ms,
                             max_queue_images=depth))


def test_decision_table_wait_vs_dispatch():
    s = sched(max_wait_ms=10.0)
    # empty queue: idle (sleep until a submit)
    assert s.decide(backlog=0, oldest_submit_s=None, now_s=5.0).action == \
        "idle"
    # a full largest bucket never waits
    d = s.decide(backlog=8, oldest_submit_s=0.0, now_s=0.0)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 8, 8)
    d = s.decide(backlog=9, oldest_submit_s=0.0, now_s=0.0)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 8, 8)
    # partial backlog inside the window: wait EXACTLY until the deadline
    d = s.decide(backlog=3, oldest_submit_s=1.0, now_s=1.004)
    assert d.action == "wait"
    assert d.wait_s == pytest.approx(0.006)
    # at the deadline: dispatch the FIRST chunk of the pad-minimizing
    # split — 3 over (2, 8) runs 2 now, leaves 1 accumulating
    d = s.decide(backlog=3, oldest_submit_s=1.0, now_s=1.010)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 2, 2)
    assert d.reason == "max_wait deadline reached"


def test_decision_table_tail_smaller_than_smallest_bucket():
    s = sched(max_wait_ms=10.0)
    # backlog 1 < smallest bucket 2: waits its window, then dispatches
    # padded into the smallest bucket
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0)
    assert d.action == "wait" and d.wait_s == pytest.approx(0.010)
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.011)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 2, 1)


def test_decision_table_slo_pressure_closes_window_early():
    s = sched(max_wait_ms=50.0, slo_ms=30.0)
    # no observed step times: SLO deadline = submit + slo (estimate 0),
    # tighter than max_wait
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0)
    assert d.action == "wait" and d.wait_s == pytest.approx(0.030)
    # an observed 20ms step shrinks the budget: dispatch by 30-20=10ms
    s.observe_step(2, 0.020)
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0)
    assert d.action == "wait" and d.wait_s == pytest.approx(0.010)
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0105)
    assert d.action == "dispatch" and d.reason == "SLO pressure"
    # EWMA: a faster step moves the estimate, deterministically
    s.observe_step(2, 0.010)
    assert s.service_estimate(2) == pytest.approx(0.8 * 0.020 + 0.2 * 0.010)
    # unknown bucket: conservative (slowest observed)
    assert s.service_estimate(8) == s.service_estimate(2)


def test_decision_table_draining_dispatches_immediately():
    s = sched(max_wait_ms=10_000.0)
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0, draining=True)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 2, 1)
    assert d.reason == "draining"
    assert s.decide(backlog=0, oldest_submit_s=None, now_s=0.0,
                    draining=True).action == "idle"


def test_scheduler_admission_bound():
    s = sched(depth=4)
    assert s.admit(0, 4) and s.admit(3, 1)
    assert not s.admit(3, 2) and not s.admit(0, 5)
    with pytest.raises(ValueError, match="max_queue_images"):
        ServePolicy(max_queue_images=0)
    with pytest.raises(ValueError, match="slo_ms"):
        ServePolicy(slo_ms=0)
    with pytest.raises(ValueError, match="max_wait_ms"):
        ServePolicy(max_wait_ms=-1)


def test_scheduler_reuses_model_plan_chunks(small):
    """The scheduler's dispatch shape IS the model's pad-minimizing split —
    same function, not a copy."""
    _, model, _ = small
    for n in range(1, 20):
        assert plan_chunks(n, model.buckets) == model.plan_chunks(n)
    s = sched()
    for backlog in range(1, 8):
        d = s.decide(backlog=backlog, oldest_submit_s=0.0, now_s=1.0)
        assert (d.rows, d.bucket) == model.plan_chunks(backlog)[0]


# ---------------------------------------------------------------------------
# shared serve plumbing (engine.py): validation, assembly, accounting
# ---------------------------------------------------------------------------

def test_validate_images_shape_and_dtype():
    ok = validate_images(np.zeros((2, 16, 16, 3), np.uint8), (16, 16, 3))
    assert ok.shape == (2, 16, 16, 3) and ok.dtype == np.uint8
    # int32 in range casts; out of range refuses
    assert validate_images(np.full((1, 16, 16, 3), 255, np.int32),
                           (16, 16, 3)).dtype == np.uint8
    with pytest.raises(ValueError, match=r"outside \[0, 255\]"):
        validate_images(np.full((1, 16, 16, 3), 256, np.int32), (16, 16, 3))
    # the error NAMES the expected per-image shape
    with pytest.raises(ValueError, match=r"\(n, 16, 16, 3\)"):
        validate_images(np.zeros((2, 8, 8, 3), np.uint8), (16, 16, 3))
    with pytest.raises(ValueError, match="expected uint8"):
        validate_images(np.zeros((2, 16, 16, 3), np.float32), (16, 16, 3))
    # a single unbatched image is not silently promoted
    with pytest.raises(ValueError, match=r"\(16, 16, 3\)"):
        validate_images(np.zeros((16, 16, 3), np.uint8), (16, 16, 3))


def test_engine_submit_door_validation(small):
    _, model, imgs = small
    eng = MicroBatchEngine(model)
    with pytest.raises(ValueError, match=r"\(n, 16, 16, 3\)"):
        eng.submit(np.zeros((1, 8, 8, 3), np.uint8))
    with pytest.raises(ValueError, match="dtype"):
        eng.submit(imgs[:1].astype(np.float32))
    assert not eng.queue                  # nothing half-queued


def test_assemble_batch_and_accounting():
    batch, pad = assemble_batch([np.ones((4, 4), np.uint8)] * 3, 8)
    assert batch.shape == (8, 4, 4) and pad == 5
    assert batch[:3].all() and not batch[3:].any()
    batch, pad = assemble_batch([np.ones((4, 4), np.uint8)] * 2, 2)
    assert batch.shape == (2, 4, 4) and pad == 0
    acct = StepAccounting()
    acct.record_step(rows=3, bucket=8, busy_s=0.5, wall_s=1.0)
    acct.record_step(rows=2, bucket=2, busy_s=0.25, wall_s=0.5)
    assert acct.batches == 2 and acct.images == 5
    assert acct.padded_rows == 5 and acct.total_rows == 10
    assert acct.pad_waste == 0.5
    assert acct.fps == pytest.approx(5 / 1.5)
    assert latency_summary([])["latency_p99_s"] is None
    s = latency_summary([0.1] * 99 + [1.0])
    assert s["latency_p50_s"] == 0.1 and s["latency_p99_s"] > 0.1


# ---------------------------------------------------------------------------
# runtime: sync/async parity and the edge-case contract
# ---------------------------------------------------------------------------

def trace_requests(imgs):
    """A fixed mixed-size request trace over the fixture images."""
    sizes = (2, 1, 3, 1, 2, 2)
    out, i = [], 0
    for n in sizes:
        out.append(imgs[i:i + n])
        i += n
    return out


def test_identical_trace_sync_async_bit_identical_labels(small):
    """The acceptance property: the SAME request trace through the sync
    engine and the async runtime yields bit-identical labels, and both
    match direct classify()."""
    _, model, imgs = small
    reqs = trace_requests(imgs)
    eng = MicroBatchEngine(model)
    for r in reqs:
        eng.submit(r)
    sync_done = sorted(eng.run(), key=lambda r: r.rid)
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        handles = [rt.submit(r) for r in reqs]
        async_labels = [h.result(timeout=30) for h in handles]
    assert [r.labels for r in sync_done] == async_labels
    want = np.asarray(model.classify(imgs)).tolist()
    flat = [lab for labs in async_labels for lab in labs]
    assert flat == want[:len(flat)]


def test_async_empty_request_completes_via_future(small):
    _, model, imgs = small
    with AsyncServeRuntime(model) as rt:
        req = rt.submit(imgs[:0])
        assert req.result(timeout=5) == []
        assert req.t_done == req.t_submit
        assert rt.stats()["requests"] == 1


def test_async_rid_reuse_and_inflight_rejection(small):
    _, model, imgs = small
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=10_000.0)) as rt:
        first = rt.submit(imgs[:2], rid=7)     # fills bucket 2: dispatches
        assert first.result(timeout=30) is not None
        second = rt.submit(imgs[2:3], rid=7)   # completed rid is reusable
        # 1 image < smallest bucket + huge window: still in flight
        with pytest.raises(ValueError, match="already in flight"):
            rt.submit(imgs[3:4], rid=7)
    # close() drained: the in-flight request completed, not abandoned
    assert second.result(timeout=1) == second.labels
    assert len(second.labels) == 1
    with pytest.raises(RuntimeError, match="closed"):
        rt.submit(imgs[:1])


def test_async_queue_full_rejection_is_explicit(small):
    _, model, imgs = small
    policy = ServePolicy(max_wait_ms=10_000.0, max_queue_images=3)
    with AsyncServeRuntime(model, policy=policy) as rt:
        kept = [rt.submit(imgs[i:i + 1]) for i in range(3)]
        with pytest.raises(QueueFull, match="max_queue_images=3"):
            rt.submit(imgs[3:4])
        assert rt.stats()["requests_rejected"] == 1
    # every ACCEPTED request still completed on drain
    assert all(len(k.result(timeout=1)) == 1 for k in kept)


def test_async_tail_smaller_than_smallest_bucket_pads(small):
    """A lone request below the smallest bucket is not starved: the window
    closes and it ships padded."""
    _, model, imgs = small
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=1.0)) as rt:
        req = rt.submit(imgs[:1])
        assert len(req.result(timeout=30)) == 1
        stats = rt.stats()
    assert stats["padded_rows"] == 1 and stats["total_rows"] == 2
    assert req.labels == np.asarray(model.classify(imgs[:1])).tolist()


def test_async_submit_door_validation_rejects_before_queueing(small):
    _, model, imgs = small
    with AsyncServeRuntime(model) as rt:
        with pytest.raises(ValueError, match=r"\(n, 16, 16, 3\)"):
            rt.submit(np.zeros((1, 8, 8, 3), np.uint8))
        with pytest.raises(ValueError, match="dtype"):
            rt.submit(imgs[:1].astype(np.float64))
        assert rt.stats()["queued_images"] == 0


def test_async_streaming_callback_per_image(small):
    _, model, imgs = small
    got, lock = [], threading.Lock()

    def on_image(rid, idx, label):
        with lock:
            got.append((rid, idx, label))

    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        req = rt.submit(imgs[:3], rid=0, on_image=on_image)
        labels = req.result(timeout=30)
    assert sorted(got) == [(0, i, labels[i]) for i in range(3)]


def test_async_streaming_callback_exception_does_not_kill_worker(small):
    """A raising user callback must not wedge the runtime: the future
    still resolves and later requests still serve."""
    _, model, imgs = small

    def bad(rid, idx, label):
        raise RuntimeError("user callback bug")

    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        r1 = rt.submit(imgs[:2], on_image=bad)
        assert len(r1.result(timeout=30)) == 2
        r2 = rt.submit(imgs[2:4])
        assert len(r2.result(timeout=30)) == 2
    assert rt.stats()["requests"] == 2


class FlakyModel:
    """CompiledModel stand-in whose step fails on demand — small enough to
    pin the runtime's failure semantics without a real compile."""
    buckets = (2,)

    def __init__(self):
        self.fail_next = 0

    def input_shape(self, bucket=None):
        return (2, 4, 4, 3)

    def step(self, batch):
        if self.fail_next:
            self.fail_next -= 1
            raise RuntimeError("step boom")
        return np.zeros((len(batch), 10), np.float32)


def test_async_step_failure_fails_that_batch_not_the_runtime():
    """A failing model step resolves the affected futures with the error
    (never a silent forever-block) and serving continues."""
    model = FlakyModel()
    model.fail_next = 1
    imgs = np.zeros((2, 4, 4, 3), np.uint8)
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        bad = rt.submit(imgs)
        with pytest.raises(RuntimeError, match="step boom"):
            bad.result(timeout=10)
        ok = rt.submit(imgs)                  # the worker survived
        assert ok.result(timeout=10) == [0, 0]
        stats = rt.stats()
    assert stats["requests_failed"] == 1 and stats["requests"] == 1


def test_async_submits_from_many_threads(small):
    """The bounded queue really is thread-safe: concurrent submitters, all
    futures complete, labels match the single-threaded classify()."""
    _, model, imgs = small
    want = np.asarray(model.classify(imgs)).tolist()
    results = {}
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        def worker(i):
            results[i] = rt.submit(imgs[i:i + 1], rid=i).result(timeout=30)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert {i: labs[0] for i, labs in results.items()} == \
        {i: want[i] for i in range(8)}


# ---------------------------------------------------------------------------
# loadgen: deterministic traces, open-loop metrics
# ---------------------------------------------------------------------------

def test_poisson_trace_deterministic_and_bounded():
    a = poisson_trace(rps=100, duration_s=1.0, seed=3,
                      images_per_request=(1, 3))
    b = poisson_trace(rps=100, duration_s=1.0, seed=3,
                      images_per_request=(1, 3))
    assert a == b and len(a) > 20
    assert all(0 < x.t_s < 1.0 and 1 <= x.n_images <= 3 for x in a)
    assert [x.t_s for x in a] == sorted(x.t_s for x in a)
    assert a != poisson_trace(rps=100, duration_s=1.0, seed=4,
                              images_per_request=(1, 3))
    with pytest.raises(ValueError, match="rps"):
        poisson_trace(rps=0, duration_s=1.0, seed=0)


def test_validate_trace_fails_loud():
    # non-monotonic timestamps: a loud ValueError naming the index — the
    # replay contract depends on arrival order, so never a silent sort
    with pytest.raises(ValueError, match="arrival 2 .* precedes"):
        validate_trace([Arrival(0.1, 1), Arrival(0.2, 1), Arrival(0.15, 1)])
    with pytest.raises(ValueError, match="n_images"):
        validate_trace([Arrival(0.1, 0)])
    with pytest.raises(ValueError, match="arrival 0"):
        validate_trace([Arrival(-0.1, 1)])
    # any sorted iterable works, including a generator
    got = validate_trace(Arrival(0.01 * k, 1) for k in range(5))
    assert len(got) == 5


def test_open_loop_rejects_unsorted_trace(small):
    _, model, _ = small
    bad = [Arrival(0.2, 1), Arrival(0.1, 1)]
    with AsyncServeRuntime(model, policy=ServePolicy()) as rt:
        with pytest.raises(ValueError, match="sorted"):
            run_open_loop(rt, bad, image_maker(model.input_shape()[1:],
                                               seed=0), slo_ms=100.0)


def test_burst_trace_deterministic_and_bursty():
    kw = dict(rps_on=200.0, on_s=0.1, off_s=0.3, duration_s=2.0, seed=7)
    a, b = burst_trace(**kw), burst_trace(**kw)
    assert a == b and len(a) > 10
    assert [x.t_s for x in a] == sorted(x.t_s for x in a)
    # every arrival lands inside an ON phase (OFF draws are discarded)
    assert all((x.t_s % 0.4) < 0.1 for x in a)
    # ON/OFF traffic disperses far above Poisson at the same mean rate
    mean_rps = len(a) / 2.0
    pois = poisson_trace(rps=mean_rps, duration_s=2.0, seed=7)
    d_burst = burstiness(a)["dispersion_index"]
    d_pois = burstiness(pois)["dispersion_index"]
    assert d_burst > 2.0 > d_pois
    assert burstiness(a)["peak_to_mean_rate"] > 1.5
    with pytest.raises(ValueError, match="rps_on"):
        burst_trace(rps_on=0, on_s=0.1, off_s=0.1, duration_s=1.0, seed=0)


def test_burstiness_degenerate_traces():
    assert burstiness([]) == {"dispersion_index": None,
                              "peak_to_mean_rate": None}
    # one window only: no variance to speak of
    assert burstiness([Arrival(0.01, 1)])["dispersion_index"] is None


def test_open_loop_metrics_carry_burstiness(small):
    _, model, _ = small
    trace = poisson_trace(rps=100, duration_s=0.5, seed=2)
    eng = MicroBatchEngine(model)
    m = run_open_loop(eng, trace, image_maker(model.input_shape()[1:],
                                              seed=3), slo_ms=10_000.0)
    assert m["dispersion_index"] is not None
    assert m["peak_to_mean_rate"] >= 1.0


def test_replay_decisions_bursty_shed_and_recovery():
    """The decision-table replay contract under ON/OFF traffic: the same
    trace + policy + service model produce the IDENTICAL table, the burst
    peak sheds (QueueFull) against the admission bound, and the queue
    recovers — every admitted image leaves the table."""
    trace = burst_trace(rps_on=400.0, on_s=0.05, off_s=0.2,
                        duration_s=0.5, seed=11)

    def table():
        return replay_decisions(trace, sched(max_wait_ms=5.0, depth=6),
                                service_s={2: 0.02, 8: 0.05})

    t1, t2 = table(), table()
    assert t1 == t2 and t1
    rejects = [r for r in t1 if r["event"] == "reject"]
    dispatches = [r for r in t1 if r["event"] == "dispatch"]
    assert rejects, "burst peak must shed against depth 6"
    assert len(rejects) < len(trace), "recovery: not everything sheds"
    # sheds happen at the bound, never beyond it
    assert all(r["backlog"] + r["images"] > 6 for r in rejects)
    # conservation: every admitted image is dispatched exactly once
    admitted = (sum(a.n_images for a in trace)
                - sum(r["images"] for r in rejects))
    assert sum(d["rows"] for d in dispatches) == admitted
    assert t1[-1]["event"] == "dispatch" and t1[-1]["backlog"] == 0


def test_replay_decisions_fleet_uses_both_replicas():
    trace = burst_trace(rps_on=400.0, on_s=0.05, off_s=0.2,
                        duration_s=0.5, seed=11)

    def table():
        s = fleet_sched(n=2, max_wait_ms=5.0, max_queue_images=6)
        return replay_decisions(trace, s, service_s={2: 0.02, 8: 0.05})

    t1, t2 = table(), table()
    assert t1 == t2
    dispatches = [r for r in t1 if r["event"] == "dispatch"]
    assert {d["replica"] for d in dispatches} == {0, 1}
    # two modeled workers drain the same bursts with fewer sheds than one
    one = replay_decisions(trace, sched(max_wait_ms=5.0, depth=6),
                           service_s={2: 0.02, 8: 0.05})
    sheds = sum(r["images"] for r in t1 if r["event"] == "reject")
    sheds_one = sum(r["images"] for r in one if r["event"] == "reject")
    assert sheds < sheds_one


def test_replay_decisions_validates_trace():
    with pytest.raises(ValueError, match="sorted"):
        replay_decisions([Arrival(0.2, 1), Arrival(0.1, 1)], sched(),
                         service_s={2: 0.01, 8: 0.01})


def test_service_snapshot_is_a_copy_and_feeds_replay():
    s = sched()
    s.observe_step(2, 0.02)
    s.observe_step(8, 0.05)
    snap = s.service_snapshot()
    assert snap == {2: pytest.approx(0.02), 8: pytest.approx(0.05)}
    snap[2] = 99.0                       # mutating the snapshot is safe
    assert s.service_estimate(2) == pytest.approx(0.02)
    # a snapshot is a ready-made service model for the replay
    table = replay_decisions([Arrival(0.001, 2)], sched(), service_s=snap)
    assert table and table[-1]["event"] == "dispatch"


def test_image_maker_deterministic(small):
    _, model, _ = small
    shape = model.input_shape()[1:]
    m1, m2 = image_maker(shape, seed=5), image_maker(shape, seed=5)
    exact(m1(0, 2), m2(0, 2))
    exact(m1(1, 1), m2(1, 1))
    assert m1(2, 3).shape == (3, *shape) and m1(2, 3).dtype == np.uint8


def test_open_loop_run_completes_everything(small):
    _, model, _ = small
    trace = poisson_trace(rps=200, duration_s=0.3, seed=0)
    policy = ServePolicy(max_wait_ms=5.0, slo_ms=500.0)
    with AsyncServeRuntime(model, policy=policy) as rt:
        m = run_open_loop(rt, trace,
                          image_maker(model.input_shape()[1:], seed=1),
                          slo_ms=500.0)
    assert m["requests_offered"] == len(trace)
    assert m["requests_accepted"] + m["requests_rejected"] == len(trace)
    assert m["requests_dropped"] == 0                 # accepted == promise
    assert m["images_completed"] == sum(
        len(r.labels) for r in rt.done)
    assert m["goodput_fps"] <= m["completed_fps"]
    assert m["latency_p99_s"] is not None
    assert 0.0 <= m["slo_attainment"] <= 1.0


def test_open_loop_trace_replays_bit_identical_through_sync_engine(small):
    """The loadgen's deterministic trace + image stream replayed through
    the SYNC engine produces the same labels the async run produced."""
    _, model, _ = small
    trace = [Arrival(t_s=0.001 * (k + 1), n_images=1 + k % 3)
             for k in range(6)]
    shape = model.input_shape()[1:]
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        run_open_loop(rt, trace, image_maker(shape, seed=9), slo_ms=100.0)
    async_labels = {r.rid: r.labels for r in rt.done}
    make = image_maker(shape, seed=9)                 # fresh, same stream
    eng = MicroBatchEngine(model)
    for k, a in enumerate(trace):
        eng.submit(make(k, a.n_images))
    sync_labels = {r.rid: r.labels for r in eng.run()}
    assert sync_labels == async_labels


# ---------------------------------------------------------------------------
# runtime construction contract
# ---------------------------------------------------------------------------

def test_runtime_rejects_policy_and_scheduler_together(small):
    _, model, _ = small
    with pytest.raises(ValueError, match="either policy or"):
        AsyncServeRuntime(model, policy=ServePolicy(),
                          scheduler=ContinuousBatchingScheduler((2, 8)))


def test_runtime_close_idempotent_without_start(small):
    _, model, _ = small
    rt = AsyncServeRuntime(model)
    rt.close()                              # never started: no-op
    rt.close()
    with pytest.raises(RuntimeError, match="closed"):
        rt.submit(np.zeros((1, 16, 16, 3), np.uint8))


# ---------------------------------------------------------------------------
# the unified ServeClient surface: one protocol, one stats schema
# ---------------------------------------------------------------------------

def test_all_three_clients_satisfy_serve_client_protocol(small):
    _, model, _ = small
    eng = MicroBatchEngine(model)
    rt = AsyncServeRuntime(model)
    fleet = ServeFleet(model, replicas=2)
    for client in (eng, rt, fleet):
        assert isinstance(client, ServeClient), type(client)
    rt.close()
    fleet.close()


def test_stats_schema_shared_and_versioned(small):
    """Every client's stats() carries the same versioned core schema, so
    loadgen/bench drivers read any of the three without isinstance."""
    _, model, imgs = small
    shared = {"stats_version", "requests", "images", "batches", "fps",
              "occupancy", "pad_waste", "padded_rows", "total_rows",
              "buckets", "wall_s", "paper_fps", "realtime",
              "latency_p50_s", "latency_p95_s", "latency_p99_s",
              "latency_mean_s", "queue_depth_peak"}
    # queue_depth_peak joined the shared vocabulary in v2; v3 made the
    # latency_* fields histogram-backed (same keys, bounded approximation)
    # — pin the version so a schema change can't ship without bumping it
    assert SERVE_STATS_VERSION == 3
    eng = MicroBatchEngine(model)
    eng.submit(imgs[:2])
    eng.close()                             # protocol close == run()
    clients = {"engine": eng.stats()}
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        rt.submit(imgs[:2]).result(timeout=30)
    clients["runtime"] = rt.stats()
    with ServeFleet(model, replicas=2,
                    policy=ServePolicy(max_wait_ms=2.0)) as fleet:
        fleet.submit(imgs[:2]).result(timeout=30)
    clients["fleet"] = fleet.stats()
    for name, st in clients.items():
        missing = shared - set(st)
        assert not missing, (name, missing)
        assert st["stats_version"] == SERVE_STATS_VERSION
        assert st["requests"] == 1 and st["images"] == 2
        assert st["queue_depth_peak"] >= 0
    # async surfaces add queue metrics; the fleet adds its replica table
    for name in ("runtime", "fleet"):
        assert {"queued_images", "requests_rejected",
                "requests_failed"} <= set(clients[name])
    assert clients["fleet"]["replicas"] == 2
    assert len(clients["fleet"]["replica_stats"]) == 2


def test_sync_engine_drives_run_open_loop(small):
    """The sync engine is a ServeClient too: the loadgen drives it through
    the same protocol (result() drains the queue in-thread)."""
    _, model, _ = small
    trace = [Arrival(t_s=0.001 * (k + 1), n_images=1 + k % 3)
             for k in range(5)]
    eng = MicroBatchEngine(model)
    m = run_open_loop(eng, trace, image_maker(model.input_shape()[1:],
                                              seed=11), slo_ms=10_000.0)
    assert m["requests_dropped"] == 0 and m["requests_rejected"] == 0
    assert m["images_completed"] == sum(a.n_images for a in trace)


# ---------------------------------------------------------------------------
# fleet scheduler: placement is pure and replays from a pinned table
# ---------------------------------------------------------------------------

def fleet_sched(n=2, max_wait_ms=10.0, **kw):
    return FleetScheduler((2, 8), ServePolicy(max_wait_ms=max_wait_ms, **kw),
                          n_replicas=n)


def test_fleet_placement_decision_table():
    s = fleet_sched(n=2)
    # no history: free replicas tie on estimate 0 -> lowest index, and the
    # base wait-vs-dispatch table is untouched
    d = s.decide(backlog=8, oldest_submit_s=0.0, now_s=0.0)
    assert (d.action, d.bucket, d.rows, d.replica) == ("dispatch", 8, 8, 0)
    assert s.decide(backlog=0, oldest_submit_s=None, now_s=0.0).action == \
        "idle"
    # replica 0 is observed slower than replica 1: placement flips
    s.observe_step(8, 0.040, replica=0)
    s.observe_step(8, 0.010, replica=1)
    d = s.decide(backlog=8, oldest_submit_s=0.0, now_s=0.0)
    assert d.replica == 1
    # the faster replica busy: the slower free one gets the chunk
    d = s.decide(backlog=8, oldest_submit_s=0.0, now_s=0.0,
                 busy=(False, True))
    assert d.replica == 0
    # everyone busy: a bounded wait, never a dispatch nobody can run
    d = s.decide(backlog=8, oldest_submit_s=0.0, now_s=0.0,
                 busy=(True, True))
    assert d.action == "wait" and d.reason == "all replicas busy"
    assert d.wait_s == pytest.approx(0.010)
    # wait/idle decisions replay identically given identical inputs
    assert s.decide(backlog=8, oldest_submit_s=0.0, now_s=0.0) == \
        s.decide(backlog=8, oldest_submit_s=0.0, now_s=0.0)


def test_fleet_placement_class_conditioned_estimates():
    """Sparse and dense traffic get separate per-replica EWMAs: the same
    bucket routes to different replicas depending on the occupancy class —
    SLO pressure places batches on the replica whose class estimate meets
    the deadline."""
    s = fleet_sched(n=2, sparse_occupancy=0.35)
    # replica 0 is fast on sparse batches, replica 1 fast on dense
    s.observe_step(2, 0.010, occupancy=0.1, replica=0)
    s.observe_step(2, 0.050, occupancy=0.8, replica=0)
    s.observe_step(2, 0.040, occupancy=0.1, replica=1)
    s.observe_step(2, 0.015, occupancy=0.8, replica=1)
    free = (False, False)
    assert s.place(2, busy=free, occupancy=0.1) == 0
    assert s.place(2, busy=free, occupancy=0.9) == 1
    # with no explicit occupancy the running EWMA picks the class
    assert s.replica_estimate(0, 2, 0.1) == pytest.approx(0.010)
    assert s.replica_estimate(1, 2, 0.9) == pytest.approx(0.015)
    # a fresh replica (no history) borrows the fleet-wide estimate
    s3 = fleet_sched(n=3)
    s3.observe_step(2, 0.020, replica=0)
    assert s3.replica_estimate(2, 2) == s3.service_estimate(2)


def test_fleet_scheduler_validates_busy_mask_and_counts():
    with pytest.raises(ValueError, match="n_replicas"):
        fleet_sched(n=0)
    s = fleet_sched(n=2)
    with pytest.raises(ValueError, match="busy mask"):
        s.decide(backlog=8, oldest_submit_s=0.0, now_s=0.0,
                 busy=(True,))


# ---------------------------------------------------------------------------
# fleet runtime: determinism, lifecycle, hot swap
# ---------------------------------------------------------------------------

def test_fleet_identical_trace_one_vs_n_replicas_bit_identical(small):
    """The tentpole acceptance property: the SAME request trace through 1,
    2, and 3 replicas yields bit-identical labels, all matching direct
    classify()."""
    _, model, imgs = small
    reqs = trace_requests(imgs)
    per_n = {}
    for n in (1, 2, 3):
        with ServeFleet(model, replicas=n,
                        policy=ServePolicy(max_wait_ms=2.0)) as fleet:
            handles = [fleet.submit(r) for r in reqs]
            per_n[n] = [h.result(timeout=30) for h in handles]
    assert per_n[1] == per_n[2] == per_n[3]
    want = np.asarray(model.classify(imgs)).tolist()
    flat = [lab for labs in per_n[2] for lab in labs]
    assert flat == want[:len(flat)]


def test_fleet_construction_contract(small):
    _, model, _ = small
    with pytest.raises(ValueError, match="replicas"):
        ServeFleet(model, replicas=0)
    with pytest.raises(ValueError, match="pace_fps"):
        ServeFleet(model, replicas=1, pace_fps=0)
    with pytest.raises(ValueError, match="either policy or"):
        ServeFleet(model, replicas=2, policy=ServePolicy(),
                   scheduler=FleetScheduler((2, 8), n_replicas=2))
    with pytest.raises(ValueError, match="placement"):
        ServeFleet(model, replicas=2,
                   scheduler=ContinuousBatchingScheduler((2, 8)))
    with pytest.raises(ValueError, match="2 replicas"):
        ServeFleet(model, replicas=3,
                   scheduler=FleetScheduler((2, 8), n_replicas=2))


def test_fleet_lifecycle_health_and_probe(small):
    _, model, imgs = small
    fleet = ServeFleet(model, replicas=2)
    assert all(r["state"] == "created"
               for r in fleet.health()["replicas"])
    fleet.start()
    h = fleet.health()
    assert all(r["state"] == "ready" and r["warmup_s"] is not None
               for r in h["replicas"])
    probes = fleet.probe()
    assert all(p["ok"] and p["probe_s"] is not None for p in probes)
    # drain replica 0: it takes no work, the fleet keeps serving
    fleet.drain_replica(0)
    assert fleet.submit(imgs[:3]).result(timeout=30) is not None
    h = fleet.health()
    assert h["replicas"][0]["state"] == "draining"
    assert h["replicas"][0]["steps"] == 0
    assert h["replicas"][1]["steps"] > 0
    fleet.resume_replica(0)
    assert fleet.health()["replicas"][0]["state"] == "ready"
    fleet.close()
    assert all(r["state"] == "stopped"
               for r in fleet.health()["replicas"])
    with pytest.raises(RuntimeError, match="closed"):
        fleet.submit(imgs[:1])


def test_fleet_hot_swap_under_load_keeps_every_promise(small):
    """Plan hot-swap mid-traffic: requests accepted before, during, and
    after the swap all resolve; post-swap labels are the NEW model's."""
    cfg, model, imgs = small
    params2 = init(jax.random.PRNGKey(42), cfg)
    model2 = infer_compile(params2, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    model2.warmup()
    policy = ServePolicy(max_wait_ms=2.0)
    with ServeFleet(model, replicas=2, policy=policy) as fleet:
        before = [fleet.submit(imgs[i:i + 2]) for i in (0, 2, 4)]
        fleet.swap(model2, timeout=30)
        after = [fleet.submit(imgs[i:i + 2]) for i in (6, 8)]
        for h in before + after:
            assert len(h.result(timeout=30)) == 2
    assert fleet.swaps == 1
    assert all(r["swaps"] == 1 for r in fleet.health()["replicas"])
    want = np.asarray(model2.classify(imgs)).tolist()
    assert [h.result() for h in after] == [want[6:8], want[8:10]]


def test_fleet_swap_rejects_incompatible_plan(small):
    cfg, model, _ = small
    params = init(jax.random.PRNGKey(0), cfg)
    other = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(4,)))
    with ServeFleet(model, replicas=1) as fleet:
        with pytest.raises(ValueError, match="bucket set"):
            fleet.swap(other)


def test_fleet_step_failure_contained_to_batch():
    """A failing replica step fails that batch's requests and counts on the
    replica's health row; the fleet keeps serving."""
    model = FlakyModel()
    model.fail_next = 1
    imgs = np.zeros((2, 4, 4, 3), np.uint8)
    with ServeFleet(model, replicas=2,
                    policy=ServePolicy(max_wait_ms=2.0)) as fleet:
        bad = fleet.submit(imgs)
        with pytest.raises(RuntimeError, match="step boom"):
            bad.result(timeout=10)
        ok = fleet.submit(imgs)
        assert ok.result(timeout=10) == [0, 0]
        stats = fleet.stats()
        health = fleet.health()
    assert stats["requests_failed"] == 1 and stats["requests"] == 1
    assert sum(r["failures"] for r in health["replicas"]) == 1


class RaceModel:
    """Forces two chunks of one request to be IN FLIGHT on two replicas at
    the same time (a barrier inside step), then fails the first
    ``fail_calls`` steps — the cross-replica failure-containment race."""
    buckets = (2,)

    def __init__(self, fail_calls=1):
        self.fail_calls = fail_calls
        self.barrier = threading.Barrier(2)
        self.lock = threading.Lock()
        self.calls = 0

    def input_shape(self, bucket=None):
        return (2, 4, 4, 3)

    def step(self, batch):
        with self.lock:
            self.calls += 1
            n = self.calls
        if n <= 2:
            self.barrier.wait(timeout=10)   # both chunks in flight together
            if n > self.fail_calls:
                time.sleep(0.05)   # lose the race: the purge lands first
        if n <= self.fail_calls:
            raise RuntimeError("step boom")
        return np.zeros((len(batch), 10), np.float32)


def test_fleet_cross_replica_failure_does_not_kill_fleet():
    """One request's chunks in flight on two replicas when one step fails:
    the surviving replica's completion must skip the purged bookkeeping,
    not KeyError into a whole-fleet abort."""
    model = RaceModel(fail_calls=1)
    imgs = np.zeros((4, 4, 4, 3), np.uint8)
    with ServeFleet(model, replicas=2,
                    policy=ServePolicy(max_wait_ms=1.0)) as fleet:
        bad = fleet.submit(imgs)        # 4 images -> two bucket-2 chunks
        with pytest.raises(RuntimeError, match="step boom"):
            bad.result(timeout=10)
        # bad's future fails the moment the FIRST chunk's step raises; the
        # surviving chunk is still in flight — wait for its completion
        # bookkeeping to land before judging fleet health (the pre-fix
        # KeyError->abort fires exactly there)
        deadline = time.time() + 5
        while time.time() < deadline and any(
                r._work is not None for r in fleet.replicas):
            time.sleep(0.01)
        ok = fleet.submit(imgs[:2])     # the fleet survived, still serves
        assert ok.result(timeout=10) == [0, 0]
        stats = fleet.stats()
    assert stats["requests_failed"] == 1
    assert stats["requests"] == 1


def test_fleet_same_request_failing_on_two_replicas_counts_once():
    """Both chunks of one request fail, on different replicas: the request
    fails once — failed_requests must not double-count the rid."""
    model = RaceModel(fail_calls=2)
    imgs = np.zeros((4, 4, 4, 3), np.uint8)
    with ServeFleet(model, replicas=2,
                    policy=ServePolicy(max_wait_ms=1.0)) as fleet:
        bad = fleet.submit(imgs)
        with pytest.raises(RuntimeError, match="step boom"):
            bad.result(timeout=10)
        ok = fleet.submit(imgs[:2])
        assert ok.result(timeout=10) == [0, 0]
        # bad's future fails on the FIRST chunk's _fail_batch; the second
        # replica's worker may still be landing its own failure bookkeeping
        # (failures += 1, then _work = None, under the lock) — wait for it
        deadline = time.time() + 5
        while time.time() < deadline and any(
                r._work is not None for r in fleet.replicas):
            time.sleep(0.005)
        stats = fleet.stats()
        health = fleet.health()
    assert stats["requests_failed"] == 1
    assert sum(r["failures"] for r in health["replicas"]) == 2


def test_fleet_close_resumes_drained_replicas(small):
    """close() finishes the drain even when the caller drained EVERY
    replica first: queued work still dispatches and every accepted
    request resolves (a fully-drained fleet must not hang close)."""
    _, model, imgs = small
    fleet = ServeFleet(model, replicas=2,
                       policy=ServePolicy(max_wait_ms=5.0)).start()
    fleet.drain_replica(0)
    fleet.drain_replica(1)
    req = fleet.submit(imgs[:3])
    fleet.close(timeout=30)
    assert len(req.result(timeout=1)) == 3
    assert fleet.stats()["requests_failed"] == 0


def test_fleet_queue_full_and_empty_request(small):
    _, model, imgs = small
    policy = ServePolicy(max_wait_ms=10_000.0, max_queue_images=3)
    with ServeFleet(model, replicas=2, policy=policy) as fleet:
        kept = [fleet.submit(imgs[i:i + 1]) for i in range(3)]
        with pytest.raises(QueueFull, match="max_queue_images=3"):
            fleet.submit(imgs[3:4])
        empty = fleet.submit(imgs[:0])
        assert empty.result(timeout=5) == []
    assert all(len(k.result(timeout=1)) == 1 for k in kept)
    assert fleet.stats()["requests_rejected"] == 1


def test_fleet_paced_replica_sweep_scales_goodput(small):
    """Paced replicas model fixed-rate cores: with the offered rate above
    one core's capacity, adding a second replica must raise goodput
    (the committed bench gates >= 1.5x; here >= 1.4 absorbs CI noise on a
    short trace) with zero drops and full SLO attainment."""
    _, model, _ = small
    policy = ServePolicy(max_wait_ms=10.0, slo_ms=1000.0,
                         max_queue_images=16)
    trace = poisson_trace(rps=40, duration_s=1.5, seed=5,
                          images_per_request=(1, 3))
    rows = run_replica_sweep(
        lambda n: ServeFleet(model, replicas=n, policy=policy,
                             pace_fps=40).start(),
        trace,
        lambda: image_maker(model.input_shape()[1:], seed=6),
        replica_counts=(1, 2), slo_ms=1000.0)
    assert [r["replicas"] for r in rows] == [1, 2]
    for r in rows:
        assert r["requests_dropped"] == 0
        assert r["slo_attainment"] == 1.0
    assert rows[0]["goodput_scaling"] == 1.0
    assert rows[1]["goodput_scaling"] >= 1.4, rows
