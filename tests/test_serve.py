"""The async continuous-batching serving runtime (``repro.serve``).

Three layers, three standards of proof:

* the SCHEDULER is pure — its full wait-vs-dispatch decision table is
  pinned under an injected clock, no threads, no sleeps;
* the RUNTIME is checked against the sync engine: an identical request
  trace must produce bit-identical labels through ``MicroBatchEngine``
  and ``AsyncServeRuntime`` (per-image math is row-independent and
  bucket-invariant, so batching happenstance cannot leak into labels);
* the LOADGEN is deterministic from its seed and measures the open-loop
  contract: every accepted request completes (zero dropped).
"""
import threading

import jax
import numpy as np
import pytest

from repro.core.spikformer import SpikformerConfig, init
from repro.infer import ExecutionPlan, MicroBatchEngine, compile as \
    infer_compile
from repro.infer.compile import plan_chunks
from repro.infer.engine import (StepAccounting, assemble_batch,
                                latency_summary, validate_images)
from repro.serve import (Arrival, AsyncServeRuntime,
                         ContinuousBatchingScheduler, QueueFull, ServePolicy,
                         image_maker, poisson_trace, run_open_loop)


def exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.fixture(scope="module")
def small():
    cfg = SpikformerConfig().scaled(img_size=16, dim=32, depth=1)
    params = init(jax.random.PRNGKey(0), cfg)
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    model.warmup()
    imgs = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (11, 16, 16, 3), 0, 256, "uint8"))
    return cfg, model, imgs


# ---------------------------------------------------------------------------
# scheduler: the pinned decision table (pure, injected clock)
# ---------------------------------------------------------------------------

def sched(max_wait_ms=10.0, slo_ms=None, depth=512, buckets=(2, 8)):
    return ContinuousBatchingScheduler(
        buckets, ServePolicy(max_wait_ms=max_wait_ms, slo_ms=slo_ms,
                             max_queue_images=depth))


def test_decision_table_wait_vs_dispatch():
    s = sched(max_wait_ms=10.0)
    # empty queue: idle (sleep until a submit)
    assert s.decide(backlog=0, oldest_submit_s=None, now_s=5.0).action == \
        "idle"
    # a full largest bucket never waits
    d = s.decide(backlog=8, oldest_submit_s=0.0, now_s=0.0)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 8, 8)
    d = s.decide(backlog=9, oldest_submit_s=0.0, now_s=0.0)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 8, 8)
    # partial backlog inside the window: wait EXACTLY until the deadline
    d = s.decide(backlog=3, oldest_submit_s=1.0, now_s=1.004)
    assert d.action == "wait"
    assert d.wait_s == pytest.approx(0.006)
    # at the deadline: dispatch the FIRST chunk of the pad-minimizing
    # split — 3 over (2, 8) runs 2 now, leaves 1 accumulating
    d = s.decide(backlog=3, oldest_submit_s=1.0, now_s=1.010)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 2, 2)
    assert d.reason == "max_wait deadline reached"


def test_decision_table_tail_smaller_than_smallest_bucket():
    s = sched(max_wait_ms=10.0)
    # backlog 1 < smallest bucket 2: waits its window, then dispatches
    # padded into the smallest bucket
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0)
    assert d.action == "wait" and d.wait_s == pytest.approx(0.010)
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.011)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 2, 1)


def test_decision_table_slo_pressure_closes_window_early():
    s = sched(max_wait_ms=50.0, slo_ms=30.0)
    # no observed step times: SLO deadline = submit + slo (estimate 0),
    # tighter than max_wait
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0)
    assert d.action == "wait" and d.wait_s == pytest.approx(0.030)
    # an observed 20ms step shrinks the budget: dispatch by 30-20=10ms
    s.observe_step(2, 0.020)
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0)
    assert d.action == "wait" and d.wait_s == pytest.approx(0.010)
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0105)
    assert d.action == "dispatch" and d.reason == "SLO pressure"
    # EWMA: a faster step moves the estimate, deterministically
    s.observe_step(2, 0.010)
    assert s.service_estimate(2) == pytest.approx(0.8 * 0.020 + 0.2 * 0.010)
    # unknown bucket: conservative (slowest observed)
    assert s.service_estimate(8) == s.service_estimate(2)


def test_decision_table_draining_dispatches_immediately():
    s = sched(max_wait_ms=10_000.0)
    d = s.decide(backlog=1, oldest_submit_s=0.0, now_s=0.0, draining=True)
    assert (d.action, d.bucket, d.rows) == ("dispatch", 2, 1)
    assert d.reason == "draining"
    assert s.decide(backlog=0, oldest_submit_s=None, now_s=0.0,
                    draining=True).action == "idle"


def test_scheduler_admission_bound():
    s = sched(depth=4)
    assert s.admit(0, 4) and s.admit(3, 1)
    assert not s.admit(3, 2) and not s.admit(0, 5)
    with pytest.raises(ValueError, match="max_queue_images"):
        ServePolicy(max_queue_images=0)
    with pytest.raises(ValueError, match="slo_ms"):
        ServePolicy(slo_ms=0)
    with pytest.raises(ValueError, match="max_wait_ms"):
        ServePolicy(max_wait_ms=-1)


def test_scheduler_reuses_model_plan_chunks(small):
    """The scheduler's dispatch shape IS the model's pad-minimizing split —
    same function, not a copy."""
    _, model, _ = small
    for n in range(1, 20):
        assert plan_chunks(n, model.buckets) == model.plan_chunks(n)
    s = sched()
    for backlog in range(1, 8):
        d = s.decide(backlog=backlog, oldest_submit_s=0.0, now_s=1.0)
        assert (d.rows, d.bucket) == model.plan_chunks(backlog)[0]


# ---------------------------------------------------------------------------
# shared serve plumbing (engine.py): validation, assembly, accounting
# ---------------------------------------------------------------------------

def test_validate_images_shape_and_dtype():
    ok = validate_images(np.zeros((2, 16, 16, 3), np.uint8), (16, 16, 3))
    assert ok.shape == (2, 16, 16, 3) and ok.dtype == np.uint8
    # int32 in range casts; out of range refuses
    assert validate_images(np.full((1, 16, 16, 3), 255, np.int32),
                           (16, 16, 3)).dtype == np.uint8
    with pytest.raises(ValueError, match=r"outside \[0, 255\]"):
        validate_images(np.full((1, 16, 16, 3), 256, np.int32), (16, 16, 3))
    # the error NAMES the expected per-image shape
    with pytest.raises(ValueError, match=r"\(n, 16, 16, 3\)"):
        validate_images(np.zeros((2, 8, 8, 3), np.uint8), (16, 16, 3))
    with pytest.raises(ValueError, match="expected uint8"):
        validate_images(np.zeros((2, 16, 16, 3), np.float32), (16, 16, 3))
    # a single unbatched image is not silently promoted
    with pytest.raises(ValueError, match=r"\(16, 16, 3\)"):
        validate_images(np.zeros((16, 16, 3), np.uint8), (16, 16, 3))


def test_engine_submit_door_validation(small):
    _, model, imgs = small
    eng = MicroBatchEngine(model)
    with pytest.raises(ValueError, match=r"\(n, 16, 16, 3\)"):
        eng.submit(np.zeros((1, 8, 8, 3), np.uint8))
    with pytest.raises(ValueError, match="dtype"):
        eng.submit(imgs[:1].astype(np.float32))
    assert not eng.queue                  # nothing half-queued


def test_assemble_batch_and_accounting():
    batch, pad = assemble_batch([np.ones((4, 4), np.uint8)] * 3, 8)
    assert batch.shape == (8, 4, 4) and pad == 5
    assert batch[:3].all() and not batch[3:].any()
    batch, pad = assemble_batch([np.ones((4, 4), np.uint8)] * 2, 2)
    assert batch.shape == (2, 4, 4) and pad == 0
    acct = StepAccounting()
    acct.record_step(rows=3, bucket=8, busy_s=0.5, wall_s=1.0)
    acct.record_step(rows=2, bucket=2, busy_s=0.25, wall_s=0.5)
    assert acct.batches == 2 and acct.images == 5
    assert acct.padded_rows == 5 and acct.total_rows == 10
    assert acct.pad_waste == 0.5
    assert acct.fps == pytest.approx(5 / 1.5)
    assert latency_summary([])["latency_p99_s"] is None
    s = latency_summary([0.1] * 99 + [1.0])
    assert s["latency_p50_s"] == 0.1 and s["latency_p99_s"] > 0.1


# ---------------------------------------------------------------------------
# runtime: sync/async parity and the edge-case contract
# ---------------------------------------------------------------------------

def trace_requests(imgs):
    """A fixed mixed-size request trace over the fixture images."""
    sizes = (2, 1, 3, 1, 2, 2)
    out, i = [], 0
    for n in sizes:
        out.append(imgs[i:i + n])
        i += n
    return out


def test_identical_trace_sync_async_bit_identical_labels(small):
    """The acceptance property: the SAME request trace through the sync
    engine and the async runtime yields bit-identical labels, and both
    match direct classify()."""
    _, model, imgs = small
    reqs = trace_requests(imgs)
    eng = MicroBatchEngine(model)
    for r in reqs:
        eng.submit(r)
    sync_done = sorted(eng.run(), key=lambda r: r.rid)
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        handles = [rt.submit(r) for r in reqs]
        async_labels = [h.result(timeout=30) for h in handles]
    assert [r.labels for r in sync_done] == async_labels
    want = np.asarray(model.classify(imgs)).tolist()
    flat = [lab for labs in async_labels for lab in labs]
    assert flat == want[:len(flat)]


def test_async_empty_request_completes_via_future(small):
    _, model, imgs = small
    with AsyncServeRuntime(model) as rt:
        req = rt.submit(imgs[:0])
        assert req.result(timeout=5) == []
        assert req.t_done == req.t_submit
        assert rt.stats()["requests"] == 1


def test_async_rid_reuse_and_inflight_rejection(small):
    _, model, imgs = small
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=10_000.0)) as rt:
        first = rt.submit(imgs[:2], rid=7)     # fills bucket 2: dispatches
        assert first.result(timeout=30) is not None
        second = rt.submit(imgs[2:3], rid=7)   # completed rid is reusable
        # 1 image < smallest bucket + huge window: still in flight
        with pytest.raises(ValueError, match="already in flight"):
            rt.submit(imgs[3:4], rid=7)
    # close() drained: the in-flight request completed, not abandoned
    assert second.result(timeout=1) == second.labels
    assert len(second.labels) == 1
    with pytest.raises(RuntimeError, match="closed"):
        rt.submit(imgs[:1])


def test_async_queue_full_rejection_is_explicit(small):
    _, model, imgs = small
    policy = ServePolicy(max_wait_ms=10_000.0, max_queue_images=3)
    with AsyncServeRuntime(model, policy=policy) as rt:
        kept = [rt.submit(imgs[i:i + 1]) for i in range(3)]
        with pytest.raises(QueueFull, match="max_queue_images=3"):
            rt.submit(imgs[3:4])
        assert rt.stats()["requests_rejected"] == 1
    # every ACCEPTED request still completed on drain
    assert all(len(k.result(timeout=1)) == 1 for k in kept)


def test_async_tail_smaller_than_smallest_bucket_pads(small):
    """A lone request below the smallest bucket is not starved: the window
    closes and it ships padded."""
    _, model, imgs = small
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=1.0)) as rt:
        req = rt.submit(imgs[:1])
        assert len(req.result(timeout=30)) == 1
        stats = rt.stats()
    assert stats["padded_rows"] == 1 and stats["total_rows"] == 2
    assert req.labels == np.asarray(model.classify(imgs[:1])).tolist()


def test_async_submit_door_validation_rejects_before_queueing(small):
    _, model, imgs = small
    with AsyncServeRuntime(model) as rt:
        with pytest.raises(ValueError, match=r"\(n, 16, 16, 3\)"):
            rt.submit(np.zeros((1, 8, 8, 3), np.uint8))
        with pytest.raises(ValueError, match="dtype"):
            rt.submit(imgs[:1].astype(np.float64))
        assert rt.stats()["queued_images"] == 0


def test_async_streaming_callback_per_image(small):
    _, model, imgs = small
    got, lock = [], threading.Lock()

    def on_image(rid, idx, label):
        with lock:
            got.append((rid, idx, label))

    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        req = rt.submit(imgs[:3], rid=0, on_image=on_image)
        labels = req.result(timeout=30)
    assert sorted(got) == [(0, i, labels[i]) for i in range(3)]


def test_async_streaming_callback_exception_does_not_kill_worker(small):
    """A raising user callback must not wedge the runtime: the future
    still resolves and later requests still serve."""
    _, model, imgs = small

    def bad(rid, idx, label):
        raise RuntimeError("user callback bug")

    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        r1 = rt.submit(imgs[:2], on_image=bad)
        assert len(r1.result(timeout=30)) == 2
        r2 = rt.submit(imgs[2:4])
        assert len(r2.result(timeout=30)) == 2
    assert rt.stats()["requests"] == 2


class FlakyModel:
    """CompiledModel stand-in whose step fails on demand — small enough to
    pin the runtime's failure semantics without a real compile."""
    buckets = (2,)

    def __init__(self):
        self.fail_next = 0

    def input_shape(self, bucket=None):
        return (2, 4, 4, 3)

    def step(self, batch):
        if self.fail_next:
            self.fail_next -= 1
            raise RuntimeError("step boom")
        return np.zeros((len(batch), 10), np.float32)


def test_async_step_failure_fails_that_batch_not_the_runtime():
    """A failing model step resolves the affected futures with the error
    (never a silent forever-block) and serving continues."""
    model = FlakyModel()
    model.fail_next = 1
    imgs = np.zeros((2, 4, 4, 3), np.uint8)
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        bad = rt.submit(imgs)
        with pytest.raises(RuntimeError, match="step boom"):
            bad.result(timeout=10)
        ok = rt.submit(imgs)                  # the worker survived
        assert ok.result(timeout=10) == [0, 0]
        stats = rt.stats()
    assert stats["requests_failed"] == 1 and stats["requests"] == 1


def test_async_submits_from_many_threads(small):
    """The bounded queue really is thread-safe: concurrent submitters, all
    futures complete, labels match the single-threaded classify()."""
    _, model, imgs = small
    want = np.asarray(model.classify(imgs)).tolist()
    results = {}
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        def worker(i):
            results[i] = rt.submit(imgs[i:i + 1], rid=i).result(timeout=30)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert {i: labs[0] for i, labs in results.items()} == \
        {i: want[i] for i in range(8)}


# ---------------------------------------------------------------------------
# loadgen: deterministic traces, open-loop metrics
# ---------------------------------------------------------------------------

def test_poisson_trace_deterministic_and_bounded():
    a = poisson_trace(rps=100, duration_s=1.0, seed=3,
                      images_per_request=(1, 3))
    b = poisson_trace(rps=100, duration_s=1.0, seed=3,
                      images_per_request=(1, 3))
    assert a == b and len(a) > 20
    assert all(0 < x.t_s < 1.0 and 1 <= x.n_images <= 3 for x in a)
    assert [x.t_s for x in a] == sorted(x.t_s for x in a)
    assert a != poisson_trace(rps=100, duration_s=1.0, seed=4,
                              images_per_request=(1, 3))
    with pytest.raises(ValueError, match="rps"):
        poisson_trace(rps=0, duration_s=1.0, seed=0)


def test_image_maker_deterministic(small):
    _, model, _ = small
    shape = model.input_shape()[1:]
    m1, m2 = image_maker(shape, seed=5), image_maker(shape, seed=5)
    exact(m1(0, 2), m2(0, 2))
    exact(m1(1, 1), m2(1, 1))
    assert m1(2, 3).shape == (3, *shape) and m1(2, 3).dtype == np.uint8


def test_open_loop_run_completes_everything(small):
    _, model, _ = small
    trace = poisson_trace(rps=200, duration_s=0.3, seed=0)
    policy = ServePolicy(max_wait_ms=5.0, slo_ms=500.0)
    with AsyncServeRuntime(model, policy=policy) as rt:
        m = run_open_loop(rt, trace,
                          image_maker(model.input_shape()[1:], seed=1),
                          slo_ms=500.0)
    assert m["requests_offered"] == len(trace)
    assert m["requests_accepted"] + m["requests_rejected"] == len(trace)
    assert m["requests_dropped"] == 0                 # accepted == promise
    assert m["images_completed"] == sum(
        len(r.labels) for r in rt.done)
    assert m["goodput_fps"] <= m["completed_fps"]
    assert m["latency_p99_s"] is not None
    assert 0.0 <= m["slo_attainment"] <= 1.0


def test_open_loop_trace_replays_bit_identical_through_sync_engine(small):
    """The loadgen's deterministic trace + image stream replayed through
    the SYNC engine produces the same labels the async run produced."""
    _, model, _ = small
    trace = [Arrival(t_s=0.001 * (k + 1), n_images=1 + k % 3)
             for k in range(6)]
    shape = model.input_shape()[1:]
    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=2.0)) as rt:
        run_open_loop(rt, trace, image_maker(shape, seed=9), slo_ms=100.0)
    async_labels = {r.rid: r.labels for r in rt.done}
    make = image_maker(shape, seed=9)                 # fresh, same stream
    eng = MicroBatchEngine(model)
    for k, a in enumerate(trace):
        eng.submit(make(k, a.n_images))
    sync_labels = {r.rid: r.labels for r in eng.run()}
    assert sync_labels == async_labels


# ---------------------------------------------------------------------------
# runtime construction contract
# ---------------------------------------------------------------------------

def test_runtime_rejects_policy_and_scheduler_together(small):
    _, model, _ = small
    with pytest.raises(ValueError, match="either policy or"):
        AsyncServeRuntime(model, policy=ServePolicy(),
                          scheduler=ContinuousBatchingScheduler((2, 8)))


def test_runtime_close_idempotent_without_start(small):
    _, model, _ = small
    rt = AsyncServeRuntime(model)
    rt.close()                              # never started: no-op
    rt.close()
    with pytest.raises(RuntimeError, match="closed"):
        rt.submit(np.zeros((1, 16, 16, 3), np.uint8))
