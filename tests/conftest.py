import collections
import json
import os
import sys

# Tests see the REAL device count (1 CPU) — the 512-device override belongs
# to launch/dryrun.py only. Keep x64 off (default JAX behaviour).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

# Per-route parity pass counts (tests/test_parity.py records into this via
# the ``parity_pass`` fixture). When $PARITY_SUMMARY names a file, the
# counts are dumped there as JSON at session end — scripts/tier1.sh merges
# them into tier1_summary.json and the CI step summary, so a sweep that
# silently stopped covering a route shows up as a dropped counter, not a
# green run.
_PARITY_PASSES = collections.Counter()


@pytest.fixture
def parity_pass():
    """Record passed parity checks: call with ``{"route-key": n}`` (or any
    Counter-updatable) AFTER the assertions they count have passed."""
    return _PARITY_PASSES.update


def pytest_sessionfinish(session, exitstatus):
    path = os.environ.get("PARITY_SUMMARY")
    if path and _PARITY_PASSES:
        with open(path, "w") as f:
            json.dump({"parity_passes": dict(sorted(_PARITY_PASSES.items()))},
                      f, indent=1, sort_keys=True)
            f.write("\n")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def assert_trees_close(a, b, *, rtol=1e-5, atol=1e-5):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)
