import os
import sys

# Tests see the REAL device count (1 CPU) — the 512-device override belongs
# to launch/dryrun.py only. Keep x64 off (default JAX behaviour).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def assert_trees_close(a, b, *, rtol=1e-5, atol=1e-5):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)
