"""Cross-backend differential parity harness.

One seeded sweep proves the whole route matrix agrees: for each (M, K, N,
G, T, weight dtype, firing rate) — including tail shapes T in {1, 9, 17},
sub-block M/N, and shapes crossing the Pallas block boundaries — every
registered backend and every route is compared against the FloatBackend
contract:

  * LUT family (CPU dense gather, CPU zero-chunk-skipping sparse gather,
    Pallas VMEM-table gather under interpret mode, a Pallas-replayed
    "lut_sparse" pin, and the fused pack->TFLIF->matmul kernel) — all
    BIT-EXACT against ``lut_matmul_planes``, the defined-fold oracle the
    float reference executes for LUT-planned layers.
  * unpack family — the CPU mirrored dot is bit-exact against
    ``core.unified``; the Pallas grouped dot kernel is bit-exact for
    integer weights and reduction-order-tolerant for float32 (which is why
    float bit-exactness pins "lut" routes).
  * end to end — ``compile()`` under every registered backend, with the
    reference partner compiled from the SUBJECT's resolved plan (routes
    pinned, not re-derived), asserting bit-identical logits. TPU-only
    backends run through their documented ``interpret`` escape hatch.

The fuzz sweep derives shapes and occupancy from a deterministic
per-seed PRNG; every assertion message carries the seed + shape so a
failure is reproducible from the message alone. Passed checks are counted
per route via the ``parity_pass`` fixture (see conftest.py) and published
to $PARITY_SUMMARY for the CI step summary.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import unified
from repro.core.spike import pack_timesteps
from repro.core.spikformer import SpikformerConfig, init
from repro.infer import (ExecutionPlan, compile as infer_compile,
                         list_backends)
from repro.infer.backends import chunk_occupancy
from repro.kernels import lut_matmul as lut
from repro.kernels import ops

# TPU-only backends enter the sweep through their documented escape hatch:
# the Pallas interpreter runs the same kernel bodies on CPU, bit-exactly
BACKEND_OPTIONS = {"packed_pallas": {"interpret": True}}

# (t, m, k, n): tail T (1, 9, 17), sub-block M/N/K, non-multiple-of-8 K.
# Block-boundary crossing is exercised at the kernel level with shrunken
# bm/bn/bc blocks (test_pallas_block_tiling_*) — same tiling code paths,
# interpret-mode cost of a 128-wide grid not paid on every run.
SHAPES = [
    (1, 1, 1, 1),
    (1, 7, 12, 5),
    (4, 6, 20, 10),
    (9, 3, 8, 5),
    (17, 5, 33, 12),
]


def exact(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


def make_case(seed, t, m, k, n, *, rate, int_w):
    """Deterministic operands for one parity case."""
    r = np.random.default_rng(seed)
    s = jnp.asarray((r.random((t, m, k)) < rate).astype(np.float32))
    if int_w:
        w = jnp.asarray(r.integers(-127, 128, (k, n)).astype(np.int8))
    else:
        w = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    return s, w, b


def check_route_matrix(s, w, b, *, t, tag, parity_pass):
    """The differential core: all LUT-family routes vs the defined-fold
    oracle, both unpack routes vs ``core.unified``, fused pair vs the
    unfused composition. Returns nothing; raises with ``tag`` on any
    mismatch."""
    m, k = s.shape[1], s.shape[2]
    p = pack_timesteps(s)                         # (G, m, k)
    tbl = lut.build_lut(w)
    occ = chunk_occupancy(p, t)
    int_w = lut._is_int_kernel(w)

    # the float reference's fold-order oracle for LUT-planned layers
    oracle = lut.lut_matmul_planes(s.reshape(t, m, k), w) + b

    routes = {
        "lut": dict(route="lut", table=tbl, pallas=False),
        "lut_sparse": dict(route="lut_sparse", table=tbl, occupancy=occ,
                           pallas=False),
        "pallas_lut": dict(route="lut", table=tbl, pallas=True),
        # a CPU-calibrated sparse pin replayed on the Pallas branch runs
        # the dense gather — bitwise identical by construction
        "pallas_lut_sparse_pin": dict(route="lut_sparse", table=tbl,
                                      occupancy=occ, pallas=True),
    }
    for name, kw in routes.items():
        got = ops.spike_linear(p, w, b, t=t, **kw)
        exact(got, oracle, msg=f"{tag} route={name}")
        parity_pass({name: 1})

    unpack_oracle = unified.wssl(s, w, b)
    got = ops.spike_linear(p, w, b, t=t, route="unpack", pallas=False)
    exact(got, unpack_oracle, msg=f"{tag} route=unpack")
    parity_pass({"unpack": 1})
    got = ops.spike_linear(p, w, b, t=t, route="unpack", pallas=True)
    if int_w:
        exact(got, unpack_oracle, msg=f"{tag} route=pallas_unpack")
    else:
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(unpack_oracle), rtol=1e-5,
            atol=1e-5, err_msg=f"{tag} route=pallas_unpack")
    parity_pass({"pallas_unpack": 1})

    # fused pack->TFLIF->matmul vs the unfused composition, both outputs
    acc = s * 2.0 - 0.5                           # arbitrary f32 pre-LIF
    s0, a0 = ops.tflif_lut(acc, b[:1], table=tbl, t=t, pallas=False)
    s1, a1 = ops.tflif_lut(acc, b[:1], table=tbl, t=t, pallas=True)
    exact(s0, s1, msg=f"{tag} route=fused(spikes)")
    exact(a0, a1, msg=f"{tag} route=fused(acc)")
    parity_pass({"fused": 1})


@pytest.mark.parametrize("int_w", [False, True], ids=["f32", "int8"])
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "t%dm%dk%dn%d" % s)
def test_route_matrix_bit_exact(shape, int_w, parity_pass):
    t, m, k, n = shape
    s, w, b = make_case(hash(shape) % (1 << 31), t, m, k, n, rate=0.3,
                        int_w=int_w)
    check_route_matrix(s, w, b, t=t, parity_pass=parity_pass,
                       tag=f"shape={shape} int_w={int_w} rate=0.3")


@pytest.mark.parametrize("rate", [0.0, 0.05, 0.9, 1.0])
def test_route_matrix_occupancy_extremes(rate, parity_pass):
    """All-silent and near-saturated inputs: the sparse budget collapses to
    ~0 or the dense fold, and every route must still agree."""
    s, w, b = make_case(99, 9, 6, 21, 8, rate=rate, int_w=False)
    check_route_matrix(s, w, b, t=9, parity_pass=parity_pass,
                       tag=f"rate={rate}")


def _fuzz_case(seed):
    """Deterministic shape/occupancy generator: everything derives from the
    seed, so the seed in a failure message reproduces the case exactly."""
    r = np.random.default_rng(seed)
    t = int(r.integers(1, 13))
    m = int(r.integers(1, 24))
    k = int(r.integers(1, 49))
    n = int(r.integers(1, 24))
    rate = float(r.uniform(0.02, 0.95))
    int_w = bool(r.integers(0, 2))
    return t, m, k, n, rate, int_w


@pytest.mark.parametrize("seed", range(6))
def test_fuzzed_route_matrix_bit_exact(seed, parity_pass):
    t, m, k, n, rate, int_w = _fuzz_case(seed)
    tag = (f"fuzz seed={seed} -> t={t} m={m} k={k} n={n} "
           f"rate={rate:.3f} int_w={int_w}")
    s, w, b = make_case(seed + (1 << 20), t, m, k, n, rate=rate, int_w=int_w)
    check_route_matrix(s, w, b, t=t, parity_pass=parity_pass, tag=tag)
    parity_pass({"fuzz": 1})


def test_pallas_block_tiling_is_exact(parity_pass):
    """Grid tiling must not change the fold: shrunken bm/bn/bc blocks force
    a multi-tile (P, M/bm, N/bn, C/bc) grid on a small shape, and the
    result stays bit-identical to the untiled call and the CPU fold —
    per-chunk adds carried through the accumulator scratch preserve the
    exact ascending-chunk order across tile steps."""
    r = np.random.default_rng(11)
    idx = jnp.asarray(r.integers(0, 256, (3, 13, 5)).astype(np.uint8))
    for dt in (np.float32, np.int8):
        w = jnp.asarray((r.normal(size=(40, 21)) * 3).astype(dt))
        tbl = lut.build_lut(w)
        want = lut.lut_matmul(idx, tbl)
        exact(lut.lut_matmul_pallas(idx, tbl), want,
              msg=f"untiled {np.dtype(dt).name}")
        exact(lut.lut_matmul_pallas(idx, tbl, bm=4, bn=8, bc=2), want,
              msg=f"tiled bm=4 bn=8 bc=2 {np.dtype(dt).name}")
        parity_pass({"pallas_lut_tiled": 1})


def test_sssc_pallas_lut_bit_exact(parity_pass):
    """The value-plane (SSSC) entry point through the Pallas gather: same
    defined fold, same oracle."""
    r = np.random.default_rng(7)
    x = jnp.asarray(r.integers(0, 256, (3, 5, 21)).astype(np.uint8))
    w = jnp.asarray(r.normal(size=(21, 9)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(9,)).astype(np.float32))
    tbl = lut.build_lut(w)
    want = ops.sssc_linear(x, w, b, route="lut", table=tbl, pallas=False)
    got = ops.sssc_linear(x, w, b, route="lut", table=tbl, pallas=True)
    exact(got, want, msg="sssc pallas lut")
    parity_pass({"sssc_pallas_lut": 1})


# ---------------------------------------------------------------------------
# end to end: every registered backend vs a reference partner compiled from
# the SUBJECT's resolved plan (routes pinned — replay, not re-derivation)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(SpikformerConfig().scaled(), depth=1)
    params = init(jax.random.PRNGKey(0), cfg)
    img = jax.random.randint(jax.random.PRNGKey(1), (2, 32, 32, 3), 0, 256,
                             jnp.uint8)
    return cfg, params, img


@pytest.fixture(scope="module")
def reference_logits(tiny):
    """Reference-partner logits keyed by the subject's resolved plan —
    subjects that resolved to the same (weight_dtype, routes) share one
    partner compile, which is also itself a parity statement: ONE float
    execution is the contract for every packed route plan that pins it."""
    cfg, params, img = tiny
    cache = {}

    def get(subject):
        plan = dataclasses.replace(subject.plan, backend="reference",
                                   backend_options={})
        key = plan.to_json()
        if key not in cache:
            partner = infer_compile(params, cfg, plan)
            assert partner.plan.routes == subject.plan.routes  # replayed
            cache[key] = np.asarray(partner.logits(img))
        return cache[key]

    return get


@pytest.mark.parametrize("weight_dtype", ["float32", "int8"])
@pytest.mark.parametrize("backend", sorted(list_backends()))
def test_e2e_backend_matches_pinned_reference(tiny, reference_logits,
                                              backend, weight_dtype,
                                              parity_pass):
    cfg, params, img = tiny
    subject = infer_compile(
        params, cfg,
        ExecutionPlan(backend=backend, weight_dtype=weight_dtype,
                      batch_buckets=(2,),
                      backend_options=BACKEND_OPTIONS.get(backend, {})))
    assert subject.plan.routes
    exact(subject.logits(img), reference_logits(subject),
          msg=f"e2e {backend}/{weight_dtype}")
    for r in set(subject.plan.routes.values()):
        parity_pass({f"e2e:{backend}:{r}": 1})


def test_e2e_pallas_tail_timesteps_lut_pin(tiny, parity_pass):
    """T=9 (a tail plane group) through the Pallas backend with the global
    "lut" route pin — the float bit-exactness configuration — against a
    reference partner replaying the same pinned plan. Narrow model (the
    interpret-mode kernel work scales with T x C); the tail-T kernel math
    itself is swept wider at the op level above."""
    _, _, img = tiny
    cfg9 = dataclasses.replace(SpikformerConfig().scaled(dim=32), depth=1,
                               timesteps=9)
    params = init(jax.random.PRNGKey(0), cfg9)
    subject = infer_compile(
        params, cfg9,
        ExecutionPlan(backend="packed_pallas", route="lut",
                      batch_buckets=(2,),
                      backend_options={"interpret": True}))
    assert subject.plan.routes
    assert all(r == "lut" for r in subject.plan.routes.values())
    partner = infer_compile(
        params, cfg9, dataclasses.replace(subject.plan, backend="reference",
                                          backend_options={}))
    exact(subject.logits(img), partner.logits(img), msg="e2e pallas t=9 lut")
    parity_pass({"e2e:packed_pallas:lut_pin_t9": 1})
