"""The event-stream workload (``repro.events``).

Three layers, each pinned to an existing contract rather than trusted:

* the ENCODER is proved against the packing oracle — for every tail
  shape (T in {1, 8, 9, 16, 17}), both polarities, and the empty window,
  ``encode_events_to_plane_groups`` must be bit-identical to
  ``core.spike.pack_timesteps`` of a dense rasterization of the same
  events, and its occupancy readout must agree with the jax-side
  ``infer.backends.chunk_occupancy`` the sparse route calibrates from;
* the SESSION is checked against the serving protocol with a scripted
  fake client (watermark windowing, late-event rejection, QueueFull
  shedding — all deterministic, no threads) and end-to-end against the
  real async runtime (labels land, capture→save→load→replay closes the
  loop);
* the TRACE format replays deterministically: the committed fixture
  through one runtime twice → bit-identical labels; through a
  2-replica fleet → the same labels again; and a re-recorded file is
  byte-identical to what was loaded.
"""
import dataclasses
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.core.spike import pack_timesteps, packed_occupancy
from repro.core.spikformer import SpikformerConfig, init
from repro.events import (POLARITIES, EventStream, EventStreamSession,
                          EventTrace, TraceArrival, empty_stream,
                          encode_events_to_plane_groups, events_to_frame,
                          flicker_burst_events, labels_checksum, load_trace,
                          merge_streams, moving_edge_events, rasterize_events,
                          record_trace, replay_trace, trace_to_load,
                          window_occupancy)
from repro.infer import ExecutionPlan, chunk_occupancy
from repro.infer import compile as infer_compile
from repro.serve import AsyncServeRuntime, QueueFull, ServeFleet, ServePolicy

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
FIXTURE = REPO_ROOT / "benchmarks" / "traces" / "dvs_synth_mini.jsonl"

H = W = 16


def busy_stream(duration_us=20_000, seed=0):
    """A merged moving-edge + flicker stream that exercises both
    polarities and both sparse and dense windows."""
    return merge_streams(
        moving_edge_events(height=H, width=W, duration_us=duration_us,
                           seed=seed),
        flicker_burst_events(height=H, width=W, duration_us=duration_us,
                             seed=seed + 1, bursts=2, events_per_burst=150))


# ---------------------------------------------------------------------------
# encoder: bit-exact against the dense rasterize + pack oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", [1, 8, 9, 16, 17])
def test_encode_bit_exact_vs_pack_timesteps(t):
    ev = busy_stream()
    window_us = 20_000 // t
    direct = encode_events_to_plane_groups(ev, t=t, window_us=window_us)
    dense = rasterize_events(ev, t=t, window_us=window_us)
    oracle = np.asarray(pack_timesteps(jax.numpy.asarray(dense)))
    assert direct.shape == (-(-t // 8), H, W, POLARITIES)
    assert direct.dtype == np.uint8
    np.testing.assert_array_equal(direct, oracle)
    assert direct.any(), "a busy stream must set bits"
    # both polarities present in the encoding, not just in the stream
    assert direct[..., 0].any() and direct[..., 1].any()


def test_encode_empty_window():
    ev = empty_stream(H, W)
    planes = encode_events_to_plane_groups(ev, t=9, window_us=100)
    assert planes.shape == (2, H, W, POLARITIES)
    assert not planes.any()
    oracle = np.asarray(pack_timesteps(jax.numpy.asarray(
        rasterize_events(ev, t=9, window_us=100))))
    np.testing.assert_array_equal(planes, oracle)


def test_encode_trailing_bits_stay_zero():
    # t=9: the second group may only ever use bit 0 — the packing
    # invariant every popcount readout relies on
    planes = encode_events_to_plane_groups(busy_stream(), t=9,
                                           window_us=20_000 // 9)
    assert not (planes[1] & 0xFE).any()


def test_encode_window_slicing_and_t0():
    ev = busy_stream()
    # events outside [t0, t0 + t*window_us) are ignored, not wrapped
    tight = encode_events_to_plane_groups(ev, t=4, window_us=1_000)
    full = encode_events_to_plane_groups(
        ev.slice_time(0, 4_000), t=4, window_us=1_000)
    np.testing.assert_array_equal(tight, full)
    # a shifted stream with a matching t0 encodes identically
    shifted = encode_events_to_plane_groups(
        ev.shift_time(7_000), t=4, window_us=1_000, t0_us=7_000)
    np.testing.assert_array_equal(tight, shifted)


def test_encoder_validates_arguments():
    ev = empty_stream(H, W)
    with pytest.raises(ValueError, match="t must be"):
        encode_events_to_plane_groups(ev, t=0, window_us=10)
    with pytest.raises(ValueError, match="window_us"):
        encode_events_to_plane_groups(ev, t=8, window_us=0)


# ---------------------------------------------------------------------------
# EventStream: loud validation at the door
# ---------------------------------------------------------------------------

def test_event_stream_validation():
    z = np.zeros(2, np.int64)
    with pytest.raises(ValueError, match="parallel"):
        EventStream(H, W, z, z, z, np.zeros(3, np.int64))
    with pytest.raises(ValueError, match="x values"):
        EventStream(H, W, np.array([0, W]), z, z, z)
    with pytest.raises(ValueError, match="y values"):
        EventStream(H, W, z, np.array([-1, 0]), z, z)
    with pytest.raises(ValueError, match="polarity values"):
        EventStream(H, W, z, z, z, np.array([0, 2]))
    with pytest.raises(ValueError, match="sorted non-decreasing"):
        EventStream(H, W, z, z, np.array([5, 3]), z)
    with pytest.raises(ValueError, match="at least 1x1"):
        EventStream(0, W, z, z, z, z)


def test_slice_shift_merge():
    ev = busy_stream()
    part = ev.slice_time(5_000, 10_000)
    assert len(part) and all(5_000 <= t < 10_000 for t in part.t_us)
    back = part.shift_time(-5_000)
    assert int(back.t_us[0]) == int(part.t_us[0]) - 5_000
    m = merge_streams(ev.slice_time(0, 5_000), ev.slice_time(5_000, 99_999))
    np.testing.assert_array_equal(m.t_us, ev.t_us)
    np.testing.assert_array_equal(m.x, ev.x)
    with pytest.raises(ValueError, match="different sensors"):
        merge_streams(ev, empty_stream(H, W + 1))


def test_generators_deterministic():
    a = moving_edge_events(height=H, width=W, duration_us=10_000, seed=3)
    b = moving_edge_events(height=H, width=W, duration_us=10_000, seed=3)
    np.testing.assert_array_equal(a.t_us, b.t_us)
    np.testing.assert_array_equal(a.x, b.x)
    c = moving_edge_events(height=H, width=W, duration_us=10_000, seed=4)
    assert len(a) != len(c) or not np.array_equal(a.t_us, c.t_us)
    f1 = flicker_burst_events(height=H, width=W, duration_us=10_000, seed=3)
    f2 = flicker_burst_events(height=H, width=W, duration_us=10_000, seed=3)
    np.testing.assert_array_equal(f1.x, f2.x)
    # construction re-validates bounds, so reaching here means in-range;
    # still pin the timestamps inside the requested duration
    assert int(a.t_us[-1]) < 10_000 and int(f1.t_us[-1]) < 10_000


# ---------------------------------------------------------------------------
# readouts: occupancy agreement with the jax side, count frames
# ---------------------------------------------------------------------------

def test_window_occupancy_matches_jax_chunk_occupancy():
    for t in (8, 9, 16):
        planes = encode_events_to_plane_groups(
            busy_stream(), t=t, window_us=20_000 // t)
        ours = window_occupancy(planes, t=t)
        jaxs = chunk_occupancy(jax.numpy.asarray(planes), t)
        assert ours == pytest.approx(jaxs, abs=1e-6), t
        assert 0.0 < ours <= 1.0
    with pytest.raises(ValueError, match="plane groups"):
        window_occupancy(np.zeros((1, H, W, 2), np.uint8), t=9)


def test_packed_occupancy_firing_rate():
    # one event -> one bit: firing rate is exactly bits / (t * neurons)
    ev = EventStream(H, W, np.array([2]), np.array([3]),
                     np.array([0]), np.array([1]))
    planes = encode_events_to_plane_groups(ev, t=8, window_us=10)
    assert packed_occupancy(planes, 8) == pytest.approx(
        1.0 / (8 * H * W * POLARITIES))
    assert packed_occupancy(np.zeros((1, H, W, 2), np.uint8), 8) == 0.0


def test_events_to_frame_counts_and_clip():
    n = 7
    ev = EventStream(H, W, np.full(n, 4), np.full(n, 5),
                     np.arange(n), np.full(n, 1))
    frame = events_to_frame(ev)
    assert frame.shape == (H, W, POLARITIES) and frame.dtype == np.uint8
    assert frame[5, 4, 1] == n and frame.sum() == n
    assert events_to_frame(ev, clip=3)[5, 4, 1] == 3
    with pytest.raises(ValueError, match="clip"):
        events_to_frame(ev, clip=0)


# ---------------------------------------------------------------------------
# session: windowing semantics against a scripted fake client
# ---------------------------------------------------------------------------

class FakeHandle:
    def __init__(self, labels):
        self.labels = labels

    def result(self, timeout=None):
        return self.labels


class FakeClient:
    """Scripted ServeClient: labels each image with a running counter,
    synchronously, and raises QueueFull for windows in ``full_at``."""

    def __init__(self, full_at=()):
        self.full_at = set(full_at)
        self.attempts = 0
        self.submissions = []

    def submit(self, images, *, rid=None, on_image=None):
        k = self.attempts
        self.attempts += 1
        if k in self.full_at:
            raise QueueFull("scripted")
        self.submissions.append(np.asarray(images))
        if on_image is not None:
            for i in range(len(images)):
                on_image(k, i, k)
        return FakeHandle([k] * len(images))


def session_over(client, **kw):
    kw.setdefault("window_us", 1_000)
    kw.setdefault("height", H)
    kw.setdefault("width", W)
    return EventStreamSession(client, **kw)


def events_at(*t_us, x=1, y=1, p=1):
    t = np.asarray(t_us, np.int64)
    n = len(t)
    return EventStream(H, W, np.full(n, x), np.full(n, y), t, np.full(n, p))


def test_session_watermark_windowing():
    client = FakeClient()
    seen = []
    s = session_over(client, on_window=lambda w, lab: seen.append((w, lab)))
    s.feed(events_at(100, 900))            # window 0, still open
    assert not client.submissions
    s.feed(events_at(1_100))               # watermark crosses 1_000: closes 0
    assert len(client.submissions) == 1
    assert client.submissions[0].shape == (1, H, W, POLARITIES)
    assert client.submissions[0][0, 1, 1, 1] == 2       # both window-0 events
    s.feed(events_at(5_500))               # closes 1..4; 1-4 empty -> skipped
    assert len(client.submissions) == 2
    s.close()                              # flush window 5
    assert len(client.submissions) == 3
    st = s.stats()
    assert st["windows_submitted"] == 3 and st["windows_empty"] == 3
    assert st["windows_closed"] == 6 and st["events_seen"] == 4
    assert s.labels() == {0: 0, 1: 1, 5: 2}
    assert seen == [(0, 0), (1, 1), (5, 2)]
    assert len(s.occupancy_trace()) == 3
    assert all(0 < occ <= 1 for occ in s.occupancy_trace())


def test_session_submit_empty_serves_quiet_windows():
    client = FakeClient()
    s = session_over(client, submit_empty=True)
    s.feed(events_at(100))
    s.feed(events_at(3_500))               # closes 0, 1, 2 (1 and 2 empty)
    assert len(client.submissions) == 3
    assert not client.submissions[1].any()
    assert s.stats()["windows_empty"] == 0


def test_session_late_events_raise():
    s = session_over(FakeClient())
    s.feed(events_at(2_500))               # closes 0 and 1
    with pytest.raises(ValueError, match="precedes the open window"):
        s.feed(events_at(1_500))
    # equal-time and later events are fine
    s.feed(events_at(2_600))


def test_session_sheds_on_queue_full():
    client = FakeClient(full_at={1})
    s = session_over(client)
    s.feed(events_at(500))
    s.feed(events_at(1_500))               # closes 0 (submitted)
    s.feed(events_at(2_500))               # closes 1 (shed)
    s.close()
    assert s.windows_shed == 1
    assert [r["shed"] for r in s.windows] == [False, True, False]
    assert s.windows[1]["label"] is None
    st = s.stats()
    assert st["windows_submitted"] == 2 and st["windows_shed"] == 1


def test_session_validates_construction_and_sensor():
    with pytest.raises(ValueError, match="window_us"):
        session_over(FakeClient(), window_us=0)
    with pytest.raises(ValueError, match="bins"):
        session_over(FakeClient(), bins=3)   # 3 does not divide 1000
    s = session_over(FakeClient())
    with pytest.raises(ValueError, match="sensor"):
        s.feed(empty_stream(H, W + 1).shift_time(0))
    with pytest.raises(ValueError, match="capture=False"):
        s.save_trace("/tmp/never_written.jsonl")


def test_session_capture_records_window_relative_events(tmp_path):
    fake_now = [0.0]
    client = FakeClient()
    s = session_over(client, capture=True, clock=lambda: fake_now[0])
    s.feed(events_at(100, 800))
    fake_now[0] = 0.5
    s.feed(events_at(1_200))
    s.close()
    assert [w for _, w, _ in s.captured] == [0, 1]
    t_s, w, ev = s.captured[0]
    assert list(ev.t_us) == [100, 800]     # window 0: already relative
    _, _, ev1 = s.captured[1]
    assert list(ev1.t_us) == [200]         # 1_200 relative to window 1
    path = tmp_path / "cap.jsonl"
    assert s.save_trace(path) == 2
    loaded = load_trace(path)
    assert loaded.window_us == 1_000 and len(loaded.arrivals) == 2
    np.testing.assert_array_equal(loaded.arrivals[1].events.t_us, [200])


# ---------------------------------------------------------------------------
# trace format: roundtrip, loud failures, checksum
# ---------------------------------------------------------------------------

def test_trace_roundtrip_byte_identical(tmp_path):
    p1, p2 = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    ev = busy_stream(duration_us=900)
    arrivals = [TraceArrival(t_s=0.1, window=0, events=ev),
                TraceArrival(t_s=0.3, window=2,
                             events=empty_stream(H, W))]
    record_trace(p1, height=H, width=W, window_us=1_000, bins=8,
                 arrivals=arrivals, meta={"k": 1})
    t = load_trace(p1)
    assert (t.height, t.width, t.window_us, t.bins) == (H, W, 1_000, 8)
    assert t.payload == "events" and t.meta == {"k": 1}
    assert t.duration_s == pytest.approx(0.3)
    np.testing.assert_array_equal(t.arrivals[0].events.x, ev.x)
    record_trace(p2, height=t.height, width=t.width, window_us=t.window_us,
                 bins=t.bins, arrivals=t.arrivals, meta=t.meta)
    assert p1.read_bytes() == p2.read_bytes()


def test_trace_load_fails_loud(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text("")
    with pytest.raises(ValueError, match="empty trace"):
        load_trace(p)
    p.write_text(json.dumps({"kind": "something_else"}) + "\n")
    with pytest.raises(ValueError, match="not a 'event_serve_trace'"):
        load_trace(p)
    p.write_text(json.dumps({"kind": "event_serve_trace",
                             "trace_version": 99}) + "\n")
    with pytest.raises(ValueError, match="trace_version=99"):
        load_trace(p)


def test_record_trace_validates(tmp_path):
    p = tmp_path / "t.jsonl"
    with pytest.raises(ValueError, match="payload"):
        record_trace(p, height=H, width=W, window_us=1_000, bins=8,
                     arrivals=[], payload="frames")
    bad = [TraceArrival(t_s=0.2), TraceArrival(t_s=0.1)]
    with pytest.raises(ValueError, match="time order"):
        record_trace(p, height=H, width=W, window_us=1_000, bins=8,
                     arrivals=bad, payload="counts")
    with pytest.raises(ValueError, match="no events"):
        record_trace(p, height=H, width=W, window_us=1_000, bins=8,
                     arrivals=[TraceArrival(t_s=0.1)])


def test_counts_payload_roundtrip_and_load(tmp_path):
    p = tmp_path / "counts.jsonl"
    arrivals = [TraceArrival(t_s=0.01, n_images=2),
                TraceArrival(t_s=0.02, n_images=1)]
    record_trace(p, height=H, width=W, window_us=1_000, bins=8,
                 arrivals=arrivals, payload="counts",
                 meta={"image_seed": 5})
    t = load_trace(p)
    assert [a.n_images for a in t.arrivals] == [2, 1]
    load, make = trace_to_load(t)
    assert [a.n_images for a in load] == [2, 1]
    imgs = make(0, 2)
    assert imgs.shape == (2, H, W, POLARITIES) and imgs.dtype == np.uint8


def test_trace_to_load_events_payload_is_replay_stable():
    ev = busy_stream(duration_us=900)
    t = EventTrace(height=H, width=W, window_us=1_000, bins=8,
                   payload="events",
                   arrivals=(TraceArrival(t_s=0.1, events=ev),))
    _, make1 = trace_to_load(t)
    _, make2 = trace_to_load(t)
    np.testing.assert_array_equal(make1(0, 1), make2(0, 1))
    np.testing.assert_array_equal(make1(0, 1)[0], events_to_frame(ev))


def test_labels_checksum_stable():
    a = labels_checksum([[1, 2], None, [3]])
    assert a == labels_checksum([[1, 2], None, [3]])
    assert len(a) == 16
    assert a != labels_checksum([[1, 2], None, [4]])


# ---------------------------------------------------------------------------
# end to end: the committed fixture replays deterministically through the
# real serving stack, 1 runtime and a 2-replica fleet
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dvs_model():
    trace = load_trace(FIXTURE)
    cfg = dataclasses.replace(
        SpikformerConfig().scaled(img_size=trace.height, dim=32, depth=1),
        in_channels=trace.channels)
    params = init(jax.random.PRNGKey(0), cfg)
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    model.warmup()
    return trace, model


def test_fixture_is_committed_and_bursty():
    trace = load_trace(FIXTURE)
    assert trace.payload == "events" and trace.arrivals
    assert (trace.height, trace.width) == (16, 16)
    # the fixture's reason to exist: a bursty (non-Poisson) arrival gap
    # structure — silent stretches between event bursts
    gaps = np.diff([a.t_s for a in trace.arrivals])
    assert gaps.max() > 3 * np.median(gaps)


def test_replay_fixture_deterministic_one_runtime(dvs_model):
    trace, model = dvs_model
    policy = ServePolicy(max_wait_ms=10.0, slo_ms=2_000.0,
                         max_queue_images=64)

    def once():
        with AsyncServeRuntime(model, policy=policy) as rt:
            return replay_trace(trace, rt, slo_ms=2_000.0)

    m1, m2 = once(), once()
    assert m1["requests_dropped"] == 0 and m1["requests_rejected"] == 0
    assert m1["windows"] == len(trace.arrivals)
    assert all(lab is not None and len(lab) == 1 for lab in m1["labels"])
    assert m1["labels_sha"] == m2["labels_sha"]
    assert m1["labels"] == m2["labels"]
    assert m1["dispersion_index"] is not None


def test_replay_fixture_fleet_matches_single_replica(dvs_model):
    trace, model = dvs_model
    policy = ServePolicy(max_wait_ms=10.0, slo_ms=2_000.0,
                         max_queue_images=64)
    with AsyncServeRuntime(model, policy=policy) as rt:
        single = replay_trace(trace, rt, slo_ms=2_000.0)
    with ServeFleet(model, replicas=2, policy=policy) as fleet:
        dual = replay_trace(trace, fleet, slo_ms=2_000.0)
    assert dual["requests_dropped"] == 0 and dual["requests_rejected"] == 0
    assert dual["labels_sha"] == single["labels_sha"]
    assert dual["labels"] == single["labels"]


def test_session_capture_replay_reproduces_live_labels(dvs_model, tmp_path):
    """The full loop: a live session over the real runtime, captured,
    saved, loaded, replayed — the replay's labels equal the live run's."""
    trace, model = dvs_model
    policy = ServePolicy(max_wait_ms=10.0, slo_ms=2_000.0,
                         max_queue_images=64)
    stream = busy_stream(duration_us=60_000, seed=9)
    with AsyncServeRuntime(model, policy=policy) as rt:
        s = EventStreamSession(rt, window_us=20_000, height=H, width=W,
                               capture=True)
        s.feed(stream)
        s.close()
        live = [[s.windows[k]["label"]] for k in range(len(s.windows))]
        path = tmp_path / "live.jsonl"
        s.save_trace(path)
    with AsyncServeRuntime(model, policy=policy) as rt2:
        m = replay_trace(load_trace(path), rt2, slo_ms=2_000.0)
    assert m["labels"] == live
