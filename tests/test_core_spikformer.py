"""The paper's contribution: spike packing, LIF/TFLIF + BN folding, the four
unified dataflows (ZSC/SSSC/WSSL/STDP), and Spikformer V2 end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lif, spike, unified
from repro.core.spikformer import (SpikformerConfig, init, apply, loss_fn,
                                   fold_inference_params, merge_bn_stats)


# ---------------------------------------------------------------------------
# spike packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(10))
def test_pack_unpack_roundtrip(seed):
    n = int(np.random.default_rng(seed).integers(1, 17))
    bits = (jax.random.uniform(jax.random.PRNGKey(seed), (3, 8 * n)) < 0.5)
    packed = spike.pack_bits(bits.astype(jnp.float32))
    assert packed.shape == (3, n) and packed.dtype == jnp.uint8
    unpacked = spike.unpack_bits(packed)
    np.testing.assert_array_equal(np.asarray(unpacked),
                                  np.asarray(bits, np.float32))


def test_bitplanes_reconstruct_uint8():
    x = jnp.arange(256, dtype=jnp.uint8).reshape(16, 16)
    planes = spike.bitplanes_u8(x)                       # (8, 16, 16)
    recon = sum(planes[p] * (2.0 ** p) for p in range(8))
    np.testing.assert_array_equal(np.asarray(recon, np.uint8), np.asarray(x))


def test_space_to_depth_is_exact_conv_patches():
    x = jnp.arange(2 * 4 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 4, 3)
    y = spike.space_to_depth(x, 2)
    assert y.shape == (2, 2, 2, 12)
    # top-left 2x2 patch of batch 0, channel-major order
    np.testing.assert_array_equal(
        np.asarray(y[0, 0, 0]),
        np.asarray(jnp.stack([x[0, 0, 0], x[0, 0, 1],
                              x[0, 1, 0], x[0, 1, 1]]).reshape(-1)))


# ---------------------------------------------------------------------------
# LIF dynamics + surrogate
# ---------------------------------------------------------------------------

def test_lif_fires_and_resets():
    v, s = lif.lif_step(jnp.zeros(3), jnp.array([4.0, 0.1, 2.0]))
    np.testing.assert_array_equal(np.asarray(s), [1.0, 0.0, 1.0])
    # fired neurons reset to 0
    assert float(v[0]) == 0.0 and float(v[2]) == 0.0
    assert float(v[1]) > 0.0


def test_lif_subthreshold_accumulates():
    """Constant input below threshold accumulates toward x (tau=2 charge)."""
    v = jnp.zeros(1)
    for _ in range(10):
        v, s = lif.lif_step(v, jnp.array([0.9]))
        assert float(s[0]) == 0.0
    assert 0.8 < float(v[0]) < 0.9   # converges to x from below


def test_surrogate_gradient_nonzero():
    g = jax.grad(lambda u: lif.spike_fn(u).sum())(jnp.array([-0.5, 0.0, 0.5]))
    assert (np.asarray(jnp.abs(g)) > 0).all()
    # peaked at the threshold
    assert float(g[1]) > float(g[0]) and float(g[1]) > float(g[2])


def test_tflif_scan_equals_stepwise():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 2
    fused = lif.tflif(x)
    v = jnp.zeros(64)
    outs = []
    for t in range(4):
        v, s = lif.lif_step(v, x[t])
        outs.append(s)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(jnp.stack(outs)))


# ---------------------------------------------------------------------------
# BN folding — the TFLIF merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(10))
def test_fold_bn_exact(seed):
    """BN(x @ k + b) == x @ k' + b' after folding (inference stats)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (5, 8))
    kern = jax.random.normal(ks[1], (8, 6))
    bias = jax.random.normal(ks[2], (6,))
    bn = {
        "scale": jax.random.normal(ks[3], (6,)) + 1.5,
        "bias": jax.random.normal(ks[0], (6,)),
        "mean": jax.random.normal(ks[1], (6,)),
        "var": jax.random.uniform(ks[2], (6,), minval=0.1, maxval=2.0),
    }
    want = lif.bn_apply(bn, x @ kern + bias)
    kf, bf = lif.fold_bn(kern, bias, bn)
    got = x @ kf + bf
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# the four unified dataflows
# ---------------------------------------------------------------------------

def test_wssl_equals_per_timestep_linear():
    """T-folded weight-stationary linear == per-timestep x @ W."""
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    s = (jax.random.uniform(ks[0], (4, 2, 10, 16)) < 0.3).astype(jnp.float32)
    w = jax.random.normal(ks[1], (16, 8))
    got = unified.wssl(s, w)
    want = jnp.stack([s[t].reshape(-1, 16) @ w for t in range(4)]
                     ).reshape(4, 2, 10, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_zsc_equals_lax_conv():
    """Zig-zag spiking conv (space-to-depth matmul) == real 2x2/s2 conv."""
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    s = (jax.random.uniform(ks[0], (4, 2, 8, 8, 3)) < 0.4).astype(jnp.float32)
    kern = jax.random.normal(ks[1], (2, 2, 3, 5))
    got = unified.zsc(s, kern)                           # (4,2,4,4,5)
    x = s.reshape(8, 8, 8, 3)
    want = jax.lax.conv_general_dilated(
        x, kern, window_strides=(2, 2), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")).reshape(4, 2, 4, 4, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_sssc_equals_uint8_conv():
    """Shift-and-sum bit-plane conv == direct 8-bit conv (exact in fp32)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    img = jax.random.randint(ks[0], (2, 8, 8, 3), 0, 256, jnp.uint8)
    kern = jax.random.normal(ks[1], (2, 2, 3, 4))
    got = unified.sssc(img, kern)
    want = jax.lax.conv_general_dilated(
        img.astype(jnp.float32), kern, window_strides=(2, 2), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-2)


def test_stdp_never_materializes_nxn_and_matches():
    """unified.stdp (K^TV-first associativity) == naive (QK^T)V."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q, k, v = [(jax.random.uniform(kk, (4, 1, 2, 32, 16)) < 0.3)
               .astype(jnp.float32) for kk in ks]
    got = unified.stdp(q, k, v, scale=0.125)
    scores = jnp.einsum("tbhnd,tbhmd->tbhnm", q, k)
    want = jnp.einsum("tbhnm,tbhmf->tbhnf", scores, v) * 0.125
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Spikformer V2 end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    cfg = SpikformerConfig().scaled()
    params = init(jax.random.PRNGKey(0), cfg)
    img = jax.random.randint(jax.random.PRNGKey(1), (2, 32, 32, 3), 0, 256,
                             jnp.uint8)
    return cfg, params, img


def test_spikformer_shapes_no_nan(small):
    cfg, params, img = small
    logits, _ = apply(params, img, cfg)
    assert logits.shape == (2, cfg.num_classes)
    assert not bool(jnp.isnan(logits).any())


def test_spikformer_activations_strictly_binary(small):
    """The IAND residual keeps every inter-layer activation in {0,1} — the
    property VESTA's whole datapath depends on. Instrument by checking the
    residual combine output on random spike inputs."""
    from repro.core.spikformer import _combine
    a = (jax.random.uniform(jax.random.PRNGKey(0), (100,)) < 0.5).astype(jnp.float32)
    b = (jax.random.uniform(jax.random.PRNGKey(1), (100,)) < 0.5).astype(jnp.float32)
    out = _combine(a, b, "iand")
    assert set(np.unique(np.asarray(out))) <= {0.0, 1.0}


def test_spikformer_train_step_reduces_loss(small):
    cfg, params, img = small
    batch = {"image": img, "label": jnp.array([3, 7])}

    @jax.jit
    def step(p):
        (l, (acc, stats)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(p, batch, cfg)
        p2 = jax.tree_util.tree_map(lambda w, gw: w - 0.5 * gw, p, g)
        return l, p2

    l0, params = step(params)
    for _ in range(8):
        l1, params = step(params)
    assert float(l1) < float(l0)


def test_bn_fold_inference_equivalence(small):
    """Folded inference params (matmul+LIF only graph) == train-mode graph
    with inference BN, on the SAME spike trajectory."""
    cfg, params, img = small
    logits_ref, _ = apply(params, img, cfg, train=False)

    folded = fold_inference_params(params, cfg)
    # run the folded graph manually: conv stem (as matmuls) + blocks
    from repro.core.unified import wssl, stdp
    from repro.core.spike import space_to_depth, bitplanes_u8, rate_decode
    from repro.core.lif import tflif
    from repro.core.spikformer import _combine
    t = cfg.timesteps

    # SSSC layer 0 on bit-planes with folded kernel/bias
    c0 = folded["scs"]["conv0"]
    x0 = space_to_depth(img, 2)
    planes = bitplanes_u8(x0)
    per = wssl(planes, c0["kernel"])
    scales = (2.0 ** jnp.arange(8)).reshape(8, 1, 1, 1, 1)
    y = (per * scales).sum(0) + c0["bias"]
    y = jnp.broadcast_to(y[None], (t, *y.shape))
    x = tflif(y)
    for i in range(1, len(cfg.scs_channels)):
        ci = folded["scs"][f"conv{i}"]
        y = wssl(space_to_depth(x, 2), ci["kernel"]) + ci["bias"]
        x = tflif(y)
    tt, b, h, w, c = x.shape
    x = x.reshape(tt, b, h * w, c)
    for i in range(cfg.depth):
        blk = folded["blocks"][f"b{i}"]
        dh = cfg.dim // cfg.heads
        def lbl(pp, z):
            return tflif(wssl(z, pp["kernel"]) + pp["bias"])
        qs = lbl(blk["ssa"]["wq"], x)
        ks_ = lbl(blk["ssa"]["wk"], x)
        vs = lbl(blk["ssa"]["wv"], x)
        def heads(z):
            return z.reshape(tt, b, -1, cfg.heads, dh).transpose(0, 1, 3, 2, 4)
        att = stdp(heads(qs), heads(ks_), heads(vs), scale=cfg.attn_scale)
        att = tflif(att).transpose(0, 1, 3, 2, 4).reshape(tt, b, -1, cfg.dim)
        att = lbl(blk["ssa"]["wo"], att)
        x = _combine(att, x, cfg.residual)
        s1 = lbl(blk["mlp"]["fc1"], x)
        s2 = lbl(blk["mlp"]["fc2"], s1)
        x = _combine(s2, x, cfg.residual)
    rate = rate_decode(x, axis=0).mean(axis=1)
    logits_folded = rate @ folded["head"]["kernel"] + folded["head"]["bias"]
    np.testing.assert_allclose(np.asarray(logits_folded),
                               np.asarray(logits_ref), rtol=1e-3, atol=1e-3)


def test_merge_bn_stats_roundtrip(small):
    cfg, params, img = small
    _, stats = apply(params, img, cfg, train=True)
    merged = merge_bn_stats(params, stats)
    # running stats moved away from init (mean 0 / var 1)
    bn = merged["scs"]["conv0"]["bn"]
    assert float(jnp.abs(bn["mean"]).max()) > 0.0
