"""The 40-cell grid wiring: every (arch x shape) is addressable, input specs
have the right shapes/dtypes, skip rules fire exactly where the brief says,
and cache specs stay within HBM budgets analytically."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import (ARCH_IDS, SHAPES, get_config,
                                cell_applicable, input_specs)

ALL_CELLS = [(a, s) for a in ARCH_IDS for s in SHAPES]


def test_grid_is_40_cells():
    assert len(ALL_CELLS) == 40


@pytest.mark.parametrize("arch,shape_name", ALL_CELLS)
def test_input_specs_shapes(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        assert shape_name == "long_500k" and not cfg.subquadratic
        return
    spec = input_specs(cfg, shape)
    toks = spec["batch"]["tokens"]
    if shape.kind == "train":
        assert toks.shape == (shape.batch, shape.seq)
        assert spec["batch"]["labels"].shape == (shape.batch, shape.seq)
    elif shape.kind == "prefill":
        assert toks.shape == (shape.batch, shape.seq)
    else:  # decode: one token against a seq-long cache
        assert toks.shape == (shape.batch, 1)
        assert "cache" in spec
    if cfg.family == "vlm" and shape.kind != "decode":
        assert spec["batch"]["image_embeds"].shape[1] == cfg.img_tokens
        assert spec["batch"]["mrope_positions"].shape[0] == 3
    if cfg.family == "encdec" and shape.kind != "decode":
        assert spec["batch"]["frames"].shape == (
            shape.batch, cfg.n_frames, cfg.d_model)


def test_long500k_runs_only_for_subquadratic():
    expect_run = {"mamba2-130m", "hymba-1.5b"}
    got = {a for a in ARCH_IDS
           if cell_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert got == expect_run


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_cache_fits_hbm_budget(arch):
    """Analytic per-chip cache bytes for decode_32k under the
    cache_shardings layout: KV tensors shard batch/dp x seq/model; SSM and
    positions shard batch/dp only."""
    cfg = get_config(arch)
    from repro.nn import transformer as T
    from repro.nn.module import map_with_path
    shape = SHAPES["decode_32k"]
    cache = jax.eval_shape(lambda: T.init_cache(cfg, shape.batch, shape.seq))
    dp, tp = 16, 16
    per_chip = 0

    def add(path, leaf):
        nonlocal per_chip
        b = leaf.size * leaf.dtype.itemsize
        if any(path.endswith(sfx) for sfx in ("kv/k", "kv/v", "cross_k",
                                              "cross_v")):
            per_chip += b / (dp * tp)
        else:
            per_chip += b / dp
        return leaf

    map_with_path(add, cache)
    assert per_chip < 8e9, f"{arch}: {per_chip/1e9:.1f}GB cache per chip"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_and_opt_fit_hbm_budget(arch):
    """params + AdamW moments + grad accumulator, FSDPxTP over 256 chips,
    must leave headroom under 16 GB."""
    cfg = get_config(arch)
    n = cfg.n_params()
    pbytes = 2 if cfg.param_dtype == "bfloat16" else 4
    obytes = 2 * (2 if cfg.opt_state_dtype == "bfloat16" else 4)
    gbytes = 2 if cfg.opt_state_dtype == "bfloat16" else 4
    per_chip = n * (pbytes + obytes + gbytes) / 256
    # arctic-480b is the tightest at 14.9 GB/chip (bf16 params+moments+grad
    # accumulator) — fits, with activations held small by Megatron-SP seq
    # sharding; the dry-run memory_analysis is the authoritative check.
    assert per_chip < 16e9, f"{arch}: {per_chip/1e9:.1f}GB state per chip"


def test_vocab_padding_multiple_of_256():
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab


def test_reduced_configs_keep_structure():
    for a in ARCH_IDS:
        cfg = get_config(a)
        r = cfg.reduced()
        assert r.family == cfg.family
        assert (r.n_experts > 0) == (cfg.n_experts > 0)
        assert (r.sliding_window is not None) == (cfg.sliding_window is not None)
        assert (r.mrope_sections is not None) == (cfg.mrope_sections is not None)
        assert r.d_model % max(r.n_heads, 1) == 0
