"""The observability subsystem (``repro.obs``) and its serving hooks.

Four standards of proof, mirroring the serving tests:

* the TRACER is pinned exactly: ring wrap drops the OLDEST spans and
  counts them, and an injected fake clock pins the sync engine's full
  span table — timestamps and all, no tolerance;
* the HISTOGRAM is held to its documented contract: every percentile
  within ``error_bound`` of the exact nearest-rank order statistic of
  the same sample set, single samples exact, the empty window all-None;
* the span CHAIN is client-invariant: the same request trace through
  the sync engine, the async runtime, and a 2-replica fleet yields the
  identical per-rid lifecycle chain (timestamps differ, structure may
  not);
* EXPORT round-trips: the JSONL loader inverts the writer bit-exactly
  and refuses wrong-kind/wrong-version/truncated files loudly.
"""
import json
import pathlib
import sys

import jax
import numpy as np
import pytest

from repro.core.spikformer import SpikformerConfig, init
from repro.events import EventStream, EventStreamSession
from repro.infer import (ExecutionPlan, MicroBatchEngine,
                         QueueDepthWatermark, SERVE_STATS_VERSION,
                         compile as infer_compile, profile_layer_paths)
from repro.infer.engine import (Request, StepAccounting, latency_summary,
                                serve_stats)
from repro.obs import (LIFECYCLE, Counter, Gauge, LatencyHistogram,
                       MetricsRegistry, NULL_TRACER, NullTracer, Span,
                       SPANS_SCHEMA_VERSION, Tracer, load_spans_jsonl,
                       to_chrome_trace, write_chrome_trace,
                       write_spans_jsonl)
from repro.serve import (AsyncServeRuntime, ContinuousBatchingScheduler,
                         FleetScheduler, QueueFull, ServeFleet, ServePolicy)

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "scripts"))
import trace_report  # noqa: E402


@pytest.fixture(scope="module")
def small():
    cfg = SpikformerConfig().scaled(img_size=16, dim=32, depth=1)
    params = init(jax.random.PRNGKey(0), cfg)
    model = infer_compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    model.warmup()
    imgs = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (11, 16, 16, 3), 0, 256, "uint8"))
    return cfg, model, imgs


class FakeClock:
    """Ticks 1.0 per call — pins span tables exactly."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        self.t += 1.0
        return self.t


# ---------------------------------------------------------------------------
# tracer: the ring contract
# ---------------------------------------------------------------------------

def test_ring_overflow_drops_oldest_and_counts():
    tr = Tracer(capacity=4, clock=FakeClock())
    for k in range(6):
        tr.span("test", f"s{k}", t0=float(k), t1=float(k) + 0.5)
    assert len(tr) == 4
    assert tr.dropped_spans == 2
    got = tr.spans()
    # chronological, oldest SURVIVING first: s0/s1 were overwritten
    assert [s.name for s in got] == ["s2", "s3", "s4", "s5"]
    assert got[0].t0 == 2.0 and got[0].t1 == 2.5
    assert all(isinstance(s, Span) for s in got)


def test_ring_clear_preserves_drop_account():
    tr = Tracer(capacity=2)
    for k in range(3):
        tr.span("test", "x", t0=0.0)
    assert tr.dropped_spans == 1
    tr.clear()
    assert len(tr) == 0 and tr.spans() == []
    assert tr.dropped_spans == 1          # loss is history, not contents
    tr.span("test", "y", t0=9.0)          # ring still usable after clear
    assert [s.name for s in tr.spans()] == ["y"]


def test_tracer_rejects_zero_capacity():
    with pytest.raises(ValueError, match="capacity"):
        Tracer(capacity=0)


def test_tracer_injected_clock_stamps_instants():
    clock = FakeClock()
    tr = Tracer(capacity=8, clock=clock)
    tr.span("test", "bare")               # t0 defaults to the clock
    tr.counter("depth", 3, t=10.0)
    tr.counter("depth", 4)                # counter on the clock too
    bare, c1, c2 = tr.spans()
    assert bare.t0 == bare.t1 == 1.0      # instant on the injected clock
    assert (c1.category, c1.name, c1.t0, c1.value) == \
        ("counter", "depth", 10.0, 3.0)
    assert c2.t0 == 2.0 and c2.value == 4.0


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert isinstance(NULL_TRACER, NullTracer)
    NULL_TRACER.span("x", "y", t0=1.0)
    NULL_TRACER.counter("d", 1)
    assert NULL_TRACER.spans() == [] and len(NULL_TRACER) == 0
    assert NULL_TRACER.dropped_spans == 0
    assert LIFECYCLE == ("admit", "queue", "place", "assemble", "step",
                         "complete")


# ---------------------------------------------------------------------------
# metrics: counters, gauges, the bounded histogram
# ---------------------------------------------------------------------------

def test_counter_and_gauge_watermark():
    c = Counter("n")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = Gauge("depth")
    assert g.value is None and g.max is None
    for v in (3.0, 9.0, 2.0):
        g.set(v)
    assert g.value == 2.0 and g.max == 9.0  # burst peak survives the quiet


def test_queue_depth_watermark():
    w = QueueDepthWatermark()
    assert w.peak == 0                     # nothing observed yet
    for d in (3, 8, 1):
        w.observe(d)
    assert w.peak == 8
    shared = Gauge("queue_depth")
    w2 = QueueDepthWatermark(shared)
    w2.observe(5)
    assert shared.max == 5 and w2.peak == 5


def exact_nearest_rank(samples, q):
    """The exact order statistic the histogram approximates: nearest-rank
    over the sorted sample list (NOT numpy's interpolating percentile)."""
    s = sorted(samples)
    rank = max(1, int(np.ceil(q / 100.0 * len(s))))
    return s[rank - 1]


def test_histogram_percentiles_within_documented_error():
    rng = np.random.default_rng(42)
    # log-uniform latencies spanning 100us..1s — several decades, so the
    # bucket error bound is actually exercised
    samples = np.exp(rng.uniform(np.log(1e-4), np.log(1.0), 5000))
    h = LatencyHistogram()
    for v in samples:
        h.observe(float(v))
    assert h.count == 5000
    assert h.mean == pytest.approx(float(samples.sum()) / 5000)
    for q in (1, 10, 25, 50, 75, 90, 95, 99, 99.9):
        got = h.percentile(q)
        want = exact_nearest_rank(samples, q)
        assert abs(got - want) / want <= h.error_bound, \
            f"p{q}: {got} vs exact {want} beyond {h.error_bound:.3f}"
    assert h.error_bound == pytest.approx(0.05)


def test_histogram_empty_single_and_degenerate():
    h = LatencyHistogram()
    assert h.percentile(50) is None and h.mean is None
    assert h.summary() == {"latency_p50_s": None, "latency_p95_s": None,
                           "latency_p99_s": None, "latency_mean_s": None}
    h.observe(0.0123)                     # single sample: exact everywhere
    for q in (0, 50, 99, 100):
        assert h.percentile(q) == pytest.approx(0.0123)
    h2 = LatencyHistogram()
    h2.observe(0.0)                       # the empty-request latency
    assert h2.percentile(50) == 0.0       # clamped into observed [0, 0]
    h2.observe(1e9)                       # overflow bucket: the hi edge
    assert h2.percentile(100) == h2.hi    # stands in (off the log range)
    with pytest.raises(ValueError, match=">= 0"):
        h2.observe(-0.1)
    with pytest.raises(ValueError, match="growth"):
        LatencyHistogram(growth=1.0)
    with pytest.raises(ValueError, match="lo"):
        LatencyHistogram(lo=0.0)


def test_histogram_memory_is_fixed():
    h = LatencyHistogram()
    n_buckets = len(h.counts)
    for v in np.linspace(1e-5, 2.0, 1000):
        h.observe(float(v))
    assert len(h.counts) == n_buckets     # O(buckets) however many observed
    assert sum(h.counts) == h.count == 1000


def test_registry_get_or_create_and_type_conflict():
    r = MetricsRegistry()
    c = r.counter("drops")
    assert r.counter("drops") is c
    r.gauge("depth").set(4)
    r.histogram("lat").observe(0.01)
    with pytest.raises(TypeError, match="drops"):
        r.gauge("drops")
    with pytest.raises(TypeError, match="depth"):
        r.histogram("depth")
    assert r.names() == ["depth", "drops", "lat"]
    snap = r.snapshot()
    assert snap["drops"] == 0
    assert snap["depth"] == {"value": 4, "max": 4}
    assert snap["lat"]["count"] == 1
    assert snap["lat"]["latency_p50_s"] == pytest.approx(0.01)


# ---------------------------------------------------------------------------
# the sync engine's span table, pinned under a fake clock
# ---------------------------------------------------------------------------

def test_engine_span_table_pinned(small):
    _, model, imgs = small
    tr = Tracer(capacity=64)
    eng = MicroBatchEngine(model, tracer=tr, clock=FakeClock())
    eng.submit(imgs[:2])
    eng.run()
    table = [(s.category, s.name, s.t0, s.t1, s.rid, s.bucket)
             for s in tr.spans()]
    assert table == [
        ("request", "admit", 1.0, 2.0, 0, None),
        ("counter", "queue_depth", 2.0, 2.0, None, None),
        ("batch", "place", 3.0, 4.0, None, 2),
        ("request", "queue", 2.0, 5.0, 0, None),
        ("batch", "assemble", 5.0, 6.0, None, 2),
        ("batch", "step", 6.0, 7.0, None, 2),
        ("counter", "occupancy", 6.0, 6.0, None, None),
        ("request", "complete", 2.0, 8.0, 0, None),
    ]
    by_name = {s.name: s for s in tr.spans()}
    assert by_name["admit"].value == 2          # images admitted
    assert by_name["queue_depth"].value == 2.0
    assert by_name["step"].occupancy is not None
    assert tr.dropped_spans == 0


def test_engine_empty_request_chain_skips_queue(small):
    _, model, _ = small
    tr = Tracer(capacity=16)
    eng = MicroBatchEngine(model, tracer=tr, clock=FakeClock())
    req = eng.submit(np.zeros((0, 16, 16, 3), np.uint8))
    assert req.labels == []
    names = [(s.name, s.rid) for s in tr.spans()]
    assert names == [("admit", 0), ("complete", 0)]
    assert tr.spans()[0].value == 0             # zero-image admit
    # the report gate accepts the short chain for empty admits
    assert trace_report.check_complete(tr.spans(), 0) == []


def test_untraced_engine_emits_nothing(small):
    _, model, imgs = small
    eng = MicroBatchEngine(model)
    assert eng.tracer is NULL_TRACER
    eng.submit(imgs[:2])
    eng.run()
    assert len(eng.tracer) == 0


# ---------------------------------------------------------------------------
# chain determinism: identical per-rid lifecycle across every ServeClient
# ---------------------------------------------------------------------------

def chains(tracer):
    """{rid: [span names in append order]} over rid-scoped request spans."""
    out = {}
    for s in tracer.spans():
        if s.category == "request" and s.rid is not None:
            out.setdefault(s.rid, []).append(s.name)
    return out


def test_request_chains_identical_across_clients(small):
    _, model, imgs = small
    sizes = [2, 1, 3, 2]

    tr_eng = Tracer()
    eng = MicroBatchEngine(model, tracer=tr_eng)
    for k, n in enumerate(sizes):
        eng.submit(imgs[:n], rid=k)
    eng.run()

    tr_rt = Tracer()
    with AsyncServeRuntime(model, tracer=tr_rt) as rt:
        handles = [rt.submit(imgs[:n], rid=k) for k, n in enumerate(sizes)]
        for h in handles:
            h.result(timeout=60.0)

    tr_fl = Tracer()
    with ServeFleet(model, replicas=2, tracer=tr_fl) as fleet:
        handles = [fleet.submit(imgs[:n], rid=k)
                   for k, n in enumerate(sizes)]
        for h in handles:
            h.result(timeout=60.0)

    want = {k: ["admit", "queue", "complete"] for k in range(len(sizes))}
    assert chains(tr_eng) == want
    assert chains(tr_rt) == want
    assert chains(tr_fl) == want
    # fleet batch spans carry the executing replica's index
    step_replicas = {s.replica for s in tr_fl.spans()
                     if s.category == "batch" and s.name == "step"}
    assert step_replicas and step_replicas <= {0, 1}
    for tr in (tr_eng, tr_rt, tr_fl):
        assert tr.dropped_spans == 0
        assert trace_report.check_complete(tr.spans(), 0) == []


def test_queue_depth_peak_parity_engine_vs_runtime(small):
    _, model, imgs = small
    # 4 requests x 2 images fill the largest bucket exactly; a 5s window
    # with no SLO means the async worker provably holds all 8 before the
    # first dispatch — both clients must report the identical peak
    eng = MicroBatchEngine(model)
    for k in range(4):
        eng.submit(imgs[2 * (k % 2):2 * (k % 2) + 2], rid=k)
    eng.run()
    assert eng.stats()["queue_depth_peak"] == 8

    with AsyncServeRuntime(model,
                           policy=ServePolicy(max_wait_ms=5000.0)) as rt:
        handles = [rt.submit(imgs[2 * (k % 2):2 * (k % 2) + 2], rid=k)
                   for k in range(4)]
        for h in handles:
            h.result(timeout=60.0)
        assert rt.stats()["queue_depth_peak"] == 8


# ---------------------------------------------------------------------------
# scheduler inspectability: debug_state + publish
# ---------------------------------------------------------------------------

def test_scheduler_debug_state_and_publish():
    s = ContinuousBatchingScheduler((2, 8), ServePolicy())
    s.observe_step(2, 0.010, occupancy=0.10)    # sparse (< 0.35)
    s.observe_step(8, 0.040, occupancy=0.90)    # dense
    ds = s.debug_state()
    assert ds["buckets"] == [2, 8]
    assert set(ds["step_s"]) == {2, 8}
    assert set(ds["class_step_s"]) == {"2/sparse", "8/dense"}
    assert ds["occupancy_ewma"] is not None
    ds["step_s"].clear()                        # a copy, not the live table
    assert s.debug_state()["step_s"]

    reg = MetricsRegistry()
    s.publish(reg)
    assert reg.names() == [
        "scheduler/class_step_s/2/sparse", "scheduler/class_step_s/8/dense",
        "scheduler/occupancy_ewma", "scheduler/step_s/2",
        "scheduler/step_s/8",
    ]
    assert reg.gauge("scheduler/step_s/2").value == pytest.approx(0.010)


def test_fleet_scheduler_publishes_replica_tables():
    s = FleetScheduler((2, 8), ServePolicy(), n_replicas=2)
    s.observe_step(2, 0.010, occupancy=0.10, replica=1)
    ds = s.debug_state()
    assert ds["n_replicas"] == 2
    assert set(ds["replica_step_s"]) == {"1/2"}
    assert set(ds["replica_class_step_s"]) == {"1/2/sparse"}
    reg = MetricsRegistry()
    s.publish(reg, prefix="fleet/")
    names = set(reg.names())
    assert {"fleet/n_replicas", "fleet/replica_step_s/1/2",
            "fleet/replica_class_step_s/1/2/sparse"} <= names
    assert reg.gauge("fleet/n_replicas").value == 2.0


def test_fresh_scheduler_publishes_nothing_spurious():
    reg = MetricsRegistry()
    ContinuousBatchingScheduler((2, 8)).publish(reg)
    assert reg.names() == []        # no observations, no occupancy: silence


# ---------------------------------------------------------------------------
# serve_stats v3: histogram-backed latency fields
# ---------------------------------------------------------------------------

def fake_acct():
    acct = StepAccounting()
    acct.record_step(rows=2, bucket=2, busy_s=0.01, wall_s=0.02,
                     occupancy=0.5)
    return acct


def test_serve_stats_v3_histogram_vs_exact_list():
    assert SERVE_STATS_VERSION == 3
    lats = [0.002, 0.004, 0.008, 0.016, 0.032]
    hist = LatencyHistogram()
    done = []
    for k, v in enumerate(lats):
        hist.observe(v)
        r = Request(rid=k, images=np.zeros((1, 4, 4, 3), np.uint8))
        r.t_submit, r.t_done = 0.0, v
        done.append(r)
    via_hist = serve_stats(acct=fake_acct(), done=done, buckets=(2, 8),
                           latency_hist=hist)
    via_list = serve_stats(acct=fake_acct(), done=done, buckets=(2, 8))
    assert via_hist["stats_version"] == via_list["stats_version"] == 3
    assert set(via_hist) == set(via_list)     # same schema either way
    # the histogram path honors the documented contract: within one
    # bucket width of the exact nearest-rank order statistic
    for k, q in (("latency_p50_s", 50), ("latency_p95_s", 95),
                 ("latency_p99_s", 99)):
        want = exact_nearest_rank(lats, q)
        assert via_hist[k] == pytest.approx(want, rel=hist.error_bound)
    assert via_hist["latency_mean_s"] == pytest.approx(
        via_list["latency_mean_s"], abs=1e-6)     # the mean is exact
    assert via_hist["requests"] == 5


def test_serve_stats_empty_window_reports_absence():
    empty = serve_stats(acct=StepAccounting(), done=[], buckets=(2, 8),
                        latency_hist=LatencyHistogram())
    assert empty["latency_p50_s"] is None and empty["latency_mean_s"] is None
    assert empty["requests"] == 0 and empty["fps"] == 0.0
    # the exact-list path must also shrug off in-flight Nones
    assert latency_summary([None, None])["latency_p50_s"] is None
    assert latency_summary([])["latency_p99_s"] is None


# ---------------------------------------------------------------------------
# export: chrome trace structure + JSONL round trip
# ---------------------------------------------------------------------------

def traced_fixture():
    tr = Tracer(capacity=32)
    tr.span("request", "admit", t0=10.0, t1=10.1, rid=0, value=2)
    tr.span("request", "queue", t0=10.1, t1=10.3, rid=0)
    tr.span("batch", "place", t0=10.1, t1=10.2, bucket=2)
    tr.span("batch", "step", t0=10.3, t1=10.9, bucket=2, occupancy=0.4,
            value=2, replica=1)
    tr.span("window", "encode", t0=10.0, t1=10.05, rid=3, value=7)
    tr.counter("queue_depth", 2, t=10.1)
    tr.span("request", "complete", t0=10.1, t1=11.0, rid=0)
    return tr


def test_chrome_trace_structure():
    tr = traced_fixture()
    doc = to_chrome_trace(tr.spans(), dropped_spans=3)
    assert doc["otherData"] == {"spans_version": SPANS_SCHEMA_VERSION,
                                "dropped_spans": 3}
    ev = doc["traceEvents"]
    x = [e for e in ev if e["ph"] == "X"]
    counters = [e for e in ev if e["ph"] == "C"]
    meta = [e for e in ev if e["ph"] == "M"]
    assert len(x) == 6 and len(counters) == 1
    # timestamps rebased to the earliest span, in microseconds
    assert min(e["ts"] for e in x) == 0.0
    assert all(e["dur"] >= 0.0 for e in x)
    # one pid per replica: the step span ran on replica 1, rest on pid 0
    assert {e["pid"] for e in x} == {0, 1}
    by_name = {e["name"]: e for e in x}
    assert by_name["admit"]["tid"] == 10 + 0      # request lane
    assert by_name["place"]["tid"] == 1           # scheduler lane
    assert by_name["encode"]["tid"] == 10 + 3     # rid lane wins over window
    assert by_name["step"]["args"]["occupancy"] == 0.4
    assert counters[0]["args"] == {"queue_depth": 2.0}
    proc_names = {e["pid"]: e["args"]["name"] for e in meta
                  if e["name"] == "process_name"}
    assert proc_names == {0: "replica 0", 1: "replica 1"}
    assert any(e["name"] == "thread_name" and e["args"]["name"] == "worker"
               for e in meta)


def test_jsonl_round_trip(tmp_path):
    tr = traced_fixture()
    path = tmp_path / "trace.jsonl"
    n = write_spans_jsonl(path, tr, meta={"mode": "test"})
    assert n == 7
    header, spans = load_spans_jsonl(path)
    assert header["kind"] == "repro.obs.spans"
    assert header["spans_version"] == SPANS_SCHEMA_VERSION
    assert header["dropped_spans"] == 0 and header["meta"] == {"mode": "test"}
    assert spans == tr.spans()                    # bit-exact inversion
    # the perfetto writer emits valid JSON alongside
    pf = tmp_path / "trace.perfetto.json"
    assert write_chrome_trace(pf, tr) == 7
    assert len(json.loads(pf.read_text())["traceEvents"]) > 7


def test_jsonl_loader_refuses_bad_files(tmp_path):
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    with pytest.raises(ValueError, match="empty"):
        load_spans_jsonl(empty)
    wrong = tmp_path / "wrong.jsonl"
    wrong.write_text(json.dumps({"kind": "something.else"}) + "\n")
    with pytest.raises(ValueError, match="kind"):
        load_spans_jsonl(wrong)
    future = tmp_path / "future.jsonl"
    future.write_text(json.dumps({"kind": "repro.obs.spans",
                                  "spans_version": 99, "spans": 0}) + "\n")
    with pytest.raises(ValueError, match="spans_version"):
        load_spans_jsonl(future)
    trunc = tmp_path / "trunc.jsonl"
    trunc.write_text(json.dumps({"kind": "repro.obs.spans",
                                 "spans_version": SPANS_SCHEMA_VERSION,
                                 "spans": 5}) + "\n")
    with pytest.raises(ValueError, match="truncated"):
        load_spans_jsonl(trunc)


# ---------------------------------------------------------------------------
# trace_report: the analysis views and the CI gate
# ---------------------------------------------------------------------------

def test_trace_report_views():
    spans = traced_fixture().spans()
    phases = trace_report.phase_breakdown(spans)
    assert ("counter", "queue_depth") not in phases   # instants, not phases
    assert phases[("request", "complete")]["count"] == 1
    assert phases[("batch", "step")]["mean_s"] == pytest.approx(0.6)
    slow = trace_report.slowest_requests(spans, 3)
    assert [s.rid for s in slow] == [0]
    util = trace_report.replica_utilization(spans)
    assert util[1] == pytest.approx(0.6 / 1.0)        # step 0.6s over 1s wall


def test_trace_report_gate_catches_violations():
    ok = [Span("request", "admit", 0.0, 0.1, rid=0, value=2),
          Span("request", "queue", 0.1, 0.2, rid=0),
          Span("request", "complete", 0.1, 0.3, rid=0),
          Span("request", "admit", 0.0, 0.1, rid=1, value=0),
          Span("request", "complete", 0.1, 0.1, rid=1)]
    assert trace_report.check_complete(ok, 0) == []
    assert trace_report.check_complete(ok, dropped_spans=5)  # lossy: fails
    missing = ok[:2]                                  # admitted, never done
    problems = trace_report.check_complete(missing, 0)
    assert len(problems) == 1 and "complete" in problems[0]
    # a non-empty admit with no queue span is a broken chain too
    no_queue = [ok[0], ok[2]]
    assert any("queue" in p for p in trace_report.check_complete(no_queue, 0))


def test_trace_report_main_gate(tmp_path):
    tr = Tracer()
    tr.span("request", "admit", t0=0.0, t1=0.1, rid=0, value=1)
    tr.span("request", "queue", t0=0.1, t1=0.2, rid=0)
    tr.span("request", "complete", t0=0.1, t1=0.4, rid=0)
    good = tmp_path / "good.jsonl"
    write_spans_jsonl(good, tr)
    assert trace_report.main([str(good), "--assert-complete"]) == 0
    tr2 = Tracer()
    tr2.span("request", "admit", t0=0.0, t1=0.1, rid=0, value=1)
    bad = tmp_path / "bad.jsonl"
    write_spans_jsonl(bad, tr2)
    assert trace_report.main([str(bad), "--assert-complete"]) == 1
    assert trace_report.main([str(bad)]) == 0         # report-only never gates


# ---------------------------------------------------------------------------
# per-layer kernel timing: CompiledModel.profile_step
# ---------------------------------------------------------------------------

def test_profile_step_rows_cover_every_layer(small):
    cfg, model, imgs = small
    tr = Tracer()
    rows = model.profile_step(imgs[:2], tracer=tr)
    assert [r["path"] for r in rows] == profile_layer_paths(cfg)
    assert all(r["seconds"] >= 0.0 for r in rows)
    routes = model.plan.routes or {}
    for r in rows:
        default = "stdp" if r["path"].endswith("/stdp") else "unpack"
        assert r["route"] == routes.get(r["path"], default)
        assert r["route"] in ("lut", "lut_sparse", "unpack", "stdp")
    layer_spans = [s for s in tr.spans() if s.category == "layer"]
    assert [s.name for s in layer_spans] == [r["path"] for r in rows]
    assert all(s.value == pytest.approx(s.duration_s) for s in layer_spans)


def test_profile_step_default_batch_and_bad_batch(small):
    _, model, imgs = small
    rows = model.profile_step()                   # zeros at the first bucket
    assert len(rows) == len(profile_layer_paths(model.cfg))
    with pytest.raises(ValueError, match="bucket"):
        model.profile_step(imgs[:3])              # 3 is not a bucket


# ---------------------------------------------------------------------------
# event session: window spans over a scripted client
# ---------------------------------------------------------------------------

class FakeHandle:
    def __init__(self, labels):
        self.labels = labels

    def result(self, timeout=None):
        return self.labels


class FakeClient:
    """Scripted ServeClient: labels synchronously, sheds on script."""

    def __init__(self, full_at=()):
        self.full_at = set(full_at)
        self.attempts = 0

    def submit(self, images, *, rid=None, on_image=None):
        k = self.attempts
        self.attempts += 1
        if k in self.full_at:
            raise QueueFull("scripted")
        if on_image is not None:
            for i in range(len(images)):
                on_image(k, i, k)
        return FakeHandle([k] * len(images))


def events_at(*t_us):
    t = np.asarray(t_us, np.int64)
    n = len(t)
    return EventStream(8, 8, np.full(n, 1), np.full(n, 1), t, np.full(n, 1))


def test_session_window_spans():
    tr = Tracer()
    s = EventStreamSession(FakeClient(full_at={1}), window_us=1_000,
                           height=8, width=8, tracer=tr)
    s.feed(events_at(100, 900, 1_100, 1_900, 2_100))  # closes windows 0, 1
    s.flush()                                         # closes window 2
    spans = [(sp.name, sp.rid) for sp in tr.spans()
             if sp.category == "window"]
    # window 0 served (encode + synchronous complete), window 1 shed,
    # window 2 served; rid is the WINDOW index
    assert spans == [("encode", 0), ("complete", 0),
                     ("encode", 1), ("shed", 1),
                     ("encode", 2), ("complete", 2)]
    enc0 = next(sp for sp in tr.spans() if sp.name == "encode")
    assert enc0.value == 2 and enc0.occupancy is not None  # 2 events in w0
    assert s.windows_shed == 1


def test_session_untraced_stays_silent():
    s = EventStreamSession(FakeClient(), window_us=1_000, height=8, width=8)
    assert s.tracer is NULL_TRACER
    s.feed(events_at(100, 1_100))
    s.flush()
    assert len(s.tracer) == 0 and s.windows[0]["label"] is not None
