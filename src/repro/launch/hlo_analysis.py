"""Loop-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts the body of a ``while`` loop ONCE,
not multiplied by its trip count. Every production model here wraps its
layers (and its gradient-accumulation microbatches) in ``lax.scan``, so the
stock numbers undercount FLOPs / bytes / collectives by 1-3 orders of
magnitude (e.g. qwen1.5-110b train: 80-layer scan x 8 accum steps => ~640x).

This module re-derives the three roofline terms by walking the optimized HLO
*text*, where the trip count of each loop is visible
(``backend_config={"known_trip_count":{"n":"8"}}``) and every op carries its
shapes. Cost model:

  flops   dot: 2 * prod(out) * prod(lhs contracting dims); convolution:
          2 * prod(out) * fan_in; elementwise arithmetic: prod(out);
          fusion/call/while recurse (while multiplied by trip count).

  bytes   HBM traffic at fusion granularity: every top-level op in a
          computation reads its operands and writes its output once
          (post-fusion HLO is exactly the HBM<->core schedule); pure
          data-plumbing ops (tuple/gte/bitcast/parameter/constant) are free.

  colls   per-chip payload bytes by collective kind, x loop trip counts:
          all-gather -> output bytes; reduce-scatter/all-to-all/
          collective-permute -> operand bytes; all-reduce -> 2x operand
          bytes (ring reduce + broadcast phases).

All shapes in SPMD-partitioned HLO are per-partition, so every number this
module emits is PER CHIP.
"""
from __future__ import annotations

import dataclasses
import json
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fn": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    # fp8 family (f8e4m3fn etc. start with 'f8')
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# ops that move no HBM bytes of their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "rng-get-and-update-state", "opt-barrier",
}

# arithmetic ops: 1 flop per output element (transcendentals more, but noise)
_ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "compare", "select", "and", "or", "xor", "not", "clamp", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "atan2", "cosine", "sine",
    "logistic", "exponential-minus-one", "log-plus-one", "cbrt", "erf",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "reduce", "reduce-window", "map",
}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """'bf16[1,2048,128]{2,1,0}' -> (elems, bytes). Tuples sum components."""
    type_str = type_str.strip()
    if type_str.startswith("("):
        total_e = total_b = 0
        # split a tuple type on commas that are not inside brackets/braces
        depth = 0
        part = []
        for ch in type_str[1:-1]:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                e, b = _shape_elems_bytes("".join(part))
                total_e += e
                total_b += b
                part = []
            else:
                part.append(ch)
        if part:
            e, b = _shape_elems_bytes("".join(part))
            total_e += e
            total_b += b
        return total_e, total_b
    m = re.match(r"([a-z0-9]+)\[([^\]]*)\]", type_str)
    if not m:
        return 0, 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        d = d.strip().lstrip("<=")
        if d:
            n *= int(d)
    if dt.startswith("f8"):
        itemsize = 1
    else:
        itemsize = _DTYPE_BYTES.get(dt, 4)
    return n, n * itemsize


def _shape_dims(type_str: str) -> list[int]:
    m = re.match(r"[a-z0-9]+\[([^\]]*)\]", type_str.strip())
    if not m:
        return []
    return [int(d.strip().lstrip("<=")) for d in m.group(1).split(",") if d.strip()]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    raw_args: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    # name -> type_str for every value defined in this computation (including
    # parameters from the header)
    types: dict


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
            self.coll_counts[k] += int(other.coll_counts[k] * mult)

    @property
    def collective_total(self) -> float:
        return sum(self.coll_bytes.values())

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.bytes,
            "collective_bytes": dict(self.coll_bytes),
            "collective_counts": dict(self.coll_counts),
            "collective_total_bytes": self.collective_total,
        }


_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^=]*?\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_TRIP_COUNT = re.compile(r'known_trip_count["\s:{]+n["\s:]+"?(\d+)')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_REF = re.compile(r"%([\w.\-]+)")


_COMMENT = re.compile(r"/\*.*?\*/")


def parse_hlo(text: str) -> tuple[dict, str]:
    """Parse HLO text into {comp_name: Computation}; return (comps, entry)."""
    # strip /*index=N*/ comments — they contain '=' and break type parsing
    text = _COMMENT.sub("", text)
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        m = _COMP_HEADER.match(line)
        if m and ("->" in line):
            name, args = m.group(1), m.group(2)
            cur = Computation(name, [], {})
            comps[name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = name
            # header params: "arg.1: f32[2,3]{1,0}, arg.2: (s32[], f32[4])"
            for pm in re.finditer(
                    r"([\w.\-]+)\s*:\s*((?:\([^()]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))",
                    args):
                cur.types[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_LINE.match(line)
        if om:
            name, type_str, opcode, rest = om.groups()
            # split operands (up to the matching close paren) from attrs
            depth = 1
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            operand_str, attrs = rest[:i], rest[i + 1:]
            operands = _OPERAND_REF.findall(operand_str)
            cur.ops.append(Op(name, type_str, opcode, operands, attrs,
                              raw_args=operand_str))
            cur.types[name] = type_str
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems, _ = _shape_elems_bytes(op.type_str)
    lhs = comp.types.get(op.operands[0]) if op.operands else None
    if lhs is None:
        return 2.0 * out_elems  # fallback
    dims = _shape_dims(lhs)
    cm = re.search(r"lhs_contracting_dims=\{([^}]*)\}", op.attrs)
    k = 1
    if cm:
        for d in cm.group(1).split(","):
            d = d.strip()
            if d:
                k *= dims[int(d)] if int(d) < len(dims) else 1
    return 2.0 * out_elems * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems, _ = _shape_elems_bytes(op.type_str)
    rhs = comp.types.get(op.operands[1]) if len(op.operands) > 1 else None
    if rhs is None:
        return 2.0 * out_elems
    kd = _shape_dims(rhs)
    # kernel: spatial... x in_ch x out_ch (last dim is output feature)
    fan_in = 1
    for d in kd[:-1]:
        fan_in *= d
    return 2.0 * out_elems * fan_in


class Analyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: dict[str, Cost] = {}

    def cost(self, comp_name: str | None = None) -> Cost:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            c = self._memo[comp_name]
        else:
            c = self._compute(comp_name)
            self._memo[comp_name] = c
        out = Cost()
        out.add(c)
        return out

    def _operand_bytes(self, op: Op, comp: Computation) -> float:
        total = 0.0
        for o in op.operands:
            t = comp.types.get(o)
            if t is not None:
                total += _shape_elems_bytes(t)[1]
        return total

    def _compute(self, comp_name: str) -> Cost:
        comp = self.comps.get(comp_name)
        cost = Cost()
        if comp is None:
            return cost
        for op in comp.ops:
            oc = op.opcode
            out_elems, out_bytes = _shape_elems_bytes(op.type_str)

            if oc == "while":
                body = _CALLS.search(op.attrs)
                cond = _COND.search(op.attrs)
                tc_m = _TRIP_COUNT.search(op.attrs)
                trip = int(tc_m.group(1)) if tc_m else self._trip_from_cond(
                    cond.group(1) if cond else None)
                if body:
                    cost.add(self.cost(body.group(1)), mult=trip)
                if cond:
                    cost.add(self.cost(cond.group(1)), mult=trip)
                continue

            if oc == "conditional":
                bm = _BRANCHES.search(op.attrs)
                if bm:
                    branches = _OPERAND_REF.findall(bm.group(1))
                    costs = [self.cost(b) for b in branches]
                    if costs:  # worst case branch
                        cost.add(max(costs, key=lambda c: (c.flops, c.bytes)))
                continue

            if oc in ("call", "async-start"):
                callee = _CALLS.search(op.attrs)
                if callee:
                    cost.add(self.cost(callee.group(1)))
                continue

            if oc == "fusion":
                callee = _CALLS.search(op.attrs)
                if callee:
                    inner = self.cost(callee.group(1))
                    cost.flops += inner.flops       # flops from the body
                # bytes at the fusion boundary only (one HBM pass)
                cost.bytes += self._operand_bytes(op, comp) + out_bytes
                continue

            base = oc.removesuffix("-start").removesuffix("-done")
            if base in _COLLECTIVES or oc in _COLLECTIVES:
                kind = base if base in _COLLECTIVES else oc
                if oc.endswith("-done"):
                    continue  # counted at -start
                opb = self._operand_bytes(op, comp)
                if kind == "all-gather":
                    payload = out_bytes
                elif kind == "all-reduce":
                    payload = 2.0 * opb
                else:  # reduce-scatter, all-to-all, collective-permute
                    payload = opb
                cost.coll_bytes[kind] += payload
                cost.coll_counts[kind] += 1
                cost.bytes += opb + out_bytes
                continue

            if oc in _FREE_OPS:
                continue

            # plain op: bytes in/out
            cost.bytes += self._operand_bytes(op, comp) + out_bytes
            if oc == "dot":
                cost.flops += _dot_flops(op, comp)
            elif oc == "convolution":
                cost.flops += _conv_flops(op, comp)
            elif oc in _ARITH_OPS:
                if oc in ("reduce", "reduce-window", "map"):
                    cost.flops += self._operand_bytes(op, comp) / 4.0  # ~1/elem
                else:
                    cost.flops += out_elems
            # everything else (copy, transpose, reshape, gather, scatter,
            # dynamic-slice, sort, custom-call, rng...): bytes only
        return cost

    def _trip_from_cond(self, cond_name: str | None) -> int:
        """Fallback: largest integer 'constant(N)' literal in the condition
        computation (jax scans compare the induction var against the length)."""
        if cond_name is None:
            return 1
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for op in comp.ops:
            if op.opcode == "constant":
                m = re.match(r"\s*(-?\d+)\s*$", op.raw_args)
                if m:
                    best = max(best, int(m.group(1)))
        return best


def analyze(text: str) -> Cost:
    """Full loop-aware cost of an optimized HLO module (per chip)."""
    return Analyzer(text).cost()


def analyze_dict(text: str) -> dict:
    return analyze(text).to_dict()
