import os
os.environ["XLA_FLAGS"] = (os.environ.get("EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run driver.

For every (architecture x input-shape) cell this lowers + compiles the real
jit program (train_step / prefill / serve_step) against the production mesh
— 16x16 single-pod and 2x16x16 multi-pod — using ShapeDtypeStruct inputs
(no allocation), then records:

  * memory_analysis()  — per-chip argument/output/temp bytes (fits-in-HBM proof)
  * cost_analysis()    — per-chip HLO FLOPs + bytes accessed
  * collective bytes   — parsed from the post-SPMD HLO text, per category
  * roofline terms     — compute / memory / collective seconds (v5e consts)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import pathlib
import time

import jax

from ..configs.base import (SHAPES, ARCH_IDS, get_config, cell_applicable,
                            input_specs)
from . import steps
from .hlo_analysis import analyze
from .mesh import make_production_mesh
from ..sharding.compat import set_mesh

# --- TPU v5e hardware model -------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               microbatch: int | None = None):
    """Build + lower + compile one cell. Returns (record, compiled)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}, None

    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = input_specs(cfg, shape)
    t0 = time.time()
    with set_mesh(mesh):
        if shape.kind == "train":
            # >=100B configs: bf16 grad accumulation + smaller microbatch,
            # or params+moments+grads+activations exceed 16 GB HBM per chip
            big = cfg.opt_state_dtype == "bfloat16"
            ts = steps.TrainSettings(
                microbatch=microbatch or (16 if big else 32),
                accum_dtype=cfg.opt_state_dtype)
            step, (p_sh, o_sh, b_sh), _ = steps.jit_train_step(
                cfg, mesh, ts, spec["batch"])
            lowered = step.lower(p_sh, o_sh, spec["batch"])
        elif shape.kind == "prefill":
            fn, (p_sh, b_sh), _ = steps.jit_prefill(
                cfg, mesh, shape, spec["batch"])
            lowered = fn.lower(p_sh, spec["batch"])
        else:  # decode
            fn, (p_sh, c_sh, b_sh), _ = steps.jit_serve_step(
                cfg, mesh, spec["cache"], spec["batch"])
            lowered = fn.lower(p_sh, spec["cache"], spec["batch"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    xla_ca = compiled.cost_analysis() or {}
    # loop-aware per-chip cost: XLA's cost_analysis counts while bodies ONCE;
    # analyze() multiplies by the known trip counts (layer scan, grad accum).
    cost = analyze(compiled.as_text())
    n_chips = mesh.devices.size

    terms = {
        "compute_s": cost.flops / PEAK_FLOPS,
        "memory_s": cost.bytes / HBM_BW,
        "collective_s": cost.collective_total / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())

    tokens = shape.batch * (shape.seq if shape.kind == "train" else
                            (shape.seq if shape.kind == "prefill" else 1))
    n_active = cfg.n_active_params()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    hlo_flops_global = cost.flops * n_chips
    ideal_model_s = model_flops / (n_chips * PEAK_FLOPS)

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_gb_per_chip": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes) / 1e9, 3),
            "fits_16gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes) < 16e9,
        },
        "cost": {
            "flops_per_chip": cost.flops,
            "hbm_bytes_per_chip": cost.bytes,
            # stock XLA numbers for cross-check (undercount loops)
            "xla_flops_per_chip": float(xla_ca.get("flops", 0.0)),
            "xla_bytes_per_chip": float(xla_ca.get("bytes accessed", 0.0)),
        },
        "collectives": {
            "bytes": dict(cost.coll_bytes),
            "counts": dict(cost.coll_counts),
            "total_bytes": cost.collective_total,
        },
        "roofline": {
            **{k: round(v, 6) for k, v in terms.items()},
            "dominant": dominant,
            "bound_s": round(bound_s, 6),
            "model_flops": model_flops,
            "hlo_flops_global": hlo_flops_global,
            # MODEL_FLOPS / HLO_FLOPs: <1 means remat/attention/router
            # overhead; >1 would mean the analyzer missed compute.
            "useful_flops_ratio": round(
                model_flops / hlo_flops_global, 4) if hlo_flops_global else 0,
            # fraction of roofline: ideal model-compute time / bound time
            "roofline_frac": round(ideal_model_s / max(bound_s, 1e-12), 4),
        },
        "params": {"total": cfg.n_params(), "active": n_active},
    }
    return record, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    arches = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in arches:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                try:
                    rec, _ = lower_cell(arch, shape, multi_pod=mp,
                                        microbatch=args.microbatch)
                except Exception as e:  # a failure here is a bug in our system
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}"}
                (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
                if "skipped" in rec:
                    print(f"[skip] {tag}: {rec['skipped']}", flush=True)
                elif "error" in rec:
                    print(f"[FAIL] {tag}: {rec['error'][:200]}", flush=True)
                else:
                    r = rec["roofline"]
                    m = rec["memory"]
                    print(f"[ok] {tag}: compile={rec['compile_s']}s "
                          f"peak={m['peak_gb_per_chip']}GB "
                          f"dom={r['dominant']} bound={r['bound_s']}s "
                          f"frac={r['roofline_frac']}", flush=True)


if __name__ == "__main__":
    main()
