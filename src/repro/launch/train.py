"""Training driver.

Two modes:
  * REAL RUN (default) — trains the requested arch (optionally ``--reduce``d
    so it fits this CPU container) on synthetic/file data with the full
    production loop: sharded jit step, async checkpointing, restart
    supervision, loss guard, straggler bookkeeping, metrics log.
  * DRY RUN (``--dry-run``) — delegates to launch/dryrun.py semantics for the
    production mesh (lower+compile only). Use dryrun.py directly for the
    full 40-cell sweep.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduce \
      --steps 50 --global-batch 8 --seq 256 --ckpt-dir /tmp/ck --ckpt-every 20
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
      --reduce --steps 10 --compression int8
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from ..configs.base import get_config
from ..data.pipeline import DataConfig, DataPipeline
from ..checkpoint.checkpointer import Checkpointer
from ..runtime.fault_tolerance import (LossGuard, RestartPolicy,
                                       StragglerDetector, TrainSupervisor,
                                       NodeFailure)
from ..optim import adamw
from ..nn import transformer as T
from ..sharding import rules
from . import steps
from .mesh import make_cpu_mesh
from ..sharding.compat import set_mesh


def build_parser():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true",
                    help="scale the arch down to a CPU-runnable size")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=0,
                    help="0 = no accumulation")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="synthetic_lm")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="test hook: raise NodeFailure at this step once")
    return ap


@dataclasses.dataclass
class TrainState:
    params: dict
    opt_state: dict
    data: DataPipeline
    step: int


def main(argv=None):
    args = build_parser().parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()

    mesh = make_cpu_mesh()
    dcfg = DataConfig(seq=args.seq, global_batch=args.global_batch,
                      vocab=cfg.padded_vocab, seed=args.seed,
                      kind=args.data, path=args.data_path)
    ts = steps.TrainSettings(
        microbatch=args.microbatch or args.global_batch,
        compression=args.compression,
        opt=adamw.OptConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                            decay_steps=max(args.steps, 2 * args.warmup)))

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    guard = LossGuard()
    straggler = StragglerDetector(n_nodes=1)
    metrics_log: list[dict] = []
    injected = {"done": False}

    batch_shapes = {
        "tokens": jax.ShapeDtypeStruct((args.global_batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((args.global_batch, args.seq), jnp.int32),
    }
    if cfg.family == "vlm":
        batch_shapes["image_embeds"] = jax.ShapeDtypeStruct(
            (args.global_batch, cfg.img_tokens, cfg.d_model), jnp.bfloat16)
        batch_shapes["mrope_positions"] = jax.ShapeDtypeStruct(
            (3, args.global_batch, args.seq), jnp.int32)
    if cfg.family == "encdec":
        batch_shapes["frames"] = jax.ShapeDtypeStruct(
            (args.global_batch, cfg.n_frames, cfg.d_model), jnp.bfloat16)

    with set_mesh(mesh):
        step_fn, (p_sh, o_sh, _), in_sh = steps.jit_train_step(
            cfg, mesh, ts, batch_shapes)

        def augment(batch):
            """Add the stub modality inputs the synthetic LM stream lacks."""
            b, s = batch["tokens"].shape
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (b, cfg.img_tokens, cfg.d_model), jnp.bfloat16)
                pos = jnp.broadcast_to(jnp.arange(s), (b, s))
                batch["mrope_positions"] = jnp.broadcast_to(
                    pos[None], (3, b, s)).astype(jnp.int32)
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
            return batch

        def make_state(restore):
            if restore is not None and ckpt is not None \
                    and ckpt.latest_step() is not None:
                skel_p = steps.abstract_params(cfg)
                skel_o = steps.abstract_opt_state(cfg, skel_p, ts)
                tree, extra = ckpt.restore(
                    skeleton={"params": skel_p, "opt": skel_o},
                    shardings={"params": rules.param_shardings(mesh, skel_p),
                               "opt": rules.opt_state_shardings(mesh, skel_o)})
                data = DataPipeline.restore(dcfg, extra["data"])
                print(f"[restore] step {extra['step']} from {ckpt.dir}")
                return TrainState(tree["params"], tree["opt"], data,
                                  int(extra["step"]))
            params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
            params = jax.tree_util.tree_map(
                jax.device_put, params, rules.param_shardings(
                    mesh, jax.eval_shape(lambda: params)))
            opt_state = adamw.init(params, ts.opt)
            if ts.compression != "none":
                from ..optim.compression import ef_init
                opt_state["ef"] = ef_init(params)
            return TrainState(params, opt_state, DataPipeline(dcfg), 0)

        def run_segment(state: TrainState):
            params, opt_state, data = state.params, state.opt_state, state.data
            for step in range(state.step, args.steps):
                if step == args.inject_failure_at and not injected["done"]:
                    injected["done"] = True
                    data.close()
                    raise NodeFailure(f"injected at step {step}")
                t0 = time.time()
                batch = augment(next(data))
                params, opt_state, m = step_fn(params, opt_state, batch)
                loss = float(m["loss"])
                dt = time.time() - t0
                straggler.update([dt])
                if not guard.check(loss):
                    data.close()
                    raise NodeFailure(f"loss diverged: {loss} at step {step}")
                if step % args.log_every == 0 or step == args.steps - 1:
                    rec = {"step": step, "loss": round(loss, 4),
                           "grad_norm": round(float(m["grad_norm"]), 4),
                           "lr": float(m["lr"]), "step_s": round(dt, 3)}
                    metrics_log.append(rec)
                    print(json.dumps(rec), flush=True)
                if ckpt is not None and (step + 1) % args.ckpt_every == 0:
                    ckpt.save(step + 1,
                              {"params": params, "opt": opt_state},
                              extra={"step": step + 1,
                                     "data": data.state_dict()})
            if ckpt is not None:
                ckpt.save(args.steps, {"params": params, "opt": opt_state},
                          extra={"step": args.steps,
                                 "data": data.state_dict()}, block=True)
            data.close()
            return None

        sup = TrainSupervisor(RestartPolicy(backoff_s=0.01), make_state,
                              run_segment)
        result = sup.run()
        print(json.dumps({"result": result}), flush=True)

    if args.metrics_out:
        pathlib.Path(args.metrics_out).write_text(json.dumps(metrics_log))
    return metrics_log


if __name__ == "__main__":
    main()
