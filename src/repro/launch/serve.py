"""Serving driver: batched prefill + continuous-batching decode.

The serving loop maintains a fixed pool of `slots` (the decode batch); each
slot holds one request's KV/SSM cache rows. Requests arrive in a queue,
prefill runs per-request (chunked attention => O(S·chunk) peak), the
resulting cache row is spliced into the pool, and one fused `serve_step`
advances EVERY active slot by one token per iteration — the standard
continuous-batching schedule (vLLM-style), expressed with a static-shape
cache pool so the step stays jit-compiled.

This container runs reduced configs end-to-end on CPU; the decode_32k /
long_500k production shapes are exercised by launch/dryrun.py on the
512-chip mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduce \
      --slots 4 --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from collections import deque

import jax
import jax.numpy as jnp

from ..configs.base import get_config
from ..nn import transformer as T
from . import steps
from .mesh import make_cpu_mesh
from ..sharding.compat import set_mesh


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    t_arrival: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    """Continuous-batching engine over a static slot pool."""

    def __init__(self, cfg, *, slots: int, cache_len: int, seed: int = 0,
                 compute_dtype=None, cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.compute_dtype = compute_dtype or jnp.dtype(cfg.compute_dtype)
        self.cache_dtype = cache_dtype
        self.params = T.init_model(jax.random.PRNGKey(seed), cfg)
        self.pool = T.init_cache(cfg, slots, cache_len, dtype=cache_dtype)
        self.active: dict[int, Request] = {}           # slot -> request
        self.positions = jnp.zeros((slots,), jnp.int32)
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []

        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_impl)

    # -- jit bodies -----------------------------------------------------------
    def _prefill_impl(self, params, tokens):
        """tokens: (1, S) -> (next_token, cache_row)."""
        cache = T.init_cache(self.cfg, 1, self.cache_len,
                             dtype=self.cache_dtype)
        batch = {"tokens": tokens, "cache_pos": jnp.int32(0)}
        logits, cache, _ = T.model_apply(
            params, batch, self.cfg, mode="prefill", cache=cache,
            compute_dtype=self.compute_dtype)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

    def _decode_impl(self, params, pool, tokens, positions):
        """tokens: (slots, 1); positions: (slots,) per-slot cache_pos.

        ONE fused step advances every slot: the cache tracks per-row
        positions, so heterogeneous offsets need no per-slot dispatch."""
        batch = {"tokens": tokens, "cache_pos": positions}
        logits, pool, _ = T.model_apply(
            params, batch, self.cfg, mode="decode", cache=pool,
            compute_dtype=self.compute_dtype)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), pool

    # -- pool management ------------------------------------------------------
    def _splice(self, slot: int, row_cache):
        """Copy a 1-row prefill cache into pool slot `slot`.

        The batch axis position is determined by the cache layout, NOT by
        shape matching (ambiguous when n_layers == slots): scan-stacked
        caches are (L, B, ...) => axis 1; per-layer list caches are
        (B, ...) => axis 0."""
        axis = 1 if self.cfg.scan_layers else 0

        def put(pool_leaf, row_leaf):
            if axis == 0:
                return pool_leaf.at[slot].set(row_leaf[0])
            return pool_leaf.at[:, slot].set(row_leaf[:, 0])

        self.pool = jax.tree_util.tree_map(put, self.pool, row_cache)

    def submit(self, req: Request):
        req.t_arrival = time.time()
        self.queue.append(req)

    def _admit(self):
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.popleft()
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            next_tok, row = self._prefill(self.params, toks)
            req.out.append(int(next_tok[0]))
            req.t_first = time.time()
            self._splice(slot, row)
            self.positions = self.positions.at[slot].set(len(req.prompt))
            self.active[slot] = req

    def step(self) -> int:
        """One engine iteration; returns number of active slots."""
        self._admit()
        if not self.active:
            return 0
        tokens = jnp.zeros((self.slots, 1), jnp.int32)
        for slot, req in self.active.items():
            tokens = tokens.at[slot, 0].set(req.out[-1])
        toks, self.pool = self._decode(self.params, self.pool, tokens,
                                       self.positions)
        self.positions = self.positions + 1
        finished = []
        for slot, req in self.active.items():
            req.out.append(int(toks[slot]))
            if len(req.out) >= req.max_new:
                req.t_done = time.time()
                finished.append(slot)
        for slot in finished:
            self.done.append(self.active.pop(slot))
        return len(self.active)

    def run(self):
        while self.queue or self.active:
            self.step()
        return self.done


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()

    mesh = make_cpu_mesh()
    with set_mesh(mesh):
        eng = Engine(cfg, slots=args.slots, cache_len=args.cache_len,
                     seed=args.seed)
        rng = jax.random.PRNGKey(args.seed + 1)
        t0 = time.time()
        for i in range(args.requests):
            rng, k = jax.random.split(rng)
            prompt = jax.random.randint(
                k, (args.prompt_len,), 0, cfg.vocab).tolist()
            eng.submit(Request(rid=i, prompt=prompt, max_new=args.max_new))
        done = eng.run()
        wall = time.time() - t0

    total_tokens = sum(len(r.out) for r in done)
    ttfts = [r.t_first - r.t_arrival for r in done]
    summary = {
        "requests": len(done),
        "total_new_tokens": total_tokens,
        "wall_s": round(wall, 3),
        "tok_per_s": round(total_tokens / wall, 2),
        "mean_ttft_s": round(sum(ttfts) / len(ttfts), 4),
    }
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
