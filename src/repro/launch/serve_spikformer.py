"""Spikformer image-classification serving driver — a thin CLI over the
compile/serve split: ``repro.infer.compile`` builds the multi-bucket
``CompiledModel``, ``repro.infer.engine.MicroBatchEngine`` drains the
request queue through it. This is the paper's real-time classification
serving loop: VESTA sustains ~30 fps on Spikformer V2; the engine reports
achieved fps against that target, plus p50/p95 latency and pad waste (the
padded-rows fraction multi-bucket dispatch exists to cut).

  PYTHONPATH=src python -m repro.launch.serve_spikformer --reduce \
      --requests 12 --buckets 2,8 --backend packed

  PYTHONPATH=src python -m repro.launch.serve_spikformer --reduce --smoke
      # CI gate: a handful of requests, asserts all complete with correct
      # shapes and labels in range
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from ..core.spikformer import SpikformerConfig, init as spik_init
from ..infer import ExecutionPlan, MicroBatchEngine, PAPER_FPS, compile
from ..infer.engine import Request

# Pre-split names, kept importable: ImageRequest is the engine Request;
# SpikformerEngine is a construct-from-params convenience over the split.
ImageRequest = Request


class SpikformerEngine(MicroBatchEngine):
    """Micro-batching classifier built straight from training params —
    the pre-split constructor shape, now compile() + MicroBatchEngine."""

    def __init__(self, params, cfg: SpikformerConfig, *, batch_size: int = 8,
                 buckets=None, backend: str = "packed",
                 weight_dtype: str | None = None):
        plan = ExecutionPlan(backend=backend, weight_dtype=weight_dtype,
                             batch_buckets=buckets or (batch_size,))
        super().__init__(compile(params, cfg, plan))

    @property
    def session(self):
        """The compiled model (named for the pre-split attribute)."""
        return self.model


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduce", action="store_true",
                    help="reduced CPU config (32x32, dim 64, depth 2)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--images-per-request", type=int, default=3)
    ap.add_argument("--buckets", default=None,
                    help="comma-separated static batch buckets (default "
                         "2,8); the engine picks the cheapest per step")
    ap.add_argument("--backend", default=None,
                    choices=["packed", "reference"],
                    help="default packed")
    ap.add_argument("--weight-dtype", default=None,
                    choices=["float32", "int8"])
    ap.add_argument("--plan", default=None,
                    help="load a committed ExecutionPlan JSON (backend/"
                         "buckets flags still override)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: few requests, assert completion/shapes")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests = min(args.requests, 5)
        args.images_per_request = min(args.images_per_request, 2)

    cfg = SpikformerConfig()
    if args.reduce:
        cfg = cfg.scaled()
    params = spik_init(jax.random.PRNGKey(args.seed), cfg)

    # a committed --plan replays as-is; explicit flags (only) override it
    plan = (ExecutionPlan.from_json(open(args.plan).read()) if args.plan
            else ExecutionPlan(batch_buckets=(2, 8)))
    over = {}
    if args.backend is not None:
        over["backend"] = args.backend
    if args.buckets is not None:
        over["batch_buckets"] = tuple(int(b) for b in args.buckets.split(","))
    if args.weight_dtype is not None:
        over["weight_dtype"] = args.weight_dtype
    if over:
        plan = dataclasses.replace(plan, **over)
    model = compile(params, cfg, plan)
    compile_s = model.warmup()
    eng = MicroBatchEngine(model)

    rng = np.random.default_rng(args.seed + 1)
    for i in range(args.requests):
        imgs = rng.integers(0, 256, (args.images_per_request, cfg.img_size,
                                     cfg.img_size, cfg.in_channels),
                            dtype=np.uint8)
        eng.submit(ImageRequest(rid=i, images=imgs))

    done = eng.run()
    stats = eng.stats()
    summary = {
        "backend": model.backend.name,
        "weight_dtype": model.weight_dtype,
        "compile_s": round(compile_s, 3),
        **stats,
    }
    print(json.dumps(summary))

    if args.smoke:
        # the CI contract: every request completed, every label well-formed
        assert len(done) == args.requests, (len(done), args.requests)
        for req in done:
            assert len(req.labels) == len(req.images)
            assert all(isinstance(lab, int)
                       and 0 <= lab < cfg.num_classes for lab in req.labels)
        assert stats["images"] == args.requests * args.images_per_request
        print(json.dumps({"smoke": "ok", "requests": len(done),
                          "pad_waste": stats["pad_waste"]}))
    return summary


if __name__ == "__main__":
    main()
