"""Spikformer image-classification serving driver over the packed datapath.

Mirrors the continuous-batching shape of ``launch.serve``: requests (each
carrying one or more images) queue up, the engine drains them through ONE
jit-compiled fixed-batch ``InferenceSession`` step — images from different
requests share a batch (micro-batching), partial batches are padded, so the
step never recompiles. This is the paper's real-time classification serving
loop: VESTA sustains ~30 fps on Spikformer V2; the engine reports achieved
fps against that target.

  PYTHONPATH=src python -m repro.launch.serve_spikformer --reduce \
      --requests 12 --batch-size 8 --backend packed
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..core.spikformer import SpikformerConfig, init as spik_init
from ..infer import InferenceSession

PAPER_FPS = 30.0   # VESTA's reported real-time Spikformer V2 rate


@dataclasses.dataclass
class ImageRequest:
    rid: int
    images: np.ndarray              # (n, H, W, C) uint8
    labels: list = dataclasses.field(default_factory=list)
    t_arrival: float = 0.0
    t_done: float = 0.0


class SpikformerEngine:
    """Micro-batching classifier over a static-shape InferenceSession."""

    def __init__(self, params, cfg: SpikformerConfig, *, batch_size: int = 8,
                 backend: str = "packed"):
        self.session = InferenceSession(params, cfg, backend=backend,
                                        batch_size=batch_size)
        self.batch_size = batch_size
        self.queue: deque[tuple[ImageRequest, int]] = deque()  # (req, img idx)
        self.done: list[ImageRequest] = []
        self._pending: dict[int, int] = {}                     # rid -> left

    def submit(self, req: ImageRequest):
        req.t_arrival = time.time()
        self._pending[req.rid] = len(req.images)
        req.labels = [None] * len(req.images)
        for i in range(len(req.images)):
            self.queue.append((req, i))

    def step(self) -> int:
        """Classify one fused batch drawn across requests; returns #images."""
        if not self.queue:
            return 0
        work = [self.queue.popleft()
                for _ in range(min(self.batch_size, len(self.queue)))]
        batch = np.stack([req.images[i] for req, i in work])
        labels = self.session.classify(batch)
        for (req, i), lab in zip(work, np.asarray(labels)):
            req.labels[i] = int(lab)
            self._pending[req.rid] -= 1
            if self._pending[req.rid] == 0:
                req.t_done = time.time()
                self.done.append(req)
        return len(work)

    def run(self):
        while self.queue:
            self.step()
        return self.done


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduce", action="store_true",
                    help="reduced CPU config (32x32, dim 64, depth 2)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--images-per-request", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--backend", default="packed",
                    choices=["packed", "reference"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = SpikformerConfig()
    if args.reduce:
        cfg = cfg.scaled()
    params = spik_init(jax.random.PRNGKey(args.seed), cfg)
    eng = SpikformerEngine(params, cfg, batch_size=args.batch_size,
                           backend=args.backend)
    compile_s = eng.session.warmup()

    rng = np.random.default_rng(args.seed + 1)
    for i in range(args.requests):
        imgs = rng.integers(0, 256, (args.images_per_request, cfg.img_size,
                                     cfg.img_size, cfg.in_channels),
                            dtype=np.uint8)
        eng.submit(ImageRequest(rid=i, images=imgs))

    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0

    n_images = sum(len(r.images) for r in done)
    lat = [r.t_done - r.t_arrival for r in done]
    fps = n_images / wall
    summary = {
        "backend": args.backend,
        "requests": len(done),
        "images": n_images,
        "compile_s": round(compile_s, 3),
        "wall_s": round(wall, 3),
        "fps": round(fps, 2),
        "paper_fps": PAPER_FPS,
        "realtime": fps >= PAPER_FPS,
        "mean_latency_s": round(sum(lat) / len(lat), 4),
    }
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
