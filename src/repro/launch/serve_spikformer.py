"""Spikformer image-classification serving driver — a thin CLI over the
compile/serve split: ``repro.infer.compile`` builds the multi-bucket
``CompiledModel``, then either ``MicroBatchEngine`` drains a closed-loop
request queue through it (default) or — with ``--async`` —
``repro.serve.AsyncServeRuntime`` serves an OPEN-LOOP Poisson arrival
process at ``--rps`` for ``--duration`` seconds under an ``--slo-ms``
latency target. This is the paper's real-time classification serving loop:
VESTA sustains ~30 fps on Spikformer V2; the closed loop reports achieved
fps against that target, the open loop reports what a drain cannot —
goodput, p99 latency and SLO attainment under live load.

  PYTHONPATH=src python -m repro.launch.serve_spikformer --reduce \
      --requests 12 --buckets 2,8 --backend packed

  PYTHONPATH=src python -m repro.launch.serve_spikformer --reduce \
      --async --rps 60 --duration 3 --slo-ms 100

  PYTHONPATH=src python -m repro.launch.serve_spikformer --reduce --smoke
      # CI gate: a handful of requests, asserts all complete with correct
      # shapes and labels in range; with --async, asserts the open loop
      # sustains >= 30 fps with zero dropped-but-accepted requests
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from ..core.spikformer import SpikformerConfig, init as spik_init
from ..infer import ExecutionPlan, MicroBatchEngine, PAPER_FPS, compile
from ..infer.engine import Request
from ..obs import Tracer, write_chrome_trace, write_spans_jsonl
from ..serve import (AsyncServeRuntime, ServeFleet, ServePolicy,
                     image_maker, poisson_trace, run_open_loop)

# Pre-split names, kept importable: ImageRequest is the engine Request;
# SpikformerEngine is a construct-from-params convenience over the split.
ImageRequest = Request


def make_tracer(args):
    """One ``Tracer`` when ``--trace-out`` asks for a trace, else None —
    clients built with ``tracer=None`` run the NULL_TRACER fast path."""
    return Tracer() if args.trace_out else None


def dump_trace(tracer, path, *, meta=None):
    """Write the span JSONL plus the Perfetto sibling (``.perfetto.json``
    next to the JSONL); prints where they landed and how lossy the ring
    was. Returns the summary row."""
    n = write_spans_jsonl(path, tracer, meta=meta)
    perfetto = (path[:-len(".jsonl")] + ".perfetto.json"
                if path.endswith(".jsonl") else path + ".perfetto.json")
    write_chrome_trace(perfetto, tracer)
    row = {"trace_out": path, "perfetto": perfetto, "spans": n,
           "dropped_spans": tracer.dropped_spans}
    print(json.dumps(row))
    return row


class SpikformerEngine(MicroBatchEngine):
    """Micro-batching classifier built straight from training params —
    the pre-split constructor shape, now compile() + MicroBatchEngine."""

    def __init__(self, params, cfg: SpikformerConfig, *, batch_size: int = 8,
                 buckets=None, backend: str = "packed",
                 weight_dtype: str | None = None):
        plan = ExecutionPlan(backend=backend, weight_dtype=weight_dtype,
                             batch_buckets=buckets or (batch_size,))
        super().__init__(compile(params, cfg, plan))

    @property
    def session(self):
        """The compiled model (named for the pre-split attribute)."""
        return self.model


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduce", action="store_true",
                    help="reduced CPU config (32x32, dim 64, depth 2)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--images-per-request", type=int, default=3)
    ap.add_argument("--buckets", default=None,
                    help="comma-separated static batch buckets (default "
                         "2,8); the engine picks the cheapest per step")
    ap.add_argument("--backend", default=None,
                    choices=["packed", "reference"],
                    help="default packed")
    ap.add_argument("--weight-dtype", default=None,
                    choices=["float32", "int8"])
    ap.add_argument("--plan", default=None,
                    help="load a committed ExecutionPlan JSON (backend/"
                         "buckets flags still override)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="serve an open-loop Poisson arrival process through "
                         "AsyncServeRuntime instead of the closed-loop drain")
    ap.add_argument("--rps", type=float, default=60.0,
                    help="async: offered arrival rate, requests/second")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="async: seconds of open-loop arrivals")
    ap.add_argument("--slo-ms", type=float, default=100.0,
                    help="async: per-request latency target")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="async: continuous-batching window")
    ap.add_argument("--queue-depth", type=int, default=512,
                    help="async: admission bound, queued images")
    ap.add_argument("--replicas", type=int, default=1,
                    help="async: serve through a ServeFleet of this many "
                         "replicas (per-device on multi-device hosts, "
                         "thread-backed otherwise); 1 = single runtime")
    ap.add_argument("--pace-fps", type=float, default=None,
                    help="fleet: model each replica as a fixed-rate core "
                         "at this many images/second (labels stay real; "
                         "scaling curves measure placement, not host "
                         "cores)")
    ap.add_argument("--events", action="store_true",
                    help="serve the event-stream workload: replay a DVS "
                         "trace (--trace, or a synthesized one) through "
                         "the serving stack as per-window count frames")
    ap.add_argument("--trace", default=None,
                    help="events: path to a recorded JSONL event trace "
                         "(repro.events.trace format); the model is "
                         "compiled to the trace header's sensor shape")
    ap.add_argument("--trace-out", default=None,
                    help="write the request-lifecycle trace here as span "
                         "JSONL (a Perfetto-loadable .perfetto.json lands "
                         "next to it); works in every mode")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: few requests, assert completion/shapes")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests = min(args.requests, 5)
        args.images_per_request = min(args.images_per_request, 2)
        args.rps = min(args.rps, 60.0)
        args.duration = min(args.duration, 1.5)

    if args.events:
        return main_events(args)

    cfg = SpikformerConfig()
    if args.reduce:
        cfg = cfg.scaled()
    params = spik_init(jax.random.PRNGKey(args.seed), cfg)

    # a committed --plan replays as-is; explicit flags (only) override it
    plan = (ExecutionPlan.from_json(open(args.plan).read()) if args.plan
            else ExecutionPlan(batch_buckets=(2, 8)))
    over = {}
    if args.backend is not None:
        over["backend"] = args.backend
    if args.buckets is not None:
        over["batch_buckets"] = tuple(int(b) for b in args.buckets.split(","))
    if args.weight_dtype is not None:
        over["weight_dtype"] = args.weight_dtype
    if over:
        plan = dataclasses.replace(plan, **over)
    model = compile(params, cfg, plan)
    compile_s = model.warmup()

    if args.use_async:
        return main_async(model, args, compile_s)

    tracer = make_tracer(args)
    eng = MicroBatchEngine(model, tracer=tracer)

    rng = np.random.default_rng(args.seed + 1)
    for i in range(args.requests):
        imgs = rng.integers(0, 256, (args.images_per_request, cfg.img_size,
                                     cfg.img_size, cfg.in_channels),
                            dtype=np.uint8)
        eng.submit(ImageRequest(rid=i, images=imgs))

    done = eng.run()
    stats = eng.stats()
    if tracer is not None:
        dump_trace(tracer, args.trace_out, meta={"mode": "sync"})
    summary = {
        "backend": model.backend.name,
        "weight_dtype": model.weight_dtype,
        "compile_s": round(compile_s, 3),
        **stats,
    }
    print(json.dumps(summary))

    if args.smoke:
        # the CI contract: every request completed, every label well-formed
        assert len(done) == args.requests, (len(done), args.requests)
        for req in done:
            assert len(req.labels) == len(req.images)
            assert all(isinstance(lab, int)
                       and 0 <= lab < cfg.num_classes for lab in req.labels)
        assert stats["images"] == args.requests * args.images_per_request
        print(json.dumps({"smoke": "ok", "requests": len(done),
                          "pad_waste": stats["pad_waste"]}))
    return summary


def main_async(model, args, compile_s: float):
    """Open-loop serving: Poisson arrivals at --rps for --duration seconds
    through ``AsyncServeRuntime`` (or a ``ServeFleet`` of ``--replicas``),
    measured by ``repro.serve.loadgen``."""
    policy = ServePolicy(max_wait_ms=args.max_wait_ms, slo_ms=args.slo_ms,
                         max_queue_images=args.queue_depth)
    trace = poisson_trace(rps=args.rps, duration_s=args.duration,
                          seed=args.seed + 1,
                          images_per_request=(1, args.images_per_request))
    tracer = make_tracer(args)
    if args.replicas > 1:
        client = ServeFleet(model, replicas=args.replicas, policy=policy,
                            pace_fps=args.pace_fps, tracer=tracer)
    else:
        client = AsyncServeRuntime(model, policy=policy, tracer=tracer)
    with client:
        metrics = run_open_loop(
            client, trace, image_maker(model.input_shape()[1:],
                                       seed=args.seed + 2),
            slo_ms=args.slo_ms)
    if tracer is not None:
        dump_trace(tracer, args.trace_out,
                   meta={"mode": "fleet" if args.replicas > 1 else "async",
                         "replicas": args.replicas})
    summary = {
        "backend": model.backend.name,
        "weight_dtype": model.weight_dtype,
        "compile_s": round(compile_s, 3),
        "mode": ("fleet_open_loop" if args.replicas > 1
                 else "async_open_loop"),
        "replicas": args.replicas,
        "paper_fps": PAPER_FPS,
        **metrics,
        "runtime": client.stats(),
    }
    print(json.dumps(summary))

    if args.smoke:
        # the CI contract for the open loop: an accepted request is a
        # promise (zero dropped), labels are well-formed, and the paper's
        # real-time rate is sustained at the smoke arrival rate
        assert metrics["requests_dropped"] == 0, metrics
        assert metrics["requests_offered"] == len(trace)
        # smoke offers at most rps*duration (~90) requests against a
        # 512-image admission bound: a rejection here is a real bug
        assert metrics["requests_rejected"] == 0, metrics
        n_classes = model.cfg.num_classes
        for req in client.done:
            assert len(req.labels) == len(req.images)
            assert all(isinstance(lab, int) and 0 <= lab < n_classes
                       for lab in req.labels)
        assert metrics["completed_fps"] >= PAPER_FPS, metrics
        if args.replicas > 1:
            # fleet floor: N replicas sustain N x the single-replica
            # real-time rate, and the fleet kept every promise
            assert metrics["goodput_fps"] >= args.replicas * PAPER_FPS, \
                metrics
            health = client.health()
            assert all(r["failures"] == 0 for r in health["replicas"]), \
                health
        print(json.dumps({"smoke": "ok", "mode": summary["mode"],
                          "replicas": args.replicas,
                          "completed_fps": metrics["completed_fps"],
                          "goodput_fps": metrics["goodput_fps"],
                          "slo_attainment": metrics["slo_attainment"]}))
    return summary


def synth_event_trace(*, seed: int, height: int = 16, width: int = 16):
    """A deterministic in-memory stand-in when no --trace is given: a
    moving edge plus flicker bursts, windowed exactly as
    ``scripts/record_event_trace.py`` commits its fixture."""
    from ..events import (EventTrace, TraceArrival, flicker_burst_events,
                          merge_streams, moving_edge_events)
    window_us = 20_000
    duration_us = 800_000
    stream = merge_streams(
        moving_edge_events(height=height, width=width,
                           duration_us=duration_us // 4, seed=seed),
        flicker_burst_events(height=height, width=width,
                             duration_us=duration_us, seed=seed + 1,
                             bursts=3))
    arrivals = []
    for w in range(duration_us // window_us):
        ev = stream.slice_time(w * window_us, (w + 1) * window_us)
        if len(ev):
            arrivals.append(TraceArrival(
                t_s=(w + 1) * window_us / 1e6, window=w,
                events=ev.shift_time(-w * window_us)))
    return EventTrace(height=height, width=width, window_us=window_us,
                      bins=8, payload="events", arrivals=tuple(arrivals))


def main_events(args):
    """Event-stream serving: replay a DVS trace's windows (count frames at
    the recorded arrival times) through the runtime or fleet; in --smoke,
    additionally replay it TWICE and assert the labels are bit-identical
    — the trace-replay determinism contract, as a CI gate."""
    from ..events import load_trace, replay_trace
    trace = (load_trace(args.trace) if args.trace
             else synth_event_trace(seed=args.seed))
    if trace.height != trace.width:
        raise SystemExit(
            f"trace sensor is {trace.height}x{trace.width}; the Spikformer "
            f"front end serves square inputs — re-record or crop")
    cfg = dataclasses.replace(
        SpikformerConfig().scaled(img_size=trace.height, dim=32, depth=1),
        in_channels=trace.channels)
    params = spik_init(jax.random.PRNGKey(args.seed), cfg)
    plan = (ExecutionPlan.from_json(open(args.plan).read()) if args.plan
            else ExecutionPlan(batch_buckets=(2, 8)))
    over = {}
    if args.backend is not None:
        over["backend"] = args.backend
    if args.buckets is not None:
        over["batch_buckets"] = tuple(int(b) for b in args.buckets.split(","))
    if args.weight_dtype is not None:
        over["weight_dtype"] = args.weight_dtype
    if over:
        plan = dataclasses.replace(plan, **over)
    model = compile(params, cfg, plan)
    compile_s = model.warmup()
    policy = ServePolicy(max_wait_ms=args.max_wait_ms, slo_ms=args.slo_ms,
                         max_queue_images=args.queue_depth)

    def run_once(tracer=None):
        if args.replicas > 1:
            client = ServeFleet(model, replicas=args.replicas, policy=policy,
                                pace_fps=args.pace_fps, tracer=tracer)
        else:
            client = AsyncServeRuntime(model, policy=policy, tracer=tracer)
        with client:
            metrics = replay_trace(trace, client, slo_ms=args.slo_ms)
        metrics["runtime"] = client.stats()
        return metrics

    tracer = make_tracer(args)
    metrics = run_once(tracer)
    if tracer is not None:
        dump_trace(tracer, args.trace_out,
                   meta={"mode": "events", "replicas": args.replicas})
    summary = {
        "backend": model.backend.name,
        "weight_dtype": model.weight_dtype,
        "compile_s": round(compile_s, 3),
        "mode": "event_replay",
        "trace": args.trace or "synthetic",
        "sensor": [trace.height, trace.width, trace.channels],
        "window_us": trace.window_us,
        "replicas": args.replicas,
        **{k: v for k, v in metrics.items() if k != "labels"},
    }
    print(json.dumps(summary))

    if args.smoke:
        # the event-serving CI contract: every window served (zero drops,
        # zero shed at smoke rates), on time, and deterministically
        assert metrics["requests_dropped"] == 0, summary
        assert metrics["requests_rejected"] == 0, summary
        assert metrics["slo_attainment"] == 1.0, summary
        n_classes = cfg.num_classes
        for labs in metrics["labels"]:
            assert labs is not None and len(labs) == 1, labs
            assert 0 <= labs[0] < n_classes, labs
        replay = run_once()
        assert replay["labels_sha"] == metrics["labels_sha"], (
            "trace replay is not deterministic",
            replay["labels_sha"], metrics["labels_sha"])
        print(json.dumps({"smoke": "ok", "mode": "event_replay",
                          "windows": metrics["windows"],
                          "replicas": args.replicas,
                          "labels_sha": metrics["labels_sha"],
                          "slo_attainment": metrics["slo_attainment"],
                          "dispersion_index": metrics["dispersion_index"]}))
    return summary


if __name__ == "__main__":
    main()
