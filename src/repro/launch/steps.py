"""Step builders: the jit-able train / prefill / serve(decode) programs for
any ArchConfig, plus their in/out sharding trees for a given mesh.

train_step microbatches via lax.scan (gradient accumulation) so the full-
vocab logits only ever exist for one microbatch — without this, a 4k x 256
global batch against a 152k vocab would materialize hundreds of TB.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..nn import transformer as T
from ..optim import adamw
from ..optim.compression import ef_compress
from ..sharding import rules


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    microbatch: int = 32          # rows per accumulation step
    compression: str = "none"     # none | int8 | topk
    accum_dtype: str = "float32"  # grad accumulator; bf16 for the >=100B
    # configs, where an fp32 copy of the grads (4 bytes/param/chip even under
    # FSDP) would blow the 16 GB HBM budget
    opt: adamw.OptConfig = dataclasses.field(default_factory=adamw.OptConfig)


def make_train_step(cfg: ArchConfig, ts: TrainSettings, param_shardings=None):
    opt_cfg = dataclasses.replace(
        ts.opt, state_dtype=jnp.dtype(cfg.opt_state_dtype))

    def constrain(tree):
        if param_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, param_shardings)

    def train_step(params, opt_state, batch):
        b = batch["tokens"].shape[0]
        micro = min(ts.microbatch, b)
        accum = b // micro

        def mrope_split(x):  # (3, B, S) -> (accum, 3, micro, S)
            return jnp.moveaxis(
                x.reshape(3, accum, micro, x.shape[-1]), 1, 0)

        mb = {}
        for k, v in batch.items():
            mb[k] = mrope_split(v) if k == "mrope_positions" else \
                v.reshape((accum, micro) + v.shape[1:])

        grad_fn = jax.value_and_grad(T.lm_loss, has_aux=True)

        acc_dt = jnp.dtype(ts.accum_dtype)

        def acc_step(carry, mbatch):
            gsum, lsum = carry
            (loss, aux), g = grad_fn(params, mbatch, cfg)
            gsum = jax.tree_util.tree_map(
                lambda a, b_: a + b_.astype(acc_dt), gsum, g)
            # keep the accumulator sharded exactly like the params —
            # otherwise SPMD replicates it onto every chip
            return (constrain(gsum), lsum + loss), None

        gzero = constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params))
        (gsum, lsum), _ = jax.lax.scan(acc_step, (gzero, 0.0), mb)
        grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
        loss = lsum / accum

        if ts.compression != "none":
            ef = opt_state["ef"]
            grads, new_ef = ef_compress(grads, ef, method=ts.compression)
        new_params, new_opt, metrics = adamw.update(
            grads, {k: v for k, v in opt_state.items() if k != "ef"},
            params, opt_cfg)
        if ts.compression != "none":
            new_opt["ef"] = new_ef
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill(cfg: ArchConfig, shape: ShapeSpec):
    def prefill(params, batch):
        b, s = batch["tokens"].shape
        cache = T.init_cache(cfg, b, s)
        batch = dict(batch, cache_pos=jnp.int32(0))
        logits, new_cache, _ = T.model_apply(
            params, batch, cfg, mode="prefill", cache=cache)
        return logits, new_cache
    return prefill


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, batch):
        logits, new_cache, _ = T.model_apply(
            params, batch, cfg, mode="decode", cache=cache)
        token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return token, new_cache
    return serve_step


# ---------------------------------------------------------------------------
# sharded jit builders
# ---------------------------------------------------------------------------

def abstract_params(cfg: ArchConfig, seed: int = 0):
    return jax.eval_shape(
        lambda k: T.init_model(k, cfg), jax.random.PRNGKey(seed))


def abstract_opt_state(cfg: ArchConfig, params_shapes, ts: TrainSettings):
    opt_cfg = dataclasses.replace(
        ts.opt, state_dtype=jnp.dtype(cfg.opt_state_dtype))
    st = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params_shapes)
    if ts.compression != "none":
        from ..optim.compression import ef_init
        st = dict(st, ef=jax.eval_shape(ef_init, params_shapes))
    return st


def jit_train_step(cfg: ArchConfig, mesh, ts: TrainSettings,
                   batch_shapes: dict):
    p_sh = abstract_params(cfg)
    o_sh = abstract_opt_state(cfg, p_sh, ts)
    in_sh = (rules.param_shardings(mesh, p_sh),
             rules.opt_state_shardings(mesh, o_sh),
             rules.batch_shardings(mesh, batch_shapes))
    out_sh = (in_sh[0], in_sh[1],
              jax.tree_util.tree_map(
                  lambda _: NamedSharding(mesh, P()),
                  {"grad_norm": 0, "lr": 0, "loss": 0}))
    step = jax.jit(make_train_step(cfg, ts, param_shardings=in_sh[0]),
                   in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 1))
    return step, (p_sh, o_sh, batch_shapes), in_sh


def jit_serve_step(cfg: ArchConfig, mesh, cache_shapes, batch_shapes):
    p_sh = abstract_params(cfg)
    c_sh = rules.cache_shardings(mesh, cache_shapes)
    in_sh = (rules.param_shardings(mesh, p_sh), c_sh,
             rules.batch_shardings(mesh, batch_shapes))
    tok_sh = rules.batch_shardings(
        mesh, {"t": jax.ShapeDtypeStruct(
            (batch_shapes["tokens"].shape[0],), jnp.int32)})["t"]
    step = jax.jit(make_serve_step(cfg), in_shardings=in_sh,
                   out_shardings=(tok_sh, c_sh), donate_argnums=(1,))
    return step, (p_sh, cache_shapes, batch_shapes), in_sh


def jit_prefill(cfg: ArchConfig, mesh, shape: ShapeSpec, batch_shapes):
    p_sh = abstract_params(cfg)
    in_sh = (rules.param_shardings(mesh, p_sh),
             rules.batch_shardings(mesh, batch_shapes))
    cache_shapes = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.batch, shape.seq))
    c_sh = rules.cache_shardings(mesh, cache_shapes)
    fn = jax.jit(make_prefill(cfg, shape), in_shardings=in_sh,
                 out_shardings=(None, c_sh))
    return fn, (p_sh, batch_shapes), in_sh
