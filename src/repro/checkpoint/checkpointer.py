"""Async, sharded, elastic checkpointing.

Layout (mesh-shape independent => restores onto ANY device count):

    <dir>/step_000100/
        manifest.json     {step, leaves: {path: {shape, dtype, checksum}},
                           extra: {...}}   — written LAST (commit marker)
        <flat-path>.npy   one array per param/opt/data leaf, full value

Properties a 1000-node deployment needs:
  * async  — `save()` snapshots device arrays to host memory synchronously
    (cheap) and writes files on a background thread; the train loop never
    blocks on disk. `wait()` joins before the next save or exit.
  * atomic — files land in `step_xxx.tmp/`, renamed to `step_xxx/` after the
    manifest is fsynced; a crash mid-write never corrupts the latest
    checkpoint; `latest_step()` only sees committed directories.
  * elastic — leaves are saved UNSHARDED (gathered): restore takes a target
    sharding tree for any mesh and `jax.device_put`s each leaf; nothing in
    the layout encodes the device count it was saved from.
  * integrity — crc32 per leaf, verified on restore.
  * GC — keep the newest `keep` checkpoints.

On a real multi-host pod, gathering to host 0 is replaced by
per-shard writes (process-local addressable shards); the manifest/commit
protocol is unchanged. This container is single-process, so the gather path
is exact rather than simulated.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
import zlib

import jax
import numpy as np

from ..nn.module import map_with_path


def _flat(tree) -> dict:
    out = {}

    def add(path, leaf):
        out[path] = leaf
        return leaf

    map_with_path(add, tree)
    return out


def _unflatten_into(skeleton, flat: dict):
    """Rebuild `skeleton`'s topology with arrays from `flat` (path-keyed)."""
    return map_with_path(lambda path, leaf: flat[path], skeleton)


@dataclasses.dataclass
class Checkpointer:
    directory: str
    keep: int = 3

    def __post_init__(self):
        self.dir = pathlib.Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None,
             block: bool = False):
        """Snapshot `tree` (any pytree of arrays) at `step`. Returns fast;
        file IO happens on a background thread."""
        self.wait()  # one in-flight save at a time
        # synchronous host snapshot: device -> host memory (np arrays)
        host = {p: np.asarray(jax.device_get(a)) for p, a in _flat(tree).items()}
        extra = dict(extra or {})

        def write():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                leaves = {}
                for path, arr in host.items():
                    fname = path.replace("/", ".") + ".npy"
                    np.save(tmp / fname, arr)
                    leaves[path] = {
                        "file": fname,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
                    }
                manifest = {"step": step, "leaves": leaves, "extra": extra}
                mpath = tmp / "manifest.json"
                with open(mpath, "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)          # the commit point
                self._gc()
            except Exception as e:  # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self._committed())
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- discovery --------------------------------------------------------------
    def _committed(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return out

    def latest_step(self) -> int | None:
        steps = self._committed()
        return max(steps) if steps else None

    # -- restore ------------------------------------------------------------------
    def restore(self, step: int | None = None, *, skeleton=None,
                shardings=None, verify: bool = True):
        """Load checkpoint `step` (default latest). Returns (tree, extra).

        skeleton: pytree with the target topology (shapes may come from
        eval_shape); shardings: congruent tree of NamedShardings for the
        TARGET mesh (elastic restore reshards here); either may be None —
        without a skeleton the flat {path: array} dict is returned.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())

        flat = {}
        for path, meta in manifest["leaves"].items():
            arr = np.load(cdir / meta["file"])
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc32"]:
                    raise IOError(f"checksum mismatch for {path} at step {step}")
            flat[path] = arr

        if skeleton is None:
            return flat, manifest.get("extra", {})

        tree = _unflatten_into(skeleton, flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
        return tree, manifest.get("extra", {})
