"""Event-stream → packed-plane-group encoding: the DVS front door.

A dynamic-vision-sensor (DVS) camera does not produce frames; it produces
a sparse stream of events ``(x, y, t_us, polarity)`` — one record per
pixel whose log-intensity crossed a threshold, ON (brighter) or OFF
(darker). That stream is ALREADY spike-form data: binary, temporal,
mostly silence. The packed plane-group representation the whole inference
datapath runs on (``core.spike.pack_timesteps``: bit j of group g =
timestep ``8g + j``) is its native encoding, and this module connects the
two WITHOUT the dense detour: ``encode_events_to_plane_groups`` time-bins
a window of events into ``ceil(T/8)`` uint8 plane groups by OR-ing each
event's bit directly into its byte — no (T, H, W, C) tensor is ever
materialized. ``rasterize_events`` builds exactly that dense tensor as
the test oracle: ``pack_timesteps(rasterize_events(...))`` must be
bit-identical to the direct encoding (``tests/test_events.py`` pins it
for T ∈ {1, 8, 9, 16, 17}, both polarities, empty windows included).

Polarity is the channel axis: channel 0 = OFF, channel 1 = ON — two
binary channels, the DVS convention Spikformer-family models use for
CIFAR10-DVS / DVS128 Gesture.

The module also owns the per-window readouts serving calibrates with
(``window_occupancy`` → chunk occupancy for the zero-chunk-skipping
route's ``sparse_budget``; ``core.spike.packed_occupancy`` → firing
rate), the count-frame encoding (``events_to_frame``) that feeds a
window to the SSSC uint8 front end as a servable image, and seeded
synthetic DVS generators (``moving_edge_events``, ``flicker_burst_events``)
— deterministic stand-ins until real recordings land.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# polarity → channel: OFF (darker) = 0, ON (brighter) = 1
POLARITIES = 2


@dataclasses.dataclass(frozen=True)
class EventStream:
    """A sparse DVS event stream over a ``height`` x ``width`` sensor.

    Four parallel arrays, one entry per event: pixel column ``x``
    (int32, in [0, width)), pixel row ``y`` (int32, in [0, height)),
    microsecond timestamp ``t_us`` (int64, sorted non-decreasing — a
    camera emits in time order and every consumer here depends on it),
    and ``polarity`` (uint8, 0=OFF / 1=ON). Validation is loud and at
    construction: an out-of-range coordinate corrupts a plane silently
    if it reaches the encoder's scatter."""
    height: int
    width: int
    x: np.ndarray
    y: np.ndarray
    t_us: np.ndarray
    polarity: np.ndarray

    def __post_init__(self):
        if self.height < 1 or self.width < 1:
            raise ValueError(f"sensor must be at least 1x1, got "
                             f"{self.height}x{self.width}")
        arrays = {
            "x": np.asarray(self.x, np.int32),
            "y": np.asarray(self.y, np.int32),
            "t_us": np.asarray(self.t_us, np.int64),
            "polarity": np.asarray(self.polarity, np.uint8),
        }
        n = {len(a) for a in arrays.values()}
        if len(n) != 1:
            raise ValueError(
                f"event arrays must be parallel; got lengths "
                f"{ {k: len(v) for k, v in arrays.items()} }")
        for name, lo, hi in (("x", 0, self.width), ("y", 0, self.height),
                             ("polarity", 0, POLARITIES)):
            a = arrays[name]
            if a.size and (int(a.min()) < lo or int(a.max()) >= hi):
                raise ValueError(
                    f"event {name} values must lie in [{lo}, {hi}); got "
                    f"range [{int(a.min())}, {int(a.max())}]")
        t = arrays["t_us"]
        if t.size and np.any(np.diff(t) < 0):
            k = int(np.argmax(np.diff(t) < 0))
            raise ValueError(
                f"event timestamps must be sorted non-decreasing; "
                f"t_us[{k + 1}]={int(t[k + 1])} < t_us[{k}]={int(t[k])}")
        for name, a in arrays.items():
            object.__setattr__(self, name, a)

    def __len__(self) -> int:
        return len(self.x)

    def slice_time(self, lo_us: int, hi_us: int) -> "EventStream":
        """Events with ``lo_us <= t_us < hi_us`` (O(log n) on the sorted
        timestamps), as a new stream."""
        a = int(np.searchsorted(self.t_us, lo_us, side="left"))
        b = int(np.searchsorted(self.t_us, hi_us, side="left"))
        return EventStream(self.height, self.width, self.x[a:b],
                           self.y[a:b], self.t_us[a:b], self.polarity[a:b])

    def shift_time(self, delta_us: int) -> "EventStream":
        """The same events with ``delta_us`` added to every timestamp —
        how a trace stores window-relative times."""
        return EventStream(self.height, self.width, self.x, self.y,
                           self.t_us + np.int64(delta_us), self.polarity)


def empty_stream(height: int, width: int) -> EventStream:
    """An event stream with no events (an all-quiet window)."""
    z = np.zeros(0, np.int64)
    return EventStream(height, width, z, z, z, z)


def merge_streams(*streams: EventStream) -> EventStream:
    """Merge event streams over the SAME sensor into one time-sorted
    stream (stable: simultaneous events keep their argument order)."""
    if not streams:
        raise ValueError("merge_streams needs at least one stream")
    h, w = streams[0].height, streams[0].width
    for s in streams:
        if (s.height, s.width) != (h, w):
            raise ValueError(
                f"cannot merge streams over different sensors: "
                f"{h}x{w} vs {s.height}x{s.width}")
    t = np.concatenate([s.t_us for s in streams])
    order = np.argsort(t, kind="stable")
    return EventStream(
        h, w,
        np.concatenate([s.x for s in streams])[order],
        np.concatenate([s.y for s in streams])[order],
        t[order],
        np.concatenate([s.polarity for s in streams])[order])


# ---------------------------------------------------------------------------
# Encoding: events -> packed plane groups / dense rasterization / count frame
# ---------------------------------------------------------------------------

def encode_events_to_plane_groups(events: EventStream, *, t: int,
                                  window_us: int,
                                  t0_us: int = 0) -> np.ndarray:
    """Time-bin ``t`` windows of ``window_us`` starting at ``t0_us``
    straight into packed plane groups: ``(ceil(t/8), H, W, 2)`` uint8,
    bit j of group g set iff any event hit that pixel/polarity during
    bin ``8g + j`` — the exact layout ``core.spike.pack_timesteps``
    produces from a dense rasterization, built here by OR-ing one bit per
    event (the dense (T, H, W, C) tensor never exists; for a 128x128
    sensor at T=16 that detour would be 170x the size of the events).

    Events outside ``[t0_us, t0_us + t * window_us)`` are ignored — the
    caller slices its stream into windows; stragglers are its policy, not
    a silent wraparound here. Bits past ``t - 1`` in the last group stay
    zero (the packing invariant every popcount readout relies on)."""
    if t < 1:
        raise ValueError(f"t must be >= 1, got {t!r}")
    if window_us < 1:
        raise ValueError(f"window_us must be >= 1, got {window_us!r}")
    g = -(-t // 8)
    planes = np.zeros((g, events.height, events.width, POLARITIES), np.uint8)
    if len(events):
        b = (events.t_us - np.int64(t0_us)) // window_us
        keep = (b >= 0) & (b < t)
        b = b[keep].astype(np.int64)
        np.bitwise_or.at(
            planes,
            (b >> 3, events.y[keep], events.x[keep], events.polarity[keep]),
            np.uint8(1) << (b & 7).astype(np.uint8))
    return planes


def rasterize_events(events: EventStream, *, t: int, window_us: int,
                     t0_us: int = 0) -> np.ndarray:
    """The dense detour, kept as the ORACLE: ``(t, H, W, 2)`` binary uint8
    spike planes (plane i = events in bin i). ``pack_timesteps`` of this
    must equal ``encode_events_to_plane_groups`` bit for bit — the
    equivalence test that proves the direct encoder; production code has
    no reason to call this."""
    if t < 1:
        raise ValueError(f"t must be >= 1, got {t!r}")
    if window_us < 1:
        raise ValueError(f"window_us must be >= 1, got {window_us!r}")
    dense = np.zeros((t, events.height, events.width, POLARITIES), np.uint8)
    if len(events):
        b = (events.t_us - np.int64(t0_us)) // window_us
        keep = (b >= 0) & (b < t)
        dense[b[keep], events.y[keep], events.x[keep],
              events.polarity[keep]] = 1
    return dense


def events_to_frame(events: EventStream, *,
                    clip: int = 255) -> np.ndarray:
    """A window of events as a servable image: per-pixel/polarity event
    COUNTS, saturating at ``clip``, as ``(H, W, 2)`` uint8 — the standard
    DVS "event-count frame". This is what an ``EventStreamSession``
    submits: the SSSC front end consumes uint8 bit-planes natively, so a
    count frame rides the existing serving door (``validate_images``)
    with a model compiled at ``in_channels=2``."""
    if not 1 <= clip <= 255:
        raise ValueError(f"clip must be in [1, 255], got {clip!r}")
    counts = np.zeros((events.height, events.width, POLARITIES), np.int32)
    if len(events):
        np.add.at(counts, (events.y, events.x, events.polarity), 1)
    return np.minimum(counts, clip).astype(np.uint8)


def window_occupancy(planes: np.ndarray, *, t: int) -> float:
    """CHUNK occupancy of an encoded window: the fraction of live planes
    x pixels whose (≤8-channel) chunk holds at least one event — the
    quantity the zero-chunk-skipping route's ``sparse_budget`` and
    ``choose_route`` consume (``infer.backends.chunk_occupancy`` computes
    the same number on the jax side; ``tests/test_events.py`` pins the
    agreement). Per-window, this is the ingestion-time signal for
    sparse-route calibration: a quiet sensor window should be SERVED like
    the sparse batch it is."""
    g = planes.shape[0]
    if g != -(-t // 8):
        raise ValueError(f"{g} plane groups cannot hold t={t} bins")
    bits = np.unpackbits(planes[..., None], axis=-1, bitorder="little")
    # (g, H, W, C, 8) -> (g*8 planes, H, W): a plane's pixel-chunk is live
    # iff any channel fired that bin
    live = np.moveaxis(bits, -1, 1).reshape(g * 8, *planes.shape[1:-1],
                                            planes.shape[-1]).any(axis=-1)
    return float(live[:t].mean())


# ---------------------------------------------------------------------------
# Seeded synthetic DVS generators
# ---------------------------------------------------------------------------

def moving_edge_events(*, height: int, width: int, duration_us: int,
                       seed: int, sweeps: float = 1.0,
                       fire_prob: float = 0.9) -> EventStream:
    """A vertical edge sweeping left→right across the sensor ``sweeps``
    times over ``duration_us``: the edge's leading column fires ON, the
    trailing column fires OFF, each pixel with probability ``fire_prob``
    and jittered timing within its column's dwell. The classic
    moving-stimulus DVS pattern — steady event rate, spatially coherent.
    Deterministic from ``seed``."""
    if duration_us < 1 or sweeps <= 0:
        raise ValueError(f"need duration_us >= 1 and sweeps > 0, got "
                         f"{duration_us!r}, {sweeps!r}")
    rng = np.random.default_rng(seed)
    steps = max(1, int(round(sweeps * width)))
    dwell = duration_us / steps
    xs, ys, ts, ps = [], [], [], []
    for s in range(steps):
        col = s % width
        t_lo = s * dwell
        for polarity, x in ((1, col), (0, (col - 1) % width)):
            rows = np.flatnonzero(rng.random(height) < fire_prob)
            if not rows.size:
                continue
            jitter = rng.integers(0, max(1, int(dwell)), rows.size)
            xs.append(np.full(rows.size, x, np.int64))
            ys.append(rows.astype(np.int64))
            ts.append((int(t_lo) + jitter).astype(np.int64))
            ps.append(np.full(rows.size, polarity, np.int64))
    if not xs:
        return empty_stream(height, width)
    t = np.concatenate(ts)
    order = np.argsort(t, kind="stable")
    return EventStream(height, width,
                       np.concatenate(xs)[order], np.concatenate(ys)[order],
                       np.minimum(t[order], duration_us - 1),
                       np.concatenate(ps)[order])


def flicker_burst_events(*, height: int, width: int, duration_us: int,
                         seed: int, bursts: int = 4,
                         burst_us: int | None = None,
                         patch: int | None = None,
                         events_per_burst: int = 400) -> EventStream:
    """ON/OFF burst traffic: ``bursts`` flicker episodes evenly spaced
    over ``duration_us``, each confined to a random ``patch`` x ``patch``
    region and a ``burst_us`` span, dense inside and SILENT between — the
    arrival process that actually stresses a serving queue (a blinking
    LED / flickering luminaire in a DVS recording). Deterministic from
    ``seed``."""
    if duration_us < 1 or bursts < 1 or events_per_burst < 1:
        raise ValueError(f"need duration_us, bursts, events_per_burst >= 1, "
                         f"got {duration_us!r}, {bursts!r}, "
                         f"{events_per_burst!r}")
    patch = patch or max(1, min(height, width) // 4)
    if patch > min(height, width):
        raise ValueError(f"patch {patch} exceeds sensor {height}x{width}")
    period = duration_us // bursts
    burst_us = burst_us or max(1, period // 4)
    if burst_us > period:
        raise ValueError(f"burst_us={burst_us} exceeds the per-burst "
                         f"period {period}")
    rng = np.random.default_rng(seed)
    xs, ys, ts, ps = [], [], [], []
    for k in range(bursts):
        x0 = int(rng.integers(0, width - patch + 1))
        y0 = int(rng.integers(0, height - patch + 1))
        t_lo = k * period
        n = events_per_burst
        xs.append(rng.integers(x0, x0 + patch, n))
        ys.append(rng.integers(y0, y0 + patch, n))
        ts.append(t_lo + np.sort(rng.integers(0, burst_us, n)))
        ps.append(rng.integers(0, POLARITIES, n))
    t = np.concatenate(ts)
    order = np.argsort(t, kind="stable")
    return EventStream(height, width,
                       np.concatenate(xs)[order], np.concatenate(ys)[order],
                       np.minimum(t[order], duration_us - 1).astype(np.int64),
                       np.concatenate(ps)[order])
