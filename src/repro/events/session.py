"""``EventStreamSession`` — streaming DVS ingestion over any ServeClient.

The serving stack speaks requests: ``submit(images) -> handle``. A camera
speaks a continuous event stream. This session is the adapter: feed it
events as they arrive, it accumulates them into fixed-duration windows
(``window_us``), and each time the stream's watermark crosses a window
boundary the closed window is encoded (``events_to_frame`` — a count
frame the SSSC front end consumes natively) and submitted as one request
to whatever ``ServeClient`` backs the session — the sync engine, the
async runtime, or a fleet; the session neither knows nor cares.

Backpressure is the serving stack's existing admission control: a
``QueueFull`` at the submit door SHEDS the window (counted in
``windows_shed``, recorded on the window row) — an event camera cannot
be paused, so under overload the freshest data wins and the loss is
explicit, never a silent buffer. Per-window labels stream back through
the existing per-image callback (``on_window(window, label)`` fires from
the serving worker thread as each window's batch completes).

Every closed window also gets its ingestion-time sparsity readouts —
chunk occupancy (``encoding.window_occupancy``, the ``sparse_budget``
input) and firing rate (``core.spike.packed_occupancy``) over the
window's ``bins``-bin plane-group encoding — so a deployment can
calibrate the sparse route from live traffic before any label returns.

With ``capture=True`` the session records every submitted window's
arrival time and event payload; ``save_trace`` writes the versioned
JSONL trace ``repro.events.trace`` replays deterministically.
"""
from __future__ import annotations

import threading
import time

from ..core.spike import packed_occupancy
from ..obs.trace import NULL_TRACER
from ..serve.scheduler import QueueFull
from .encoding import (EventStream, empty_stream,
                       encode_events_to_plane_groups, events_to_frame,
                       merge_streams, window_occupancy)


class EventStreamSession:
    """Accumulate a DVS event stream into fixed windows and serve them.

        session = EventStreamSession(client, window_us=20_000,
                                     height=16, width=16,
                                     on_window=lambda w, lab: ...)
        session.feed(events)        # any number of times, time-ordered
        session.feed(more_events)
        session.close()             # flush the open window + drain
        session.windows             # per-window rows: occupancy, label...

    ``feed`` is watermark-driven: an incoming event at time t closes every
    window ending at or before t (events are the only clock a sensor
    stream carries). Events older than an already-closed window boundary
    raise — the encoder would have to rewrite a submitted frame, so late
    data is a contract violation, not a silent drop. Windows with no
    events are skipped unless ``submit_empty=True`` (a DVS's silence is
    data, but serving an all-zeros frame is usually wasted work —
    skipping is also what makes a replayed quiet period LOOK quiet to the
    scheduler).
    """

    def __init__(self, client, *, window_us: int, height: int, width: int,
                 bins: int = 8, t0_us: int = 0, on_window=None,
                 submit_empty: bool = False, capture: bool = False,
                 clock=time.perf_counter, tracer=None):
        if window_us < 1:
            raise ValueError(f"window_us must be >= 1, got {window_us!r}")
        if bins < 1 or window_us % bins:
            raise ValueError(
                f"bins must be >= 1 and divide window_us (the occupancy "
                f"readout sub-bins the window); got bins={bins!r}, "
                f"window_us={window_us!r}")
        self.client = client
        self.window_us = int(window_us)
        self.height, self.width = int(height), int(width)
        self.bins = int(bins)
        self.t0_us = int(t0_us)
        self.on_window = on_window
        self.submit_empty = submit_empty
        self.capture = capture
        self._clock = clock
        # window spans ("window"/encode, shed, complete — rid is the window
        # index) land next to the client's request spans when the same
        # tracer is shared, so a Perfetto view shows ingestion and serving
        # on one timeline
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._t_start = None              # wall clock at first feed
        self._open: list[EventStream] = []   # events of the OPEN window
        self._window = 0                  # index of the open window
        self._handles: list = []          # submit handles, arrival order
        self.windows: list[dict] = []     # one row per closed window
        self.captured: list[tuple] = []   # (t_s, window, EventStream)
        self.windows_shed = 0
        self.windows_empty = 0
        self.events_seen = 0
        self._lock = threading.Lock()     # guards label writes (worker thread)

    # -- window bookkeeping -------------------------------------------------

    def _win_start_us(self, w: int) -> int:
        return self.t0_us + w * self.window_us

    def feed(self, events: EventStream) -> None:
        """Ingest a time-ordered batch of events, closing (and serving)
        every window the batch's timestamps move past."""
        if (events.height, events.width) != (self.height, self.width):
            raise ValueError(
                f"events are {events.height}x{events.width} but this "
                f"session serves a {self.height}x{self.width} sensor")
        if not len(events):
            return
        if self._t_start is None:
            self._t_start = self._clock()
        lo = int(events.t_us[0])
        if lo < self._win_start_us(self._window):
            raise ValueError(
                f"event at t_us={lo} precedes the open window starting at "
                f"{self._win_start_us(self._window)}us; window "
                f"{self._window - 1} was already closed and served — a "
                f"stream must be fed in time order")
        self.events_seen += len(events)
        hi = int(events.t_us[-1])
        # the watermark: every window fully before ``hi`` is closeable
        while hi >= self._win_start_us(self._window + 1):
            w_lo = self._win_start_us(self._window)
            w_hi = w_lo + self.window_us
            self._open.append(events.slice_time(w_lo, w_hi))
            self._close_window()
        tail = events.slice_time(self._win_start_us(self._window),
                                 hi + 1)
        if len(tail):
            self._open.append(tail)

    def flush(self) -> None:
        """Close the open window with whatever it holds (end of stream —
        there is no later event to move the watermark)."""
        if self._t_start is None:
            self._t_start = self._clock()
        self._close_window()

    def _close_window(self) -> None:
        w = self._window
        w_lo = self._win_start_us(w)
        events = (merge_streams(*self._open) if self._open
                  else empty_stream(self.height, self.width))
        self._open = []
        self._window += 1
        if not len(events) and not self.submit_empty:
            self.windows_empty += 1
            return
        tr = self.tracer
        t_enc0 = tr.clock() if tr.enabled else 0.0
        planes = encode_events_to_plane_groups(
            events, t=self.bins, window_us=self.window_us // self.bins,
            t0_us=w_lo)
        row = {
            "window": w,
            "t_start_us": w_lo,
            "events": len(events),
            "occupancy": round(window_occupancy(planes, t=self.bins), 4),
            "firing_rate": round(packed_occupancy(planes, self.bins), 4),
            "shed": False,
            "label": None,
        }
        frame = events_to_frame(events)
        t_s = self._clock() - self._t_start
        if self.capture:
            self.captured.append((t_s, w, events.shift_time(-w_lo)))
        # the row must exist BEFORE submit: a synchronous client (the
        # micro-batch engine, a test double) fires the per-image callback
        # inside submit itself
        row_index = len(self.windows)
        self.windows.append(row)
        if tr.enabled:
            # the encode span covers windowing work up to the submit door;
            # rid is the WINDOW index (the session's request id space)
            tr.span("window", "encode", t0=t_enc0, t1=tr.clock(), rid=w,
                    occupancy=row["occupancy"], value=row["events"])
        try:
            handle = self.client.submit(frame[None],
                                        on_image=self._label_cb(row_index))
        except QueueFull:
            self.windows_shed += 1
            row["shed"] = True
            if tr.enabled:
                tr.span("window", "shed", rid=w)
        else:
            self._handles.append(handle)

    def _label_cb(self, row_index: int):
        def cb(rid, image_index, label):
            with self._lock:
                self.windows[row_index]["label"] = int(label)
            tr = self.tracer
            if tr.enabled:
                tr.span("window", "complete",
                        rid=self.windows[row_index]["window"],
                        value=int(label))
            if self.on_window is not None:
                self.on_window(self.windows[row_index]["window"], int(label))
        return cb

    # -- results ------------------------------------------------------------

    def drain(self, timeout: float | None = 60.0) -> None:
        """Block until every submitted window's label has landed."""
        for h in self._handles:
            h.result(timeout=timeout)

    def close(self, timeout: float | None = 60.0) -> None:
        """Flush the open window and drain. The CLIENT stays open — the
        caller owns it (a fleet outlives any one camera session)."""
        self.flush()
        self.drain(timeout=timeout)

    def save_trace(self, path, *, meta: dict | None = None) -> int:
        """Write the captured windows (``capture=True``) as a versioned
        JSONL trace; returns the number of arrivals written. The file
        replays through ``repro.events.replay_trace`` bit-identically."""
        if not self.capture:
            raise ValueError(
                "session was built with capture=False — nothing recorded")
        from .trace import record_trace
        return record_trace(path, height=self.height, width=self.width,
                            window_us=self.window_us, bins=self.bins,
                            arrivals=self.captured, meta=meta)

    def labels(self) -> dict:
        """``{window: label}`` for every served, completed window."""
        with self._lock:
            return {r["window"]: r["label"] for r in self.windows
                    if r["label"] is not None}

    def occupancy_trace(self) -> list:
        """Per-window chunk occupancy, in window order — the live signal
        for sparse-route calibration (feed its running mean to
        ``kernels.lut_matmul.sparse_budget`` / plan calibration)."""
        return [r["occupancy"] for r in self.windows]

    def stats(self) -> dict:
        with self._lock:
            labeled = sum(1 for r in self.windows
                          if r["label"] is not None)
        return {
            "events_seen": self.events_seen,
            "windows_closed": len(self.windows) + self.windows_empty,
            "windows_submitted": len(self._handles),
            "windows_shed": self.windows_shed,
            "windows_empty": self.windows_empty,
            "windows_labeled": labeled,
            "occupancy_mean": (round(float(sum(self.occupancy_trace())
                                           / len(self.windows)), 4)
                               if self.windows else None),
        }
