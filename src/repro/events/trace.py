"""Versioned JSONL event-serve traces: capture once, replay bit-identically.

A synthetic Poisson trace answers "can the server take R rps"; a captured
trace answers "can the server take THIS traffic" — the bursty ON/OFF
arrival process a real event camera actually produces. This module owns
the file format and the replay:

* ``TRACE_VERSION = 1``, line-oriented JSON. Line 1 is the header::

      {"trace_version": 1, "kind": "event_serve_trace",
       "height": H, "width": W, "channels": 2,
       "window_us": 20000, "bins": 8, "payload": "events",
       "meta": {...}}

  Every following line is one arrival. ``payload: "events"`` carries the
  window's event arrays (timestamps RELATIVE to the window start, so a
  trace is position-independent)::

      {"t_s": 0.31, "window": 15,
       "x": [...], "y": [...], "t_us": [...], "p": [...]}

  ``payload: "counts"`` carries only ``{"t_s": ..., "n_images": n}`` —
  the arrival-process skeleton, for replaying timing against synthetic
  payloads (``meta.image_seed`` feeds ``loadgen.image_maker``).

* ``record_trace`` / ``load_trace`` write and parse that format; loading
  an unknown version or kind fails loud (a replay against a
  misinterpreted trace would "pass" meaninglessly).

* ``replay_trace`` turns a trace into ``loadgen.run_open_loop`` inputs
  (arrivals + a payload maker that re-encodes each window's events into
  a count frame) and drives any ``ServeClient`` with it. Identical trace
  file → identical arrival schedule, identical payload bytes, and — by
  the serving stack's determinism contract — bit-identical labels,
  through 1 replica or N. ``labels_sha`` in the returned metrics is the
  checksum benches gate on.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

from ..serve.loadgen import Arrival, image_maker, run_open_loop
from .encoding import POLARITIES, EventStream, events_to_frame

TRACE_VERSION = 1
TRACE_KIND = "event_serve_trace"


@dataclasses.dataclass(frozen=True)
class TraceArrival:
    """One recorded arrival: a window submitted at ``t_s`` (seconds from
    trace start). ``events`` holds the window's payload (timestamps
    window-relative) in an events-payload trace; a counts-payload trace
    carries only ``n_images``."""
    t_s: float
    window: int = 0
    events: EventStream | None = None
    n_images: int = 1


@dataclasses.dataclass(frozen=True)
class EventTrace:
    """A parsed trace: the header fields plus the arrival list."""
    height: int
    width: int
    window_us: int
    bins: int
    payload: str                       # "events" | "counts"
    arrivals: tuple
    channels: int = POLARITIES
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.arrivals[-1].t_s if self.arrivals else 0.0


def record_trace(path, *, height: int, width: int, window_us: int,
                 bins: int, arrivals, payload: str = "events",
                 channels: int = POLARITIES, meta: dict | None = None) -> int:
    """Write a trace file; returns the number of arrivals written.
    ``arrivals`` is an iterable of ``TraceArrival`` (or the
    ``(t_s, window, EventStream)`` tuples ``EventStreamSession.captured``
    collects). Arrival times must be sorted — the same loud contract the
    replay enforces."""
    if payload not in ("events", "counts"):
        raise ValueError(f"payload must be 'events' or 'counts', got "
                         f"{payload!r}")
    header = {"trace_version": TRACE_VERSION, "kind": TRACE_KIND,
              "height": int(height), "width": int(width),
              "channels": int(channels), "window_us": int(window_us),
              "bins": int(bins), "payload": payload, "meta": meta or {}}
    n, prev = 0, 0.0
    with open(path, "w") as fh:
        fh.write(json.dumps(header) + "\n")
        for a in arrivals:
            if isinstance(a, tuple):
                a = TraceArrival(t_s=a[0], window=a[1], events=a[2])
            if a.t_s < prev:
                raise ValueError(
                    f"arrival {n} at t_s={a.t_s!r} precedes its "
                    f"predecessor at {prev!r}; record in time order")
            prev = a.t_s
            row = {"t_s": round(float(a.t_s), 6)}
            if payload == "events":
                if a.events is None:
                    raise ValueError(
                        f"arrival {n} has no events but payload='events'")
                ev = a.events
                row.update(window=int(a.window),
                           x=ev.x.tolist(), y=ev.y.tolist(),
                           t_us=ev.t_us.tolist(),
                           p=ev.polarity.tolist())
            else:
                row["n_images"] = int(a.n_images)
            fh.write(json.dumps(row) + "\n")
            n += 1
    return n


def load_trace(path) -> EventTrace:
    """Parse a trace file, failing loud on anything that is not exactly a
    version-``TRACE_VERSION`` ``event_serve_trace``."""
    with open(path) as fh:
        lines = [ln for ln in fh.read().splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty trace file")
    header = json.loads(lines[0])
    if header.get("kind") != TRACE_KIND:
        raise ValueError(
            f"{path}: kind={header.get('kind')!r} is not a "
            f"{TRACE_KIND!r} trace")
    if header.get("trace_version") != TRACE_VERSION:
        raise ValueError(
            f"{path}: trace_version={header.get('trace_version')!r} "
            f"unsupported (this reader speaks {TRACE_VERSION})")
    payload = header["payload"]
    h, w = int(header["height"]), int(header["width"])
    arrivals = []
    for ln in lines[1:]:
        row = json.loads(ln)
        if payload == "events":
            arrivals.append(TraceArrival(
                t_s=float(row["t_s"]), window=int(row["window"]),
                events=EventStream(
                    h, w, np.asarray(row["x"], np.int64),
                    np.asarray(row["y"], np.int64),
                    np.asarray(row["t_us"], np.int64),
                    np.asarray(row["p"], np.int64))))
        else:
            arrivals.append(TraceArrival(t_s=float(row["t_s"]),
                                         n_images=int(row["n_images"])))
    return EventTrace(height=h, width=w, channels=int(header["channels"]),
                      window_us=int(header["window_us"]),
                      bins=int(header["bins"]), payload=payload,
                      arrivals=tuple(arrivals), meta=header.get("meta", {}))


def trace_to_load(trace: EventTrace):
    """A trace as open-loop inputs: ``(arrivals, make_images)`` for
    ``run_open_loop``. Events-payload arrivals re-encode each recorded
    window into its count frame (one image per window — identical bytes
    every replay); counts-payload arrivals use the deterministic
    synthetic maker seeded from ``meta.image_seed``."""
    arrivals = [Arrival(t_s=a.t_s, n_images=a.n_images)
                for a in trace.arrivals]
    if trace.payload == "counts":
        seed = int(trace.meta.get("image_seed", 0))
        return arrivals, image_maker(
            (trace.height, trace.width, trace.channels), seed=seed)
    frames = [events_to_frame(a.events) for a in trace.arrivals]

    def make(index: int, n: int):
        return frames[index][None]

    return arrivals, make


def labels_checksum(labels) -> str:
    """A short stable checksum over per-arrival label lists (``None`` for
    a rejected/dropped arrival) — what "bit-identical labels" is gated
    as."""
    blob = json.dumps(labels, separators=(",", ":")).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def replay_trace(trace, client, *, slo_ms: float,
                 result_timeout_s: float = 60.0) -> dict:
    """Replay a trace (an ``EventTrace`` or a path) against a FRESH
    ``ServeClient`` and measure. Returns the ``run_open_loop`` metrics
    plus the trace's shape (``windows``, ``trace_duration_s``) and the
    determinism handles: ``labels`` (per-arrival label lists, ``None``
    where admission control shed) and ``labels_sha``.

    The client must be fresh (no prior traffic): replayed labels are
    aligned to arrivals by the submit handles themselves, and the
    serving metrics in ``client.stats()`` would otherwise mix in traffic
    this trace never offered."""
    if not isinstance(trace, EventTrace):
        trace = load_trace(trace)
    arrivals, make_images = trace_to_load(trace)
    handles = {}
    metrics = run_open_loop(
        client, arrivals, make_images, slo_ms=slo_ms,
        result_timeout_s=result_timeout_s,
        on_accept=lambda k, h: handles.__setitem__(k, h))
    labels = []
    for k in range(len(arrivals)):
        h = handles.get(k)
        if h is None:
            labels.append(None)
            continue
        try:
            labels.append(list(h.result(timeout=0.0)))
        except Exception:
            labels.append(None)   # dropped: already counted by the metrics
    return {
        **metrics,
        "windows": len(arrivals),
        "trace_duration_s": round(trace.duration_s, 6),
        "labels": labels,
        "labels_sha": labels_checksum(labels),
    }
