"""Event-stream workload: DVS ingestion for the packed datapath.

Sparse event-camera streams are spike-form data already; this package
encodes them straight into the plane-group format the inference stack
runs on (``encoding``), streams them into any ``ServeClient`` as
fixed-duration windows (``session``), and captures/replays the resulting
bursty arrival process deterministically (``trace``). See README.md in
this directory for the encoding layout, window semantics, and trace
format spec."""
from .encoding import (POLARITIES, EventStream, empty_stream,
                       encode_events_to_plane_groups, events_to_frame,
                       flicker_burst_events, merge_streams,
                       moving_edge_events, rasterize_events,
                       window_occupancy)
from .session import EventStreamSession
from .trace import (TRACE_KIND, TRACE_VERSION, EventTrace, TraceArrival,
                    labels_checksum, load_trace, record_trace, replay_trace,
                    trace_to_load)

__all__ = [
    "POLARITIES", "EventStream", "empty_stream",
    "encode_events_to_plane_groups", "events_to_frame", "rasterize_events",
    "window_occupancy", "merge_streams", "moving_edge_events",
    "flicker_burst_events",
    "EventStreamSession",
    "TRACE_VERSION", "TRACE_KIND", "EventTrace", "TraceArrival",
    "record_trace", "load_trace", "replay_trace", "trace_to_load",
    "labels_checksum",
]
