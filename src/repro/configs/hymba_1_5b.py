"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention+mamba heads in every layer,
sliding-window attention except 3 global layers (first/middle/last).
Meta-token prefix omitted (noted in DESIGN.md). [arXiv:2411.13676; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, ssm_state=16, ssm_head_dim=64, ssm_expand=2,
    sliding_window=2048, global_layers=(0, 15, 31),
    scan_layers=False,  # heterogeneous caches (ring vs full) per layer
))
