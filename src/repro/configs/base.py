"""ArchConfig + shape registry: every assigned (architecture x input-shape)
cell is addressable as (arch_id, shape_id) and yields jit-able specs."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention
    causal: bool = True
    use_rope: bool = True
    rope_theta: float = 10000.0
    rotary_frac: float = 1.0
    qkv_bias: bool = False
    qk_norm: bool = False
    mrope_sections: tuple | None = None
    sliding_window: int | None = None
    global_layers: tuple = ()
    attn_chunk: int = 512

    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_parallel: bool = False
    moe_capacity_factor: float = 1.25
    moe_norm_topk: bool = True

    # ssm (mamba2 / hymba)
    ssm_state: int = 0
    ssm_groups: int = 1
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2

    # encdec (whisper)
    encoder_layers: int = 0
    cross_attention: bool = False
    n_frames: int = 0

    # vlm stub
    img_tokens: int = 0

    # misc
    norm: str = "rmsnorm"
    act: str = "swiglu"
    tie_embeddings: bool = False
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"   # bf16 moments for the >=100B configs
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over 16-way TP."""
        return -(-self.vocab // 256) * 256

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid with sliding windows)."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.sliding_window is not None)

    def encoder_cfg(self) -> "ArchConfig":
        return dataclasses.replace(
            self, causal=False, cross_attention=False, n_experts=0,
            sliding_window=None, use_rope=False)

    def reduced(self, **overrides) -> "ArchConfig":
        """Same-family tiny config: runnable forward/train step on CPU.
        Keeps every structural flag (GQA, MoE, SSM, M-RoPE, windows...)."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=2 if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            attn_chunk=64,
            remat=False,
        )
        if self.n_experts:
            # ample capacity: reduced configs must be drop-free so that
            # prefill+decode == full-forward parity holds exactly
            kw.update(n_experts=8, top_k=min(self.top_k, 2), moe_d_ff=64,
                      moe_capacity_factor=8.0)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_expand=2)
        if self.family == "encdec":
            kw.update(encoder_layers=2, n_frames=16)
        if self.family == "vlm":
            kw.update(img_tokens=8)
        if self.sliding_window is not None:
            kw.update(sliding_window=32, global_layers=(0,))
        if self.mrope_sections is not None:
            kw.update(mrope_sections=(4, 6, 6))   # sums to head_dim/2 = 16
        kw.update(overrides)
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        dh, h, kv = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * dh * (h + 2 * kv) + h * dh * d
        mlp = 3 * d * f if self.act == "swiglu" else 2 * d * f
        per_layer = 0
        if self.family != "ssm":
            per_layer += attn
        if self.family in ("ssm", "hybrid"):
            d_inner = self.ssm_expand * d
            heads = d_inner // self.ssm_head_dim
            per_layer += d * (2 * d_inner + 2 * self.ssm_groups * self.ssm_state
                              + heads) + d_inner * d
        if self.n_experts > 0:
            per_layer += d * self.n_experts + 3 * self.n_experts * d * self.moe_d_ff
            if self.dense_parallel:
                per_layer += mlp
        elif self.family != "ssm" and f > 0:
            per_layer += mlp
        total = self.n_layers * per_layer + v * d
        if not self.tie_embeddings:
            total += v * d
        if self.family == "encdec":
            total += self.encoder_layers * (attn + 2 * d * f) \
                + self.n_layers * attn  # cross attention
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.n_params()
        d = self.d_model
        moe_all = 3 * self.n_experts * d * self.moe_d_ff
        moe_active = 3 * self.top_k * d * self.moe_d_ff
        return self.n_params() - self.n_layers * (moe_all - moe_active)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "stablelm-12b", "glm4-9b", "qwen1.5-110b", "smollm-360m", "hymba-1.5b",
    "whisper-large-v3", "mamba2-130m", "arctic-480b", "qwen3-moe-30b-a3b",
    "qwen2-vl-7b",
]

_REGISTRY: dict[str, Any] = {}


def register(cfg: ArchConfig):
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        mod = arch_id.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[arch_id]


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable? (the brief's skip rules)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full quadratic attention at 500k — skipped per brief"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.
    No device allocation — dry-run lowers against these."""
    i32, bf16 = jnp.int32, jnp.bfloat16
    b, s = shape.batch, shape.seq

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        batch = {"tokens": sds((b, s)), "labels": sds((b, s))}
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((b, cfg.img_tokens, cfg.d_model), bf16)
            batch["mrope_positions"] = sds((3, b, s))
        if cfg.family == "encdec":
            batch["frames"] = sds((b, cfg.n_frames, cfg.d_model), bf16)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s))}
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((b, cfg.img_tokens, cfg.d_model), bf16)
            batch["mrope_positions"] = sds((3, b, s))
        if cfg.family == "encdec":
            batch["frames"] = sds((b, cfg.n_frames, cfg.d_model), bf16)
        return {"batch": batch}

    # decode: one new token against a seq-long cache
    from ..nn.transformer import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    batch = {"tokens": sds((b, 1)), "cache_pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.family == "vlm":
        batch["mrope_positions"] = sds((3, b, 1))
    return {"batch": batch, "cache": cache}
