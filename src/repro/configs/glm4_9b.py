"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552
— RoPE (partial 0.5), QKV bias. [hf:THUDM/glm-4-9b; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=151552, qkv_bias=True, rotary_frac=0.5, rope_theta=10000.0,
))
