"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 — per-head QK-norm, partial rotary (StableLM-2-12B family).
[hf:stabilityai/stablelm-2-1_6b scaled; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab=100352, qk_norm=True, rotary_frac=0.25, rope_theta=10000.0,
    norm="layernorm", act="swiglu",
))
