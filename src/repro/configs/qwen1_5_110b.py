"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias. bf16 params + bf16 AdamW moments so the 110B
footprint fits 256 chips. [hf:Qwen/Qwen1.5-0.5B scaled; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
    vocab=152064, qkv_bias=True, rope_theta=1000000.0,
    param_dtype="bfloat16", opt_state_dtype="bfloat16",
))
