"""whisper-large-v3 [audio/encdec]: 32L enc + 32L dec, d_model=1280 20H
d_ff=5120 vocab=51866 — conv/mel frontend STUBBED (input_specs provides
precomputed frame embeddings, 1500 frames); sinusoidal positions; gelu MLP;
layernorm. [arXiv:2212.04356; backbone only per brief]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51866, encoder_layers=32, cross_attention=True, n_frames=1500,
    use_rope=False, norm="layernorm", act="gelu",
))
