"""spikformer-v2-8-512 — the paper's own model (VESTA's workload), exposed
alongside the 10 assigned LM architectures. It is a vision SNN, not an LM,
so it lives outside the (arch x LM-shape) dry-run grid; its production
instantiation is the full 224x224 ImageNet config below and its launchers
are examples/train_spikformer.py + the core/spikformer module.
"""
import dataclasses

from ..core.spikformer import SpikformerConfig

# full paper config: 8 encoder blocks, dim 512, T=4, 224px, 1000 classes
CONFIG = SpikformerConfig()

# CPU-scale smoke config (used by tests/examples)
REDUCED = CONFIG.scaled()

# Long-timestep variants (Spike-driven Transformer V2 / Spikingformer
# workload shapes): T=16 -> ceil(16/8)=2 packed plane groups per neuron.
CONFIG_T16 = dataclasses.replace(CONFIG, timesteps=16)
REDUCED_T16 = REDUCED.scaled(timesteps=16)
