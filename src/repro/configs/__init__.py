from .base import (ArchConfig, ShapeSpec, SHAPES, ARCH_IDS, get_config,
                   register, cell_applicable, input_specs)  # noqa: F401
