"""mamba2-130m [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality), chunked dual form; d_ff=0
(no MLP block). Sub-quadratic => long_500k runs. [arXiv:2405.21060]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True, use_rope=False,
))
