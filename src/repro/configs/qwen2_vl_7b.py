"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE (t/h/w sections 16/24/24 of head_dim/2=64), QKV bias;
vision tower STUBBED: input_specs provides 256 precomputed patch embeddings
merged at sequence front. [arXiv:2409.12191; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, qkv_bias=True, mrope_sections=(16, 24, 24),
    rope_theta=1000000.0, img_tokens=256,
))
