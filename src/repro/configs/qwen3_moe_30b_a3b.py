"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) moe_d_ff=768,
128 experts top-8, QK-norm per head, no shared expert, vocab=151936.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=0,
    head_dim=128, vocab=151936, n_experts=128, top_k=8, moe_d_ff=768,
    qk_norm=True, rope_theta=1000000.0, moe_norm_topk=True,
))
