"""Mamba2 (SSD — state-space duality) block: chunked train/prefill form and
O(1)-state recurrent decode. Used by mamba2-130m and the SSM branch of Hymba.

Train/prefill follows the SSD block decomposition (Dao & Gu 2024, Listing 1):
the sequence is split into chunks; within a chunk the computation is an
attention-like quadratic form, and states are passed between chunks through
an exponential-decay recurrence (a lax.scan). This is the sub-quadratic path
that makes `long_500k` feasible where full attention is skipped.

Decode keeps a constant-size state (B, H, P, N) + a (k-1)-deep conv buffer —
the SSM analogue of VESTA's TFLIF: temporal state fused on-chip, nothing
quadratic ever materializes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .module import KeyStream, lecun_normal
from .layers import rmsnorm_init, rmsnorm
from ..sharding.hints import shard_hint


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_inner, heads, conv_dim


def ssm_init(key, cfg, dtype=jnp.float32):
    ks = KeyStream(key)
    d = cfg.d_model
    d_inner, heads, conv_dim = ssm_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    return {
        # order: [z (d_inner), x (d_inner), B (g*n), C (g*n), dt (heads)]
        "in_proj": lecun_normal(ks(), (d, 2 * d_inner + 2 * g * n + heads),
                                fan_in=d, dtype=dtype),
        "conv_w": lecun_normal(ks(), (cfg.ssm_conv, conv_dim), fan_in=cfg.ssm_conv,
                               dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.arange(1, heads + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": lecun_normal(ks(), (d_inner, d), fan_in=d_inner, dtype=dtype),
    }


def _split_proj(cfg, zxbcdt):
    d_inner, heads, _ = ssm_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, window k: explicit shift-mac (k is tiny)."""
    k = w.shape[0]
    y = xbc * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i, :]
        y = y + shifted * w[k - 1 - i]
    return jax.nn.silu(y + b)


def _segsum(a):
    """a: (..., Q) -> (..., Q, Q) lower-tri cumulative sums: L[i,j]=sum a[j+1..i]."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b_mat, c_mat, *, chunk: int, init_state=None):
    """SSD forward. x: (B,S,H,P); dt: (B,S,H); a: (H,) (negative);
    b_mat/c_mat: (B,S,G,N). Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p_dim = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    # chunk views; broadcast SSM groups to heads up front (g | h)
    xc = x.reshape(bsz, nc, chunk, h, p_dim)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bh = jnp.repeat(b_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    ch = jnp.repeat(c_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    da = dtc * a  # (B,nc,Q,H)  per-step log-decay
    da_cum = jnp.cumsum(da, axis=2)                        # within-chunk cumsum
    da_total = da_cum[:, :, -1]                            # (B,nc,H)

    # ---- intra-chunk (diagonal blocks): attention-like quadratic ----------
    lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))      # (B,nc,H,Q,Q)
    cb = jnp.einsum("bcqhn,bckhn->bchqk", ch, bh)          # (B,nc,H,Q,Q)
    scores = cb * lmat
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", scores, dtc, xc)

    # ---- per-chunk emitted states ------------------------------------------
    decay_states = jnp.exp(da_total[:, :, None, :] - da_cum)   # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchpn",
                        bh, decay_states, dtc, xc)

    # ---- inter-chunk recurrence (scan over chunks) -------------------------
    s0 = (jnp.zeros((bsz, h, p_dim, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(prev, inp):
        st, dtot = inp                                     # (B,H,P,N), (B,H)
        new = st + prev * jnp.exp(dtot)[:, :, None, None]
        return new, prev                                   # emit state BEFORE chunk

    final_state, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(da_total, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (B,nc,H,P,N)

    # contribution of carried-in states
    state_decay = jnp.exp(da_cum)                          # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p_dim)
    return y, final_state


def ssm_apply(p, x, cfg, *, state=None, conv_state=None, decode: bool = False,
              chunk: int = 128, compute_dtype=jnp.bfloat16):
    """x: (B,S,D). Returns (y (B,S,D), new_state, new_conv_state)."""
    bsz, s, d = x.shape
    d_inner, heads, conv_dim = ssm_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    p_dim = cfg.ssm_head_dim

    zxbcdt = x.astype(compute_dtype) @ p["in_proj"].astype(compute_dtype)
    if cfg.family == "hybrid":
        # pin batch to dp: without this hymba's SSD chunk intermediates
        # (B, nc, Q, H, ...) replicate onto every chip (29.6 GB/chip before
        # the hint). Pure-SSM mamba2 REGRESSED 0.7x under the same hint
        # (forced resharding against its natural propagation) — hybrid only.
        zxbcdt = shard_hint(zxbcdt, "dp", None, "model")
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["a_log"])                                       # (H,)

    if decode:
        # conv ring: conv_state (B, k-1, conv_dim) holds the last k-1 inputs
        window = jnp.concatenate([conv_state, xbc.astype(jnp.float32)], axis=1)
        w = p["conv_w"].astype(jnp.float32)
        conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"])
        new_conv_state = window[:, 1:, :]
        xin = conv_out[:, None, :]                                 # (B,1,conv)
    else:
        xin = _causal_conv(xbc.astype(jnp.float32), p["conv_w"].astype(jnp.float32),
                           p["conv_b"].astype(jnp.float32))
        new_conv_state = xbc.astype(jnp.float32)[:, -(cfg.ssm_conv - 1):, :]

    xs, bmat, cmat = jnp.split(xin, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(bsz, -1, heads, p_dim)
    bmat = bmat.reshape(bsz, -1, g, n)
    cmat = cmat.reshape(bsz, -1, g, n)

    if decode:
        # recurrent update: state' = exp(dt*a) state + dt * B x
        dt1 = dt[:, 0]                                             # (B,H)
        da = jnp.exp(dt1 * a)                                      # (B,H)
        bx = jnp.einsum("bgn,bhp->bhpn", bmat[:, 0], xs[:, 0] * dt1[..., None])
        new_state = state * da[:, :, None, None] + bx
        y = jnp.einsum("bgn,bhpn->bhp", cmat[:, 0], new_state)
        y = y[:, None]                                             # (B,1,H,P)
    else:
        pad = (-s) % chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, new_state = ssd_chunked(xs, dt, a, bmat, cmat, chunk=chunk,
                                   init_state=state)
        y = y[:, :s]

    y = y + xs[:, :s] * p["d_skip"][:, None]                       # D skip
    y = y.reshape(bsz, s, d_inner)
    y = rmsnorm(p["norm"], (y * jax.nn.silu(z.astype(jnp.float32))).astype(compute_dtype))
    out = y @ p["out_proj"].astype(compute_dtype)
    return out.astype(x.dtype), new_state, new_conv_state


def init_ssm_state(batch: int, cfg, dtype=jnp.float32):
    d_inner, heads, conv_dim = ssm_dims(cfg)
    return (jnp.zeros((batch, heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
            jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype))
