"""Generic LM backbone assembled from an ArchConfig.

One scan-able layer body covers the dense / MoE / VLM / enc-dec families
(uniform per-layer structure => layers are stacked and driven by lax.scan for
small HLO and fast compiles at 80 layers). The SSM and hybrid families unroll
in Python because their per-layer caches are heterogeneous (Hymba's three
global-attention layers carry full-length KV caches; sliding-window layers
carry ring buffers).

Modes:
  train   — teacher-forced CE loss path (remat per layer).
  prefill — forward + cache build, returns logits of the last position.
  decode  — one token against the cache (the `serve_step` the decode_* and
            long_* dry-run shapes lower).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .module import KeyStream
from .layers import (linear_init, linear, embedding_init, embed, unembed,
                     rmsnorm_init, rmsnorm, layernorm_init, layernorm,
                     swiglu, gelu, softmax_xent)
from .attention import attn_init, attn_apply, init_kv_cache
from ..sharding.hints import shard_hint
from .moe import moe_init, moe_apply
from .ssm import ssm_init, ssm_apply, init_ssm_state

# ---------------------------------------------------------------------------
# norms / mlp helpers
# ---------------------------------------------------------------------------

def _norm_init(cfg, d=None):
    d = d or cfg.d_model
    return rmsnorm_init(d) if cfg.norm == "rmsnorm" else layernorm_init(d)


def _norm(cfg, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


def mlp_init(key, cfg, dtype=jnp.float32):
    ks = KeyStream(key)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "swiglu":
        return {"gate": linear_init(ks(), d, f, dtype=dtype),
                "up": linear_init(ks(), d, f, dtype=dtype),
                "down": linear_init(ks(), f, d, dtype=dtype)}
    return {"up": linear_init(ks(), d, f, bias=True, dtype=dtype),
            "down": linear_init(ks(), f, d, bias=True, dtype=dtype)}


def mlp_apply(p, x, cfg, *, compute_dtype):
    if cfg.act == "swiglu":
        h = swiglu(linear(p["gate"], x, compute_dtype=compute_dtype),
                   linear(p["up"], x, compute_dtype=compute_dtype))
    else:
        h = gelu(linear(p["up"], x, compute_dtype=compute_dtype))
    # Megatron TP: the hidden F dim lives on the model axis (weights stay
    # sharded; the S-sharded input is all-gathered, the down-proj emits
    # partials that reduce-scatter back to the S-sharded layout).
    h = shard_hint(h, "dp", None, "model")
    return linear(p["down"], h, compute_dtype=compute_dtype)


# ---------------------------------------------------------------------------
# one decoder layer
# ---------------------------------------------------------------------------

def layer_init(key, cfg, dtype=jnp.float32):
    ks = KeyStream(key)
    p = {"ln1": _norm_init(cfg)}
    if cfg.family != "ssm":
        p["attn"] = attn_init(ks(), cfg, dtype)
    if cfg.family in ("ssm", "hybrid"):
        p["ssm"] = ssm_init(ks(), cfg, dtype)
    if cfg.family == "hybrid":
        p["attn_out_norm"] = _norm_init(cfg)
        p["ssm_out_norm"] = _norm_init(cfg)
    if cfg.family != "ssm":
        p["ln2"] = _norm_init(cfg)
        if cfg.n_experts > 0:
            p["moe"] = moe_init(ks(), cfg, dtype)
            if cfg.dense_parallel:
                p["mlp"] = mlp_init(ks(), cfg, dtype)
        elif cfg.d_ff > 0:
            p["mlp"] = mlp_init(ks(), cfg, dtype)
    if cfg.cross_attention:
        p["cross"] = attn_init(ks(), cfg, dtype)
        p["ln_cross"] = _norm_init(cfg)
    return p


def layer_apply(p, x, cfg, *, positions, cache=None, cache_pos=None,
                flags=None, mrope_positions=None, enc_out=None,
                compute_dtype=jnp.bfloat16):
    """Returns (x, new_cache, aux). cache is a per-layer dict or None."""
    aux = {}
    new_cache = dict(cache) if cache is not None else None
    h = _norm(cfg, p["ln1"], x)

    if cfg.family == "ssm":
        y, st, cv = ssm_apply(
            p["ssm"], h, cfg, state=None if cache is None else cache["ssm"],
            conv_state=None if cache is None else cache["conv"],
            decode=cache is not None and h.shape[1] == 1,
            compute_dtype=compute_dtype)
        if new_cache is not None:
            new_cache["ssm"], new_cache["conv"] = st, cv
        x = x + y
    else:
        window = None
        is_global = None
        if cfg.sliding_window is not None and flags is not None:
            window = cfg.sliding_window
            is_global = flags.get("is_global")
        mixer_out, kv = attn_apply(
            p["attn"], h, cfg, positions=positions,
            cache=None if cache is None else cache.get("kv"),
            cache_pos=cache_pos, mrope_positions=mrope_positions,
            window=window, is_global=is_global,
            compute_dtype=compute_dtype, chunk=cfg.attn_chunk)
        if new_cache is not None and kv is not None:
            new_cache["kv"] = kv
        if cfg.family == "hybrid":
            s_out, st, cv = ssm_apply(
                p["ssm"], h, cfg,
                state=None if cache is None else cache["ssm"],
                conv_state=None if cache is None else cache["conv"],
                decode=cache is not None and h.shape[1] == 1,
                compute_dtype=compute_dtype)
            if new_cache is not None:
                new_cache["ssm"], new_cache["conv"] = st, cv
            mixer_out = 0.5 * (_norm(cfg, p["attn_out_norm"], mixer_out)
                               + _norm(cfg, p["ssm_out_norm"], s_out))
        x = x + mixer_out

    if cfg.cross_attention:
        cross_kv = None
        if enc_out is not None:
            # project the encoder output with this layer's cross k/v weights
            b_, se, _ = enc_out.shape
            dh = cfg.head_dim
            ck = linear(p["cross"]["wk"], enc_out, compute_dtype=compute_dtype)
            cv = linear(p["cross"]["wv"], enc_out, compute_dtype=compute_dtype)
            ck = ck.reshape(b_, se, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
            cv = cv.reshape(b_, se, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
            cross_kv = {"k": ck, "v": cv}
            if new_cache is not None and "cross_k" in new_cache:
                new_cache["cross_k"] = ck.astype(new_cache["cross_k"].dtype)
                new_cache["cross_v"] = cv.astype(new_cache["cross_v"].dtype)
        elif cache is not None and "cross_k" in cache:
            cross_kv = {"k": cache["cross_k"], "v": cache["cross_v"]}
        if cross_kv is not None:
            hc = _norm(cfg, p["ln_cross"], x)
            cross_out, _ = attn_apply(
                p["cross"], hc, cfg, positions=positions, cross_kv=cross_kv,
                compute_dtype=compute_dtype, chunk=cfg.attn_chunk)
            x = x + cross_out

    if cfg.family != "ssm" and (cfg.d_ff > 0 or cfg.n_experts > 0):
        h2 = _norm(cfg, p["ln2"], x)
        y = 0.0
        if cfg.n_experts > 0:
            moe_out, moe_aux = moe_apply(p["moe"], h2, cfg,
                                         compute_dtype=compute_dtype)
            y = y + moe_out
            aux.update(moe_aux)
            if cfg.dense_parallel:
                y = y + mlp_apply(p["mlp"], h2, cfg, compute_dtype=compute_dtype)
        else:
            y = mlp_apply(p["mlp"], h2, cfg, compute_dtype=compute_dtype)
        x = x + y
    # Megatron-SP layout between layers: sequence sharded over the model axis
    # (keeps the scan's saved carry stack — L x (B,S,D) — 16x smaller per chip;
    # norms are per-token so they run sharded). Falls back to replicated S for
    # decode (S=1) via the divisibility guard.
    x = shard_hint(x, "dp", "model", None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------

def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_model(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = KeyStream(key)
    p = {"embed": embedding_init(ks(), cfg.padded_vocab, cfg.d_model, dtype=dtype),
         "final_norm": _norm_init(cfg)}
    p["layers"] = _stack([layer_init(ks(), cfg, dtype) for _ in range(cfg.n_layers)])
    if not cfg.tie_embeddings:
        p["head"] = linear_init(ks(), cfg.d_model, cfg.padded_vocab, dtype=dtype)
    if cfg.family == "encdec":
        enc_cfg = cfg.encoder_cfg()
        p["enc_layers"] = _stack(
            [layer_init(ks(), enc_cfg, dtype) for _ in range(cfg.encoder_layers)])
        p["enc_norm"] = _norm_init(cfg)
    return p


def _sinusoidal(positions, d):
    """(B,S) -> (B,S,D) sinusoidal embeddings (whisper-style backbone stub)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def layer_flags(cfg):
    """Per-layer traced flags (stacked for scan): Hymba global-attn layers."""
    if cfg.sliding_window is None:
        return None
    glob = jnp.zeros((cfg.n_layers,), bool)
    for i in cfg.global_layers:
        glob = glob.at[i].set(True)
    return {"is_global": glob}


def encode(params, frames, cfg, *, compute_dtype=jnp.bfloat16):
    """Whisper encoder over precomputed frame embeddings (frontend stubbed)."""
    enc_cfg = cfg.encoder_cfg()
    b, s, _ = frames.shape
    x = frames.astype(compute_dtype) + _sinusoidal(
        jnp.broadcast_to(jnp.arange(s), (b, s)), cfg.d_model).astype(compute_dtype)

    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, lp):
        y, _, _ = layer_apply(lp, x, enc_cfg, positions=positions,
                              compute_dtype=compute_dtype)
        return y, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.encoder_layers):
            lp = jax.tree_util.tree_map(lambda t: t[i], params["enc_layers"])
            x, _ = body(x, lp)
    return _norm(cfg, params["enc_norm"], x)


def model_apply(params, batch, cfg, *, mode: str = "train", cache=None,
                compute_dtype=None):
    """Returns (logits, new_cache, aux)."""
    compute_dtype = compute_dtype or jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed(params["embed"], tokens, compute_dtype=compute_dtype)
    x = shard_hint(x, "dp", "model", None)

    if cfg.family == "vlm" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(compute_dtype)
        x = jax.lax.dynamic_update_slice(x, img, (0, 0, 0))
    mrope_positions = batch.get("mrope_positions")

    cache_pos = batch.get("cache_pos")
    if cache_pos is None:
        cache_pos = jnp.int32(0)
    cache_pos = jnp.asarray(cache_pos, jnp.int32)
    _cp = cache_pos[:, None] if cache_pos.ndim == 1 else cache_pos
    positions = _cp + jnp.broadcast_to(jnp.arange(s), (b, s))

    enc_out = None
    if cfg.family == "encdec" and mode != "decode":
        enc_out = encode(params, batch["frames"], cfg,
                         compute_dtype=compute_dtype)
        x = x + _sinusoidal(positions, cfg.d_model).astype(compute_dtype)
    elif cfg.family == "encdec":
        x = x + _sinusoidal(positions, cfg.d_model).astype(compute_dtype)

    flags = layer_flags(cfg)
    aux_total = {}

    if cfg.scan_layers:
        def body(carry, xs):
            x = carry
            y, new_c, aux = layer_apply(
                xs["p"], x, cfg, positions=positions, cache=xs.get("cache"),
                cache_pos=cache_pos, flags=xs.get("flags"),
                mrope_positions=mrope_positions, enc_out=enc_out,
                compute_dtype=compute_dtype)
            return y, (new_c, aux)

        if mode == "train" and cfg.remat:
            policy = (jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(body, policy=policy)
        xs = {"p": params["layers"]}
        if cache is not None:
            xs["cache"] = cache
        if flags is not None:
            xs["flags"] = flags
        x, (new_cache, auxes) = jax.lax.scan(body, x, xs)
        aux_total = jax.tree_util.tree_map(lambda a: a.mean(), auxes)
    else:
        def run_layer(lp, x, lcache, lflags):
            return layer_apply(lp, x, cfg, positions=positions, cache=lcache,
                               cache_pos=cache_pos, flags=lflags,
                               mrope_positions=mrope_positions,
                               enc_out=enc_out, compute_dtype=compute_dtype)

        if mode == "train" and cfg.remat:
            run_layer = jax.checkpoint(
                run_layer, policy=jax.checkpoint_policies.nothing_saveable)
        new_caches = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda t: t[i], params["layers"])
            lcache = None if cache is None else cache[i]
            lflags = None if flags is None else \
                jax.tree_util.tree_map(lambda t: t[i], flags)
            x, new_c, aux = run_layer(lp, x, lcache, lflags)
            new_caches.append(new_c)
            for k, v in aux.items():
                aux_total[k] = aux_total.get(k, 0.0) + v / cfg.n_layers
        new_cache = new_caches if cache is not None else None

    x = _norm(cfg, params["final_norm"], x)
    if mode in ("prefill", "decode"):
        x = x[:, -1:, :]
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = linear(params["head"], x, compute_dtype=jnp.float32)
    logits = shard_hint(logits, "dp", None, "model")
    return logits, new_cache, aux_total


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, length: int, dtype=jnp.bfloat16):
    """Build the (stacked or per-layer list) decode cache for an arch."""
    def one_layer(i):
        c = {}
        if cfg.family != "ssm":
            win = cfg.sliding_window
            glob = i in cfg.global_layers if win is not None else True
            clen = length if (win is None or glob) else min(win, length)
            c["kv"] = init_kv_cache(batch, cfg.n_kv_heads, clen,
                                    cfg.head_dim, dtype)
        if cfg.family in ("ssm", "hybrid"):
            st, cv = init_ssm_state(batch, cfg)
            c["ssm"], c["conv"] = st, cv
        if cfg.cross_attention:
            c["cross_k"] = jnp.zeros((batch, cfg.n_kv_heads, cfg.n_frames,
                                      cfg.head_dim), dtype)
            c["cross_v"] = jnp.zeros((batch, cfg.n_kv_heads, cfg.n_frames,
                                      cfg.head_dim), dtype)
        return c

    if cfg.scan_layers:
        return _stack([one_layer(i) for i in range(cfg.n_layers)])
    return [one_layer(i) for i in range(cfg.n_layers)]


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(params, batch, cfg):
    logits, _, aux = model_apply(params, batch, cfg, mode="train")
    loss = softmax_xent(logits, batch["labels"])
    if aux:
        loss = loss + 0.01 * aux.get("load_balance", 0.0) \
                    + 0.001 * aux.get("router_z", 0.0)
    return loss, aux
