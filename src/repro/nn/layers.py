"""Common layers: Linear, Embedding, norms, rotary embeddings (RoPE + M-RoPE).

Pure functions over nested-dict params (see module.py). Compute dtype is the
caller's; params are stored in ``dtype`` chosen at init.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .module import KeyStream, lecun_normal, trunc_normal

# ---------------------------------------------------------------------------
# Linear / Embedding
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32,
                std: float | None = None):
    ks = KeyStream(key)
    if std is None:
        kernel = lecun_normal(ks(), (d_in, d_out), fan_in=d_in, dtype=dtype)
    else:
        kernel = trunc_normal(ks(), (d_in, d_out), std=std, dtype=dtype)
    p = {"kernel": kernel}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x, *, compute_dtype=None):
    w = p["kernel"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


def embedding_init(key, vocab: int, d_model: int, *, dtype=jnp.float32):
    return {"embedding": trunc_normal(key, (vocab, d_model), std=0.02, dtype=dtype)}


def embed(p, ids, *, compute_dtype=None):
    table = p["embedding"]
    if compute_dtype is not None:
        table = table.astype(compute_dtype)
    return jnp.take(table, ids, axis=0)


def unembed(p, x):
    """Tied / untied LM head: logits in fp32 for a stable softmax."""
    return x.astype(jnp.float32) @ p["embedding"].astype(jnp.float32).T


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, *, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, *, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, *, theta: float = 10000.0, rotary_frac: float = 1.0):
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * rotary_frac) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, *, theta: float = 10000.0, rotary_frac: float = 1.0):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    inv, rot = rope_freqs(head_dim, theta=theta, rotary_frac=rotary_frac)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), xp], axis=-1)


def apply_mrope(x, positions_3d, sections: tuple[int, int, int],
                *, theta: float = 1000000.0):
    """Multimodal RoPE (Qwen2-VL): the head_dim/2 frequency slots are split into
    (temporal, height, width) sections, each driven by its own position stream.

    x: (..., S, H, Dh); positions_3d: (3, ..., S); sections sum to Dh//2.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # pick, per frequency slot, which positional stream drives it
    sect_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    # positions_3d: (3, ..., S) -> (..., S, half): gather per-slot positions

    p = jnp.moveaxis(positions_3d, 0, -1).astype(jnp.float32)  # (..., S, 3)
    pos_per_slot = jnp.take(p, sect_id, axis=-1)  # (..., S, half)
    ang = pos_per_slot * inv  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def swiglu(gate, up):
    return jax.nn.silu(gate) * up


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def softmax_xent(logits, labels, *, ignore_id: int = -100):
    """Mean token cross-entropy in fp32; labels==ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    mask = (labels != ignore_id)
    labels_safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
