"""Attention for the assigned LM architectures.

Features: GQA, RoPE / partial RoPE / M-RoPE, QK-norm, QKV bias, sliding
windows (+ per-layer traced global flag for Hymba), KV caches (linear and
ring-buffer), cross-attention (Whisper), and **chunked causal attention** —
the pure-XLA memory-efficient path used in dry-runs, where the score matrix
peak is O(B*H*chunk*S) instead of O(B*H*S^2). (On real TPUs the Pallas
``kernels.flash_attention`` kernel implements the same schedule in VMEM; the
chunked form is what we .lower()/.compile() on the CPU container.)

Conventions: x is (B, S, D); caches are (B, KV, S_cache, Dh); all softmax
math in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .module import KeyStream
from .layers import linear_init, linear, apply_rope, apply_mrope, rmsnorm_init, rmsnorm
from ..sharding.hints import shard_hint
from ..sharding.compat import get_abstract_mesh

NEG_INF = -1e30


def attn_init(key, cfg, dtype=jnp.float32):
    ks = KeyStream(key)
    dh = cfg.head_dim
    p = {
        "wq": linear_init(ks(), cfg.d_model, cfg.n_heads * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wk": linear_init(ks(), cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wv": linear_init(ks(), cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wo": linear_init(ks(), cfg.n_heads * dh, cfg.d_model, bias=False, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, dtype)
        p["k_norm"] = rmsnorm_init(dh, dtype)
    return p


def _project_qkv(p, x, cfg, *, compute_dtype):
    b, s, _ = x.shape
    dh = cfg.head_dim
    q = linear(p["wq"], x, compute_dtype=compute_dtype).reshape(b, s, cfg.n_heads, dh)
    k = linear(p["wk"], x, compute_dtype=compute_dtype).reshape(b, s, cfg.n_kv_heads, dh)
    v = linear(p["wv"], x, compute_dtype=compute_dtype).reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    return q, k, v


def _rope(q, k, cfg, positions, mrope_positions=None):
    if cfg.mrope_sections is not None and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, theta=cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, theta=cfg.rope_theta)
    elif cfg.use_rope:
        q = apply_rope(q, positions, theta=cfg.rope_theta, rotary_frac=cfg.rotary_frac)
        k = apply_rope(k, positions, theta=cfg.rope_theta, rotary_frac=cfg.rotary_frac)
    return q, k


def _decode_grouped(q, k, v, *, scale, causal, q_positions, k_positions,
                    window, is_global):
    """One-token attention without expanding KV to q heads.

    q: (B, Hq, 1, Dh); k, v: (B, KV, S, Dh). Scores are (B, KV, g, S) with
    the KV-seq dim sharded over the model axis (distributed softmax)."""
    b, hq, _, dh = q.shape
    kvh = k.shape[1]
    g = hq // kvh
    am = get_abstract_mesh()
    seq_ok = (not am.empty and "model" in am.axis_names
              and k.shape[2] % am.shape["model"] == 0)
    if seq_ok:
        k = shard_hint(k, "dp", None, "model", None)
        v = shard_hint(v, "dp", None, "model", None)
    qg = q.reshape(b, kvh, g, dh)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k,
                   preferred_element_type=jnp.float32) * scale  # (B,KV,g,S)
    if seq_ok:
        s = shard_hint(s, "dp", None, None, "model")
    qp = q_positions[:, None, None, :]                 # (B,1,1,1)
    kp = k_positions[:, None, None, :]                 # (B,1,1,S)
    mask = kp >= 0
    if causal:
        mask = jnp.logical_and(mask, qp >= kp)
    if window is not None:
        w_ok = (qp - kp) < window
        if is_global is not None:
            w_ok = jnp.logical_or(w_ok, is_global)
        mask = jnp.logical_and(mask, w_ok)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, 1, dh).astype(q.dtype)


def chunked_attention(q, k, v, *, scale: float, causal: bool = True,
                      q_positions=None, k_positions=None,
                      window=None, is_global=None, chunk: int = 512):
    """Memory-efficient attention.

    q: (B, Hq, Sq, Dh); k, v: (B, KV, Skv, Dh). GQA via Hq = KV * group.
    q_positions: (Sq,) or per-row (B, Sq) absolute query positions;
    k_positions: (Skv,) or per-row (B, Skv) key positions (ring buffers and
    continuous batching, where every row sits at a different offset).
    window: optional int — sliding-window width; is_global: traced bool scalar
    that disables the window (Hymba's per-layer full-attention flag).
    """
    b, hq, sq, dh = q.shape
    kvh = k.shape[1]
    g = hq // kvh
    if q_positions is None:
        q_positions = jnp.arange(sq) + (k.shape[2] - sq)
    if k_positions is None:
        k_positions = jnp.arange(k.shape[2])
    # normalize positions to per-row (B, ·)
    q_positions = jnp.broadcast_to(jnp.atleast_2d(q_positions), (b, sq))
    k_positions = jnp.broadcast_to(jnp.atleast_2d(k_positions),
                                   (b, k.shape[2]))

    if sq == 1:
        # decode fast path: GROUPED attention — never materialize the GQA
        # repeat (8x the cache traffic for qwen1.5-110b's g=8; §Perf B4),
        # keep KV sequence-sharded, softmax distributed over the KV shards.
        return _decode_grouped(q, k, v, scale=scale, causal=causal,
                               q_positions=q_positions,
                               k_positions=k_positions, window=window,
                               is_global=is_global)

    # GQA: expand KV to the full head count. The merged head axis (divisible
    # by the TP degree for the big archs) is what the "model" mesh axis
    # shards. When heads DON'T divide the axis, keep KV SEQUENCE-sharded —
    # the old unconditional head hint silently replicated S, which forced a
    # 15 GB fp32 all-gather of the whole KV cache per layer per decode step
    # on arctic-480b (529 GB/chip/step; §Perf B2).
    am0 = get_abstract_mesh()
    tp = am0.shape["model"] if (not am0.empty and "model" in am0.axis_names) \
        else 1
    if g > 1:
        if sq == 1:
            # decode: S is the only big dim — NEVER reshard the cache to a
            # head-major layout for one query token (stablelm-12b decode
            # regressed 1.1->4.0 s memory when we did; §Perf B2b follow-up)
            kv_dims = ("dp", None, "model", None)
        elif hq % max(tp, 1) == 0:
            kv_dims = ("dp", "model", None, None)
        else:
            # train/prefill with non-divisible heads: scores contract the
            # FULL kv-seq per chip (q-seq carries the TP sharding), so a
            # seq-sharded KV would be re-gathered every layer — replicate
            kv_dims = ("dp", None, None, None)
        k = shard_hint(jnp.repeat(k, g, axis=1), *kv_dims)
        v = shard_hint(jnp.repeat(v, g, axis=1), *kv_dims)

    # When heads don't divide the TP axis, shard q-SEQUENCE over it instead,
    # and drop the chunk loop: per-chip score memory is already cut TP-fold
    # by the seq sharding, and a while loop would re-gather K/V from its
    # carry every iteration (+570 GB of all-gather measured; §Perf C1/C2).
    am = get_abstract_mesh()
    # (measured both ways for hymba's windowed unrolled layers: keeping the
    # chunk loop bounds peak at 32.4 GB but costs 2x the bound (40.2 s vs
    # 19.6 s); both exceed 16 GB, so we take the better bound and list the
    # residency remedies in §Perf extras)
    seq_tp = (not am.empty and "model" in am.axis_names
              and hq % am.shape["model"] != 0
              and sq % am.shape["model"] == 0 and sq > 1)
    if seq_tp:
        chunk = sq
    # decode (sq == 1): KV sequence stays sharded over the model axis
    kv_seq_tp = (not am.empty and "model" in am.axis_names and sq == 1
                 and k.shape[2] % am.shape["model"] == 0)
    if kv_seq_tp:
        kf_dims = ("dp", None, "model", None)
        k = shard_hint(k, *kf_dims)
        v = shard_hint(v, *kf_dims)

    nchunks = max(1, sq // chunk)
    assert sq % nchunks == 0, (sq, chunk)
    cq = sq // nchunks
    qc_all = q.reshape(b, hq, nchunks, cq, dh)
    qpos_c = jnp.moveaxis(q_positions.reshape(b, nchunks, cq), 1, 0)

    # keep K/V in their native dtype (bf16 in production) and request fp32
    # ACCUMULATION via preferred_element_type — explicit astype(f32) copies
    # of the whole KV cache were hoisted out of the layer loop by XLA and
    # doubled decode peak memory (§Perf B3). Tests pass f32 inputs and are
    # bit-identical through this path.
    kf = k
    vf = v

    @jax.checkpoint  # recompute scores per chunk in backward: without this,
    # the map stacks (nchunks, B, H, cq, Skv) fp32 score residuals — the
    # exact O(S^2) blow-up this chunking exists to avoid.
    def one_chunk(args):
        qc, qpos = args                                  # (B,H,cq,dh), (B,cq)
        if seq_tp:
            qc = shard_hint(qc, "dp", None, "model", None)
        s = jnp.einsum("bhcd,bhsd->bhcs", qc, kf,
                       preferred_element_type=jnp.float32) * scale
        if seq_tp:
            s = shard_hint(s, "dp", None, "model", None)
        elif kv_seq_tp:
            # decode with seq-sharded KV: keep the scores KEY-sharded; the
            # softmax reductions become tiny cross-shard ARs instead of a
            # full KV gather (distributed softmax; §Perf B2)
            s = shard_hint(s, "dp", None, None, "model")
        qp = qpos[:, None, :, None]                      # (B,1,cq,1)
        kp = k_positions[:, None, None, :]               # (B,1,1,Skv)
        mask = jnp.ones((b, 1, cq, k.shape[2]), bool)
        if causal:
            mask = qp >= kp
        if window is not None:
            w_ok = (qp - kp) < window
            if is_global is not None:
                w_ok = jnp.logical_or(w_ok, is_global)
            mask = jnp.logical_and(mask, w_ok)
        # invalid key slots are marked with negative positions
        mask = jnp.logical_and(mask, kp >= 0)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)          # fp32 softmax
        return jnp.einsum("bhcs,bhsd->bhcd", p.astype(vf.dtype), vf,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    out = jax.lax.map(one_chunk, (jnp.moveaxis(qc_all, 2, 0), qpos_c))
    out = jnp.moveaxis(out, 0, 2)                        # (B,H,nc,cq,dh)
    return out.reshape(b, hq, sq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, kv_heads: int, length: int, head_dim: int,
                  dtype=jnp.bfloat16):
    """Linear KV cache. `positions` is PER ROW (B, length): the absolute
    position stored in each slot (-1 = empty). Per-row tracking is what lets
    one fused decode step serve a continuous-batching pool where every
    sequence sits at a different offset; it also uniformizes linear and
    ring-buffer caches."""
    return {
        "k": jnp.zeros((batch, kv_heads, length, head_dim), dtype),
        "v": jnp.zeros((batch, kv_heads, length, head_dim), dtype),
        "positions": jnp.full((batch, length), -1, jnp.int32),
    }


def cache_update(cache, k_new, v_new, pos, *, ring: bool = False):
    """Insert (B, KV, S_new, Dh) at absolute position ``pos`` — a traced
    int32 scalar (all rows aligned) or an (B,) vector (continuous batching).

    ring=True wraps slot indices mod cache length (sliding-window cache).

    Aligned rows (scalar pos) use ``dynamic_update_slice``: the SPMD
    partitioner keeps a DUS on the cache's own sharding, whereas the
    per-row scatter forces an involuntary reshard that replicates the whole
    cache through collectives every decode step (§Perf iteration 1)."""
    b = cache["k"].shape[0]
    length = cache["k"].shape[2]
    s_new = k_new.shape[2]
    if ring and s_new > length:
        # prefill longer than the window: only the last `length` tokens matter
        k_new = k_new[:, :, -length:]
        v_new = v_new[:, :, -length:]
        pos = pos + (s_new - length)
        s_new = length
    pos = jnp.asarray(pos, jnp.int32)

    if pos.ndim == 0 and (not ring or s_new == 1):
        # one contiguous window (ring with s_new==1 wraps to a single slot)
        start = jnp.mod(pos, length) if ring else pos
        abs_row = pos + jnp.arange(s_new, dtype=jnp.int32)       # (s_new,)
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), start, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), start, axis=2)
        positions = jax.lax.dynamic_update_slice_in_dim(
            cache["positions"],
            jnp.broadcast_to(abs_row, (b, s_new)), start, axis=1)
        return {"k": k, "v": v, "positions": positions}

    # heterogeneous rows (continuous batching) or wrapping ring prefill:
    # per-row scatter
    pos = jnp.broadcast_to(pos, (b,))
    abs_pos = pos[:, None] + jnp.arange(s_new, dtype=jnp.int32)  # (B, s_new)
    slots = jnp.mod(abs_pos, length) if ring else abs_pos

    def put_row(buf, new, sl):                # (KV,S,dh), (KV,s,dh), (s,)
        return buf.at[:, sl, :].set(new.astype(buf.dtype))

    k = jax.vmap(put_row)(cache["k"], k_new, slots)
    v = jax.vmap(put_row)(cache["v"], v_new, slots)
    positions = jax.vmap(lambda p, sl, ap: p.at[sl].set(ap))(
        cache["positions"], slots, abs_pos)
    return {"k": k, "v": v, "positions": positions}


def attend_cache(q, cache, *, scale: float, q_positions, window=None,
                 is_global=None, chunk: int = 512):
    """Attention of q (B, Hq, Sq, Dh) against a (possibly ring) cache."""
    return chunked_attention(
        q, cache["k"], cache["v"], scale=scale, causal=True,
        q_positions=q_positions, k_positions=cache["positions"],
        window=window, is_global=is_global, chunk=chunk)


# ---------------------------------------------------------------------------
# the full attention block
# ---------------------------------------------------------------------------

def attn_apply(p, x, cfg, *, positions, cache=None, cache_pos=None,
               mrope_positions=None, window=None, is_global=None,
               cross_kv=None, causal=None, compute_dtype=jnp.bfloat16,
               chunk: int = 512):
    """Returns (out, new_cache). Modes:
      - train/prefill: cache=None -> self-attention over x (causal).
      - prefill w/ cache: cache given, cache_pos=0 -> fills cache, attends.
      - decode: x is (B, 1, D), cache_pos = current position.
      - cross: cross_kv = {"k","v"} precomputed (non-causal; Whisper).
    """
    b, s, _ = x.shape
    dh = cfg.head_dim
    scale = dh ** -0.5
    causal = cfg.causal if causal is None else causal
    q, k, v = _project_qkv(p, x, cfg, compute_dtype=compute_dtype)

    if cross_kv is not None:
        q = q.transpose(0, 2, 1, 3)
        out = chunked_attention(q, cross_kv["k"], cross_kv["v"], scale=scale,
                                causal=False, chunk=chunk)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
        return linear(p["wo"], out, compute_dtype=compute_dtype), cache

    q, k = _rope(q, k, cfg, positions, mrope_positions)
    # TP layout for attention: heads over the model axis when they divide
    # it; otherwise SEQUENCE over the model axis (q only). Without the
    # fallback XLA shards q-seq just 2-way for e.g. smollm's 15 heads on a
    # 16-way axis => 8x redundant score compute + replicated score memory
    # (§Perf iteration C1).
    am = get_abstract_mesh()
    heads_divide = (not am.empty and "model" in am.axis_names
                    and cfg.n_heads % am.shape["model"] == 0)
    if s == 1:
        # decode: one query token — keep q replicated across the model axis;
        # the KV cache stays sequence-sharded (distributed softmax)
        q = shard_hint(q.transpose(0, 2, 1, 3), "dp", None, None, None)
    elif heads_divide:
        q = shard_hint(q.transpose(0, 2, 1, 3), "dp", "model", None, None)
    else:
        q = shard_hint(q.transpose(0, 2, 1, 3), "dp", None, "model", None)
    k = shard_hint(k.transpose(0, 2, 1, 3), "dp", None, None, None)
    v = shard_hint(v.transpose(0, 2, 1, 3), "dp", None, None, None)

    if cache is not None:
        # ring buffer when the cache is only as long as the sliding window
        ring = window is not None and cache["k"].shape[2] <= window
        cache = cache_update(cache, k, v, cache_pos, ring=ring)
        cp = jnp.asarray(cache_pos, jnp.int32)
        qpos = (cp[:, None] if cp.ndim == 1 else cp) \
            + jnp.arange(s, dtype=jnp.int32)
        out = attend_cache(q, cache, scale=scale, q_positions=qpos,
                           window=window, is_global=is_global, chunk=chunk)
    else:
        out = chunked_attention(q, k, v, scale=scale, causal=causal,
                                q_positions=positions[0] if positions.ndim > 1 else positions,
                                window=window, is_global=is_global, chunk=chunk)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return linear(p["wo"], out, compute_dtype=compute_dtype), cache
