"""Mixture-of-Experts with sort-based dispatch (no (S, E, C) one-hot).

Tokens are routed top-k, then *sorted by expert id* within each group (group
= one batch row, which is data-sharded, so the sort never crosses shards).
Slot tables (E, C) of token indices are built from searchsorted offsets; the
expert FFN is ONE einsum against the stacked expert weights (E is a real
tensor dim => expert-parallel sharding is a PartitionSpec on E), and results
scatter-add back. Capacity-dropped tokens fall through on the residual.

This is the TPU-native expression of "weight stationary" for MoE: expert
weights stay put (sharded on E over the data axis / pod axis), activations
move through all-to-all-style collectives inserted by SPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .module import KeyStream, lecun_normal
from .layers import swiglu
from ..sharding.hints import shard_hint


def moe_init(key, cfg, dtype=jnp.float32):
    ks = KeyStream(key)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    return {
        "router": lecun_normal(ks(), (d, e), fan_in=d, dtype=jnp.float32),
        "w_gate": lecun_normal(ks(), (e, d, f), fan_in=d, dtype=dtype),
        "w_up": lecun_normal(ks(), (e, d, f), fan_in=d, dtype=dtype),
        "w_down": lecun_normal(ks(), (e, f, d), fan_in=f, dtype=dtype),
    }


def capacity(tokens_per_group: int, top_k: int, n_experts: int,
             factor: float = 1.25) -> int:
    c = int(tokens_per_group * top_k * factor / n_experts) + 1
    return max(1, min(c, tokens_per_group * top_k))


def moe_apply(p, x, cfg, *, compute_dtype=jnp.bfloat16):
    """x: (B, S, D) -> (B, S, D), plus aux losses dict.

    Groups == batch rows (B is the data-sharded axis)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(s, k, e, cfg.moe_capacity_factor)

    logits = (x.astype(jnp.float32) @ p["router"])           # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # (B,S,K)
    if cfg.moe_norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- sort tokens by expert id within each group -----------------------
    flat_e = idx.reshape(b, s * k)                           # (B, S*K)
    flat_t = jnp.broadcast_to(jnp.arange(s)[:, None], (s, k)).reshape(-1)
    flat_t = jnp.broadcast_to(flat_t, (b, s * k))
    flat_g = gates.reshape(b, s * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)

    # ---- slot table (E, C) -------------------------------------------------
    eids = jnp.arange(e)
    starts = jax.vmap(lambda a: jnp.searchsorted(a, eids, side="left"))(se)
    ends = jax.vmap(lambda a: jnp.searchsorted(a, eids, side="right"))(se)
    slots = starts[:, :, None] + jnp.arange(c)[None, None, :]   # (B,E,C)
    valid = slots < ends[:, :, None]
    slots_c = jnp.clip(slots, 0, s * k - 1).reshape(b, e * c)
    tok = jnp.take_along_axis(st, slots_c, axis=1).reshape(b, e, c)
    gate = jnp.take_along_axis(sg, slots_c, axis=1).reshape(b, e, c)
    gate = jnp.where(valid, gate, 0.0)

    # ---- gather -> expert FFN -> scatter ----------------------------------
    xin = jnp.take_along_axis(
        x, tok.reshape(b, e * c, 1), axis=1).reshape(b, e, c, d)
    xin = (xin * valid[..., None]).astype(compute_dtype)
    # Expert-parallel alignment for DECODE (s == 1): dispatch activations
    # E-over-dp to MATCH the expert weights' storage sharding — tokens move
    # (~MBs of all-to-all), weights stay put. Without this XLA all-gathers
    # the full expert weights to every chip each step (529 GB/chip/step
    # measured on arctic-480b decode_32k; §Perf B2). For train/prefill the
    # token tensors outweigh the weights, so the hint stays batch-major.
    decode_ep = s == 1
    if decode_ep:
        xin = shard_hint(xin, None, "dp", None, "model")
    h = swiglu(
        jnp.einsum("becd,edf->becf", xin, p["w_gate"].astype(compute_dtype)),
        jnp.einsum("becd,edf->becf", xin, p["w_up"].astype(compute_dtype)))
    if decode_ep:
        h = shard_hint(h, None, "dp", None, "model")
    out = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(compute_dtype))
    out = out * gate[..., None].astype(compute_dtype)
    if decode_ep:
        out = shard_hint(out, "dp", None, None, None)

    y = jnp.zeros((b, s, d), compute_dtype)
    y = y.at[jnp.arange(b)[:, None], tok.reshape(b, e * c)].add(
        out.reshape(b, e * c, d))

    # ---- aux: load-balancing loss (Switch style) ---------------------------
    me = probs.mean(axis=(0, 1))                              # (E,)
    ce = jax.nn.one_hot(idx[..., 0], e).mean(axis=(0, 1))
    aux = {"load_balance": e * jnp.sum(me * ce),
           "router_z": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)}
    return y.astype(x.dtype), aux
