from . import module, layers  # noqa: F401
