"""Minimal functional module system (no flax): params are nested dicts of
jnp arrays; every layer is an ``init(key, ...) -> params`` / ``apply(params,
x, ...) -> y`` pair. Sharding is assigned *by parameter path* (see
``repro.sharding.rules``), so the tree layout is the single source of truth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

Params = dict  # nested dict[str, Params | jnp.ndarray]


class KeyStream:
    """Deterministic stream of PRNG keys: ``ks = KeyStream(key); k = ks()``."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def trunc_normal(key, shape, std: float = 0.02, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype) * std


def lecun_normal(key, shape, fan_in: int | None = None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return trunc_normal(key, shape, std=std, dtype=dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------

def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def tree_paths(params: Params) -> Iterator[tuple[str, Any]]:
    """Yield ('a/b/c', leaf) pairs for a nested-dict param tree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        yield "/".join(_key_str(k) for k in path), leaf


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def map_with_path(fn: Callable[[str, Any], Any], tree: Params) -> Params:
    """tree_map where fn receives ('a/b/c', leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn("/".join(_key_str(k) for k in path), leaf), tree
    )


def cast_tree(tree: Params, dtype) -> Params:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


@dataclasses.dataclass(frozen=True)
class DTypes:
    """Mixed-precision policy."""

    param: Any = jnp.float32     # storage dtype of weights
    compute: Any = jnp.bfloat16  # matmul dtype
    accum: Any = jnp.float32     # reductions / softmax / losses
