"""Library-grade micro-batching over a ``CompiledModel`` — the serve half
of the compile/serve split.

Requests (each carrying one or more images) enter a queue; the engine
drains them through the model's jit-compiled fixed-shape steps, fusing
images from different requests into one batch. Multi-bucket dispatch is
the point: instead of always padding the backlog up to one fixed batch,
the engine picks the cheapest compiled bucket for it — with
``batch_buckets=(2, 8)`` a backlog of 2 runs the 2-bucket, not 2 padded
to 8 — so pad waste at low occupancy collapses. The engine accounts for
exactly that: ``stats()["pad_waste"]`` is padded
rows / total rows, the metric that motivates multi-bucket dispatch and
guards its regression.

    model = compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    eng = MicroBatchEngine(model)
    eng.submit(images_u8)                  # -> Request (labels fill on run)
    eng.run()                              # drain the queue
    print(eng.stats())                     # fps, p50/p95 latency, pad_waste

This is the paper's real-time classification loop (VESTA sustains ~30 fps
on Spikformer V2); drivers compare ``stats()["fps"]`` against that target.
``repro.launch.serve_spikformer`` is the CLI wrapper.

This module also owns the pieces the engine SHARES with the asynchronous
continuous-batching runtime (``repro.serve.runtime``): submit-door request
validation (``validate_images``), batch assembly (``assemble_batch``),
per-step accounting (``StepAccounting``), the latency-percentile summary
(``latency_summary``), and the queue-depth watermark
(``QueueDepthWatermark``) — one implementation for the sync and async
serving paths, which is part of why an identical request trace produces
bit-identical labels through both.

Observability (``repro.obs``): every ServeClient accepts a ``tracer`` and
emits the canonical request lifecycle ``admit -> queue -> place ->
assemble -> step -> complete`` as spans; completed-request latencies feed
a bounded ``LatencyHistogram`` so ``stats()`` percentiles cost O(buckets)
memory however long the server lives.
"""
from __future__ import annotations

import dataclasses
import time
import typing
from collections import deque

import numpy as np

from ..obs.metrics import Gauge, LatencyHistogram
from ..obs.trace import NULL_TRACER

PAPER_FPS = 30.0   # VESTA's reported real-time Spikformer V2 rate

# Version of the shared ``stats()`` schema every ServeClient implements.
# Bump when a shared key is renamed, its meaning changes, or a key every
# client must report is added; additive client-specific keys (replica
# table) do not bump it.
#   v2: ``queue_depth_peak`` joined the shared vocabulary — the queue-depth
#       high-watermark (max images queued at any submit), the backpressure
#       number bursty event-stream arrivals made necessary: a mean queue
#       depth hides a burst that grazed the admission bound.
#   v3: the ``latency_*`` fields are histogram-backed (``repro.obs.metrics.
#       LatencyHistogram``): same keys, same units, same ``None``-when-empty
#       contract, but percentiles now come from log-spaced buckets (<= 5%
#       documented relative error) instead of an unbounded sorted list —
#       a million-request server holds O(buckets) latency state. Meaning
#       changed (bounded approximation), so the version bumps.
SERVE_STATS_VERSION = 3


@typing.runtime_checkable
class ServeClient(typing.Protocol):
    """The one serving surface: sync engine, async runtime, and fleet all
    speak exactly this, so drivers (``repro.serve.loadgen``,
    ``benchmarks/infer_bench.py``) run against any of them without
    isinstance checks.

    * ``submit(images, *, rid=None, on_image=None)`` — keyword-only
      options; returns a ``Request`` whose ``result()`` yields the labels.
    * ``stats()`` — the versioned schema built by ``serve_stats``
      (``stats_version``, ``fps``, ``occupancy``, ``pad_waste``,
      ``latency_*``, ...).
    * ``close(timeout=None)`` — drain: every accepted request resolves
      before close returns.
    """

    def submit(self, images, *, rid: int | None = None,
               on_image=None) -> "Request": ...

    def stats(self) -> dict: ...

    def close(self, timeout: float | None = None) -> None: ...


@dataclasses.dataclass
class Request:
    """One classification request: n images in, n labels out.

    ``on_image(rid, index, label)`` is an optional streaming callback fired
    as each image's batch completes (possibly before the whole request)."""
    rid: int
    images: np.ndarray                  # (n, H, W, C) uint8
    labels: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_dequeue: float = 0.0              # first image leaves the queue
    t_done: float = 0.0
    on_image: object = None

    @property
    def latency_s(self) -> float | None:
        """Submit-to-done latency; ``None`` while the request is still in
        flight (``t_done`` unset) — the raw subtraction would report a
        nonsense negative number against a live ``t_submit``."""
        if not self.t_done:
            return None
        return self.t_done - self.t_submit

    def result(self, timeout: float | None = None) -> list:
        """The label list, blocking/draining as the serving path requires.

        On the sync engine the submitting thread IS the serving thread, so
        an incomplete request drains the engine (the hook the engine
        attached at submit) and returns. ``AsyncRequest`` overrides this
        with a real future wait. One spelling — ``req.result()`` — works
        against every ServeClient, which is what lets the open-loop load
        generator drive all of them."""
        if not self.t_done:
            drain = getattr(self, "_drain", None)
            if drain is not None:
                drain()
        if not self.t_done:
            raise RuntimeError(
                f"request {self.rid} is not complete and has no serving "
                "loop attached to drain it")
        return list(self.labels)


# ---------------------------------------------------------------------------
# Shared serve plumbing: the sync engine below and the async runtime in
# repro.serve.runtime both build on these, so batch shapes, pad accounting
# and latency reporting cannot drift between the two paths.
# ---------------------------------------------------------------------------

def validate_images(images, image_shape) -> np.ndarray:
    """Validate a request's images at the ``submit()`` door against the
    compiled model's input spec and return them as ``(n, H, W, C)`` uint8.

    A malformed request must fail HERE, with an error naming the expected
    per-image ``(H, W, C)`` — not several layers deep in a jitted step with
    a shape error about a tensor the caller never constructed. Accepted:
    uint8 directly; other integer dtypes if every pixel is in [0, 255]
    (cast); anything else (floats, bools) is rejected.
    """
    arr = np.asarray(images)
    image_shape = tuple(int(d) for d in image_shape)
    if arr.ndim != 4 or tuple(arr.shape[1:]) != image_shape:
        raise ValueError(
            f"request images have shape {tuple(arr.shape)}; this compiled "
            f"model expects (n, H, W, C) = (n, {image_shape[0]}, "
            f"{image_shape[1]}, {image_shape[2]})")
    if arr.dtype != np.uint8:
        if not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(
                f"request images have dtype {arr.dtype}; expected uint8 "
                "pixel values in [0, 255]")
        if arr.size and (int(arr.min()) < 0 or int(arr.max()) > 255):
            raise ValueError(
                f"request images of dtype {arr.dtype} contain values "
                f"outside [0, 255]; cannot safely cast to uint8 pixels")
        arr = arr.astype(np.uint8)
    return arr


def batch_occupancy(images) -> float:
    """Fraction of set bits across a uint8 image batch — the serving-level
    spike-occupancy proxy (pixel bits are exactly what the SSSC front end
    consumes as value planes). Pass only the REAL rows of a padded batch;
    zero pad rows would dilute the measurement. Returns 0.0 for an empty
    batch."""
    arr = np.asarray(images, np.uint8)
    if not arr.size:
        return 0.0
    return float(np.unpackbits(arr.reshape(-1)).mean())


def assemble_batch(images: list, bucket: int):
    """Stack per-image arrays and zero-pad up to the bucket shape.

    Returns ``(batch, pad)`` with ``batch.shape[0] == bucket`` and ``pad``
    the number of appended zero rows.
    """
    batch = np.stack(images)
    pad = bucket - len(images)
    if pad:
        batch = np.concatenate(
            [batch, np.zeros((pad, *batch.shape[1:]), batch.dtype)])
    return batch, pad


@dataclasses.dataclass
class StepAccounting:
    """Per-step serving accounting: batches, rows, pad waste, timing, and
    spike occupancy (rows-weighted, when steps measure it)."""
    batches: int = 0
    images: int = 0
    padded_rows: int = 0
    total_rows: int = 0
    busy_s: float = 0.0         # model-step compute only
    wall_s: float = 0.0         # whole steps incl. batch assembly
    occupancy_weighted: float = 0.0   # sum of per-step occupancy * rows
    occupancy_rows: int = 0           # rows with a measured occupancy

    def record_step(self, *, rows: int, bucket: int, busy_s: float,
                    wall_s: float, occupancy: float | None = None) -> None:
        self.batches += 1
        self.images += rows
        self.padded_rows += bucket - rows
        self.total_rows += bucket
        self.busy_s += busy_s
        self.wall_s += wall_s
        if occupancy is not None:
            self.occupancy_weighted += float(occupancy) * rows
            self.occupancy_rows += rows

    @property
    def pad_waste(self) -> float:
        """Padded rows / total rows across all steps so far — the cost
        multi-bucket dispatch exists to cut."""
        return self.padded_rows / self.total_rows if self.total_rows else 0.0

    @property
    def occupancy(self) -> float | None:
        """Rows-weighted mean spike occupancy over measured steps, ``None``
        when no step ever measured it (distinguishable from a true 0.0 —
        an all-dark batch is a measurement, absence is not)."""
        if not self.occupancy_rows:
            return None
        return self.occupancy_weighted / self.occupancy_rows

    @property
    def fps(self) -> float:
        """Images per second of step wall time (service capacity, not
        arrival-bounded throughput — the open-loop load generator measures
        the latter)."""
        return self.images / self.wall_s if self.wall_s else 0.0


def latency_summary(latencies_s, *, prefix: str = "latency_") -> dict:
    """p50/p95/p99/mean over per-request latencies, ``None`` when empty —
    the shared tail-latency report for engine/runtime/loadgen stats.

    Empty-safe by contract: a zero-completed-request window (and any
    ``None`` entries from still-in-flight requests that leaked into the
    iterable) reports all-``None`` fields — callers must never need to
    guard. A single sample reports that sample exactly.

    Values are seconds rounded to 6 decimals (microsecond precision):
    serving steps on small models land well under a millisecond, and the
    bench comparisons read these fields — rounding to 4 would collapse
    real sub-millisecond p50/p99 deltas into quantization noise."""
    lat = np.asarray([v for v in latencies_s if v is not None], np.float64)
    if not len(lat):
        return {f"{prefix}{k}": None for k in ("p50_s", "p95_s", "p99_s",
                                               "mean_s")}
    return {
        f"{prefix}p50_s": round(float(np.percentile(lat, 50)), 6),
        f"{prefix}p95_s": round(float(np.percentile(lat, 95)), 6),
        f"{prefix}p99_s": round(float(np.percentile(lat, 99)), 6),
        f"{prefix}mean_s": round(float(lat.mean()), 6),
    }


def serve_stats(*, acct: StepAccounting, done, buckets,
                queue_depth_peak: int = 0,
                latency_hist: LatencyHistogram | None = None,
                extra: dict | None = None) -> dict:
    """The versioned common ``ServeClient.stats()`` schema — ONE builder,
    so the shared keys (``fps``, ``occupancy``, ``pad_waste``,
    ``latency_*``, ``queue_depth_peak``) cannot drift between the sync
    engine, the async runtime, and the fleet. ``extra`` adds
    client-specific keys (rejections, per-replica table) without touching
    the shared vocabulary.

    ``latency_hist`` is the v3 percentile source: every client feeds its
    completed-request latencies into a bounded ``LatencyHistogram`` and
    passes it here, so the report costs O(buckets) however many requests
    the server has lived through. Without one (bare callers, old tests)
    the exact sorted-list path over ``done`` still works — same keys
    either way."""
    if latency_hist is not None:
        latency = latency_hist.summary()
    else:
        latency = latency_summary(r.latency_s for r in done)
    out = {
        "stats_version": SERVE_STATS_VERSION,
        "queue_depth_peak": int(queue_depth_peak),
        "requests": len(done),
        "images": acct.images,
        "batches": acct.batches,
        "buckets": list(buckets),
        "wall_s": round(acct.wall_s, 4),
        "fps": round(acct.fps, 2),
        "paper_fps": PAPER_FPS,
        "realtime": bool(acct.wall_s and acct.fps >= PAPER_FPS),
        "padded_rows": acct.padded_rows,
        "total_rows": acct.total_rows,
        "pad_waste": round(acct.pad_waste, 4),
        "occupancy": (None if acct.occupancy is None
                      else round(acct.occupancy, 4)),
        **latency,
    }
    if extra:
        out.update(extra)
    return out


class QueueDepthWatermark:
    """The queue-depth high-watermark every ServeClient reports as
    ``queue_depth_peak`` — ONE gauge-backed implementation shared by the
    sync engine, the async runtime, and the fleet, so the bookkeeping
    (formerly three copy-pasted ``max()`` updates) cannot drift between
    submit doors. ``observe`` after every enqueue; ``peak`` is the gauge's
    high-watermark."""

    __slots__ = ("gauge",)

    def __init__(self, gauge: Gauge | None = None):
        self.gauge = Gauge("queue_depth") if gauge is None else gauge

    def observe(self, depth: int) -> None:
        self.gauge.set(int(depth))

    @property
    def peak(self) -> int:
        return 0 if self.gauge.max is None else int(self.gauge.max)


class MicroBatchEngine:
    """Micro-batching classifier over a multi-bucket ``CompiledModel``.

    Implements the ``ServeClient`` protocol (submit / stats / close): the
    closed-loop member of the serving family — ``close()`` is a drain, and
    a ``result()`` on an incomplete request drains inline.

    ``tracer`` (a ``repro.obs.Tracer``) records the request lifecycle
    spans; ``clock`` is injected (default ``time.perf_counter``) so a test
    can pin the engine's full span table deterministically — the sync
    engine has no sleeping worker, so unlike the async runtime its clock
    is free to be fake."""

    def __init__(self, model, *, tracer=None, clock=time.perf_counter):
        self.model = model
        self.buckets = tuple(model.buckets)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._clock = clock
        self.queue: deque = deque()         # (request, image index)
        self.done: list[Request] = []
        self._pending: dict[int, int] = {}  # rid -> images left
        self._next_rid = 0
        self._queue_depth = QueueDepthWatermark()
        self.latency_hist = LatencyHistogram()
        self.acct = StepAccounting()

    @property
    def queue_depth_peak(self) -> int:
        return self._queue_depth.peak

    # accounting attribute surface predates StepAccounting; keep it readable
    @property
    def batches(self) -> int:
        return self.acct.batches

    @property
    def images_done(self) -> int:
        return self.acct.images

    @property
    def padded_rows(self) -> int:
        return self.acct.padded_rows

    @property
    def total_rows(self) -> int:
        return self.acct.total_rows

    @property
    def busy_s(self) -> float:
        return self.acct.busy_s

    @property
    def wall_s(self) -> float:
        return self.acct.wall_s

    def submit(self, images, *, rid: int | None = None,
               on_image=None) -> Request:
        """Queue raw images (or a prebuilt ``Request``) — the ServeClient
        door, options keyword-only. Images are validated against the
        compiled model's input spec right here.

        ``rid`` names the request id for raw images; for a ``Request``
        instance it must agree with ``req.rid`` — silently ignoring a
        conflicting ``rid=`` would complete the request under an id the
        caller never sees again. ``on_image(rid, index, label)`` streams
        per-image completions, same contract as the async runtime."""
        t_enter = self._clock()
        if isinstance(images, Request):
            req = images
            if rid is not None and rid != req.rid:
                raise ValueError(
                    f"submit(rid={rid}) conflicts with the Request's own "
                    f"rid={req.rid}; drop the argument or pass raw images")
            if on_image is not None:
                req.on_image = on_image
            req.images = validate_images(req.images,
                                         self.model.input_shape()[1:])
        else:
            arr = validate_images(images, self.model.input_shape()[1:])
            if rid is None:
                rid = self._next_rid
            req = Request(rid=rid, images=arr, on_image=on_image)
        if req.rid in self._pending:
            # a silent overwrite would strand one of the two requests
            # (completion is counted per rid) — fail at the door instead
            raise ValueError(f"request id {req.rid} is already in flight")
        self._next_rid = max(self._next_rid, req.rid + 1)
        req.t_submit = self._clock()
        req.labels = [None] * len(req.images)
        # result() on a not-yet-run request drains this engine inline —
        # the sync spelling of the async future (see Request.result)
        req._drain = self.run
        tr = self.tracer
        if not len(req.images):
            # nothing to queue: complete immediately so run()/stats() see it
            req.t_done = req.t_submit
            self.done.append(req)
            self.latency_hist.observe(0.0)
            if tr.enabled:
                tr.span("request", "admit", t0=t_enter, t1=req.t_submit,
                        rid=req.rid, value=0)
                tr.span("request", "complete", t0=req.t_submit,
                        t1=req.t_done, rid=req.rid)
            return req
        self._pending[req.rid] = len(req.images)
        for i in range(len(req.images)):
            self.queue.append((req, i))
        self._queue_depth.observe(len(self.queue))
        if tr.enabled:
            tr.span("request", "admit", t0=t_enter, t1=req.t_submit,
                    rid=req.rid, value=len(req.images))
            tr.counter("queue_depth", len(self.queue), t=req.t_submit)
        return req

    def pick_bucket(self, backlog: int) -> int:
        """The bucket the next step should run: the largest bucket while
        the backlog covers it, else the first chunk of the model's exact
        pad-minimizing split of the remainder — so 3 queued images over
        buckets (2, 8) run 2 now + 2-with-one-pad next, never 3 padded
        to 8. (The early-out keeps a deep backlog O(1) per step instead
        of re-splitting the whole queue every batch.)"""
        if backlog >= self.buckets[-1]:
            return self.buckets[-1]
        return self.model.plan_chunks(backlog)[0][1]

    def step(self) -> int:
        """Classify one fused batch drawn across requests; returns #images."""
        if not self.queue:
            return 0
        tr = self.tracer
        t_start = self._clock()
        bucket = self.pick_bucket(len(self.queue))
        t_place = self._clock()
        if tr.enabled:
            tr.span("batch", "place", t0=t_start, t1=t_place, bucket=bucket)
        work = [self.queue.popleft()
                for _ in range(min(bucket, len(self.queue)))]
        t_pop = self._clock()
        if tr.enabled:
            for req, _ in work:
                if not req.t_dequeue:     # first image leaving the queue
                    req.t_dequeue = t_pop
                    tr.span("request", "queue", t0=req.t_submit, t1=t_pop,
                            rid=req.rid)
        batch, _ = assemble_batch([req.images[i] for req, i in work], bucket)
        occ = batch_occupancy(batch[:len(work)])  # real rows only
        t0 = self._clock()
        if tr.enabled:
            tr.span("batch", "assemble", t0=t_pop, t1=t0, bucket=bucket,
                    occupancy=occ, value=len(work))
        logits = np.asarray(self.model.step(batch))
        busy_s = self._clock() - t0
        if tr.enabled:
            tr.span("batch", "step", t0=t0, t1=t0 + busy_s, bucket=bucket,
                    occupancy=occ, value=len(work))
            tr.counter("occupancy", occ, t=t0)
        labels = logits[:len(work)].argmax(axis=-1)
        now = self._clock()
        for (req, i), lab in zip(work, labels):
            req.labels[i] = int(lab)
            self._pending[req.rid] -= 1
            if self._pending[req.rid] == 0:
                del self._pending[req.rid]     # rid leaves "in flight"
                req.t_done = now
                self.done.append(req)
                self.latency_hist.observe(now - req.t_submit)
                if tr.enabled:
                    tr.span("request", "complete", t0=req.t_submit, t1=now,
                            rid=req.rid)
        self.acct.record_step(rows=len(work), bucket=bucket, busy_s=busy_s,
                              wall_s=self._clock() - t_start,
                              occupancy=occ)
        for (req, i), lab in zip(work, labels):
            if req.on_image is not None:
                try:
                    req.on_image(req.rid, i, int(lab))
                except Exception:
                    pass   # a streaming callback must not kill serving
        return len(work)

    def run(self) -> list[Request]:
        """Drain the queue; returns the completed requests. (Wall time is
        accumulated per step, so driving ``step()`` directly reports the
        same honest fps basis.)"""
        while self.queue:
            self.step()
        return self.done

    def close(self, timeout: float | None = None) -> None:
        """ServeClient close: drain the queue — every accepted request
        completes. (``timeout`` is accepted for signature parity; a sync
        drain either finishes or raises.)"""
        self.run()

    # -- accounting ---------------------------------------------------------

    @property
    def pad_waste(self) -> float:
        return self.acct.pad_waste

    def stats(self) -> dict:
        """Serving metrics over everything processed so far (the shared
        ServeClient schema)."""
        return serve_stats(acct=self.acct, done=self.done,
                           buckets=self.buckets,
                           queue_depth_peak=self.queue_depth_peak,
                           latency_hist=self.latency_hist)
