"""Library-grade micro-batching over a ``CompiledModel`` — the serve half
of the compile/serve split.

Requests (each carrying one or more images) enter a queue; the engine
drains them through the model's jit-compiled fixed-shape steps, fusing
images from different requests into one batch. Multi-bucket dispatch is
the point: instead of always padding the backlog up to one fixed batch,
the engine picks the cheapest compiled bucket for it — with
``batch_buckets=(2, 8)`` a backlog of 2 runs the 2-bucket, not 2 padded
to 8 — so pad waste at low occupancy collapses. The engine accounts for
exactly that: ``stats()["pad_waste"]`` is padded
rows / total rows, the metric that motivates multi-bucket dispatch and
guards its regression.

    model = compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    eng = MicroBatchEngine(model)
    eng.submit(images_u8)                  # -> Request (labels fill on run)
    eng.run()                              # drain the queue
    print(eng.stats())                     # fps, p50/p95 latency, pad_waste

This is the paper's real-time classification loop (VESTA sustains ~30 fps
on Spikformer V2); drivers compare ``stats()["fps"]`` against that target.
``repro.launch.serve_spikformer`` is the CLI wrapper.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

PAPER_FPS = 30.0   # VESTA's reported real-time Spikformer V2 rate


@dataclasses.dataclass
class Request:
    """One classification request: n images in, n labels out."""
    rid: int
    images: np.ndarray                  # (n, H, W, C) uint8
    labels: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


class MicroBatchEngine:
    """Micro-batching classifier over a multi-bucket ``CompiledModel``."""

    def __init__(self, model):
        self.model = model
        self.buckets = tuple(model.buckets)
        self.queue: deque = deque()         # (request, image index)
        self.done: list[Request] = []
        self._pending: dict[int, int] = {}  # rid -> images left
        self._next_rid = 0
        # accounting
        self.batches = 0
        self.images_done = 0
        self.padded_rows = 0
        self.total_rows = 0
        self.busy_s = 0.0           # model-step compute only
        self.wall_s = 0.0           # whole steps incl. batch assembly

    def submit(self, request_or_images, rid: int | None = None) -> Request:
        """Queue a ``Request`` (or raw images, wrapped into one)."""
        if isinstance(request_or_images, Request):
            req = request_or_images
        else:
            images = np.asarray(request_or_images, np.uint8)
            if rid is None:
                rid = self._next_rid
            req = Request(rid=rid, images=images)
        if req.rid in self._pending:
            # a silent overwrite would strand one of the two requests
            # (completion is counted per rid) — fail at the door instead
            raise ValueError(f"request id {req.rid} is already in flight")
        self._next_rid = max(self._next_rid, req.rid + 1)
        req.t_submit = time.perf_counter()
        req.labels = [None] * len(req.images)
        if not len(req.images):
            # nothing to queue: complete immediately so run()/stats() see it
            req.t_done = req.t_submit
            self.done.append(req)
            return req
        self._pending[req.rid] = len(req.images)
        for i in range(len(req.images)):
            self.queue.append((req, i))
        return req

    def pick_bucket(self, backlog: int) -> int:
        """The bucket the next step should run: the largest bucket while
        the backlog covers it, else the first chunk of the model's exact
        pad-minimizing split of the remainder — so 3 queued images over
        buckets (2, 8) run 2 now + 2-with-one-pad next, never 3 padded
        to 8. (The early-out keeps a deep backlog O(1) per step instead
        of re-splitting the whole queue every batch.)"""
        if backlog >= self.buckets[-1]:
            return self.buckets[-1]
        return self.model.plan_chunks(backlog)[0][1]

    def step(self) -> int:
        """Classify one fused batch drawn across requests; returns #images."""
        if not self.queue:
            return 0
        t_start = time.perf_counter()
        bucket = self.pick_bucket(len(self.queue))
        work = [self.queue.popleft()
                for _ in range(min(bucket, len(self.queue)))]
        batch = np.stack([req.images[i] for req, i in work])
        pad = bucket - len(work)
        if pad:
            batch = np.concatenate(
                [batch, np.zeros((pad, *batch.shape[1:]), np.uint8)])
        t0 = time.perf_counter()
        logits = np.asarray(self.model.step(batch))
        self.busy_s += time.perf_counter() - t0
        labels = logits[:len(work)].argmax(axis=-1)
        now = time.perf_counter()
        for (req, i), lab in zip(work, labels):
            req.labels[i] = int(lab)
            self._pending[req.rid] -= 1
            if self._pending[req.rid] == 0:
                del self._pending[req.rid]     # rid leaves "in flight"
                req.t_done = now
                self.done.append(req)
        self.batches += 1
        self.images_done += len(work)
        self.padded_rows += pad
        self.total_rows += bucket
        self.wall_s += time.perf_counter() - t_start
        return len(work)

    def run(self) -> list[Request]:
        """Drain the queue; returns the completed requests. (Wall time is
        accumulated per step, so driving ``step()`` directly reports the
        same honest fps basis.)"""
        while self.queue:
            self.step()
        return self.done

    # -- accounting ---------------------------------------------------------

    @property
    def pad_waste(self) -> float:
        """Padded rows / total rows across all steps so far — the cost
        multi-bucket dispatch exists to cut."""
        return self.padded_rows / self.total_rows if self.total_rows else 0.0

    def stats(self) -> dict:
        """Serving metrics over everything processed so far."""
        lat = np.asarray([r.latency_s for r in self.done], np.float64)
        wall = self.wall_s
        return {
            "requests": len(self.done),
            "images": self.images_done,
            "batches": self.batches,
            "buckets": list(self.buckets),
            "wall_s": round(wall, 4),
            "fps": round(self.images_done / wall, 2) if wall else 0.0,
            "paper_fps": PAPER_FPS,
            "realtime": bool(wall and self.images_done / wall >= PAPER_FPS),
            "padded_rows": self.padded_rows,
            "total_rows": self.total_rows,
            "pad_waste": round(self.pad_waste, 4),
            "latency_p50_s": round(float(np.percentile(lat, 50)), 4)
            if len(lat) else None,
            "latency_p95_s": round(float(np.percentile(lat, 95)), 4)
            if len(lat) else None,
            "latency_mean_s": round(float(lat.mean()), 4)
            if len(lat) else None,
        }
