"""InferenceSession: static-shape batched Spikformer inference.

Wraps the BN-folded forward (``core.spikformer.forward_folded``) behind one
jit-compiled entry point with a FIXED batch shape — the serving contract that
keeps the step compiled regardless of how many images each request carries.
Arbitrary request sizes are padded to the next ``batch_size`` multiple and
run in chunks; pad rows are dropped before returning.

    cfg = SpikformerConfig().scaled()
    params = spikformer.init(jax.random.PRNGKey(0), cfg)
    sess = InferenceSession(params, cfg, backend="packed", batch_size=8)
    logits = sess.logits(images_u8)          # (N, classes), any N
    labels = sess.classify(images_u8)        # (N,) argmax

The default "packed" backend carries every inter-layer activation as uint8
bit planes (1 bit/spike in storage); "reference" runs the float
``core.unified`` graph — on CPU the two produce bit-identical logits.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import spikformer
from ..core.spikformer import SpikformerConfig, fold_inference_params
from .backends import get_backend
from .quant import WEIGHT_DTYPES, quantize_folded


class InferenceSession:
    """Compiled, fixed-shape Spikformer classifier over a chosen backend."""

    def __init__(self, params, cfg: SpikformerConfig, *, backend="packed",
                 batch_size: int = 8, folded: bool = False,
                 weight_dtype: str | None = None,
                 pallas: bool | None = None, jit: bool = True):
        """``params`` is a training param tree (BN folded here) unless
        ``folded=True``, in which case it is already a fold_inference_params
        tree (possibly pre-quantized). ``batch_size`` is the static compile
        shape.

        ``weight_dtype="int8"`` quantizes the folded kernels per-out-channel
        to int8 (``infer.quant``); the dequantization scale is folded into
        each layer's LIF threshold, so the packed matmuls stay integer.
        "float32" keeps the BN-folded floats (the exactness reference for
        the float route; with int8, the "reference" backend is the bit-exact
        float *emulation* of the same quantized math). The default ``None``
        means "whatever the tree carries": float32 for a fresh fold, int8
        for a pre-quantized tree."""
        if weight_dtype is not None and weight_dtype not in WEIGHT_DTYPES:
            raise ValueError(f"unknown weight_dtype {weight_dtype!r}; "
                             f"expected one of {WEIGHT_DTYPES}")
        self.cfg = cfg
        self.batch_size = int(batch_size)
        self.backend = get_backend(backend, pallas=pallas)
        self.folded = params if folded else fold_inference_params(params, cfg)
        already_quantized = "scale" in self.folded["scs"]["conv0"]
        if weight_dtype == "float32" and already_quantized:
            raise ValueError(
                "weight_dtype='float32' requested but the folded tree is "
                "already int8-quantized; pass the float tree or drop the "
                "weight_dtype argument")
        if weight_dtype == "int8" and not already_quantized:
            self.folded = quantize_folded(self.folded)
        self.weight_dtype = ("int8" if weight_dtype == "int8"
                             or already_quantized else "float32")

        def fwd(folded_tree, images):
            return spikformer.forward_folded(folded_tree, images, cfg,
                                             backend=self.backend)

        self._fwd = jax.jit(fwd) if jit else fwd

    @property
    def input_shape(self):
        c = self.cfg
        return (self.batch_size, c.img_size, c.img_size, c.in_channels)

    def warmup(self):
        """Compile (and time) the fixed-shape step on zero images."""
        t0 = time.perf_counter()
        jax.block_until_ready(
            self._fwd(self.folded, jnp.zeros(self.input_shape, jnp.uint8)))
        return time.perf_counter() - t0

    def logits(self, images_u8):
        """images_u8: (N, H, W, C) uint8, any N >= 1 -> (N, classes) f32."""
        images_u8 = jnp.asarray(images_u8, jnp.uint8)
        n = images_u8.shape[0]
        bs = self.batch_size
        pad = (-n) % bs
        if pad:
            images_u8 = jnp.concatenate(
                [images_u8, jnp.zeros((pad, *images_u8.shape[1:]),
                                      jnp.uint8)], axis=0)
        outs = [self._fwd(self.folded, images_u8[i:i + bs])
                for i in range(0, n + pad, bs)]
        return jnp.concatenate(outs, axis=0)[:n]

    def classify(self, images_u8):
        """(N, H, W, C) uint8 -> (N,) int32 argmax class ids."""
        return jnp.argmax(self.logits(images_u8), axis=-1).astype(jnp.int32)

    def __call__(self, images_u8):
        return self.logits(images_u8)


def benchmark_session(sess: InferenceSession, *, batches: int = 4,
                      seed: int = 0):
    """Throughput probe: images/sec over ``batches`` full compiled batches
    of random uint8 images (excludes compile via warmup). Returns a dict."""
    compile_s = sess.warmup()
    imgs = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), sess.input_shape, 0, 256, jnp.uint8))
    t0 = time.perf_counter()
    for _ in range(batches):
        jax.block_until_ready(sess._fwd(sess.folded, jnp.asarray(imgs)))
    wall = time.perf_counter() - t0
    n = batches * sess.batch_size
    return {
        "backend": sess.backend.name,
        "weight_dtype": sess.weight_dtype,
        "batch_size": sess.batch_size,
        "images": n,
        "compile_s": round(compile_s, 3),
        "wall_s": round(wall, 4),
        "images_per_s": round(n / wall, 2),
    }
