"""InferenceSession: static-shape batched Spikformer inference.

Wraps the BN-folded forward (``core.spikformer.forward_folded``) behind one
jit-compiled entry point with a FIXED batch shape — the serving contract that
keeps the step compiled regardless of how many images each request carries.
Arbitrary request sizes are padded to the next ``batch_size`` multiple and
run in chunks; pad rows are dropped before returning.

    cfg = SpikformerConfig().scaled()
    params = spikformer.init(jax.random.PRNGKey(0), cfg)
    sess = InferenceSession(params, cfg, backend="packed", batch_size=8)
    logits = sess.logits(images_u8)          # (N, classes), any N
    labels = sess.classify(images_u8)        # (N,) argmax

The default "packed" backend carries every inter-layer activation as uint8
bit planes (1 bit/spike in storage); "reference" runs the float
``core.unified`` graph — on CPU the two produce bit-identical logits.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from ..core import spikformer
from ..core.spikformer import SpikformerConfig, fold_inference_params
from ..kernels import lut_matmul
from ..kernels.ops import choose_route
from .backends import get_backend
from .quant import WEIGHT_DTYPES, map_folded_layers, quantize_folded


def plan_routes(folded, cfg: SpikformerConfig, *, batch_size: int,
                max_table_bytes: int = lut_matmul.MAX_TABLE_BYTES,
                build_tables: bool = True):
    """Per-layer matmul route planning: the byte-LUT's precompute lives here.

    For every folded layer this computes the packed-route matmul shape
    (M, K, N, G) the compiled step will see, asks ``kernels.ops.choose_route``
    whether the unpack-free byte-LUT datapath wins there, and — where it does
    — builds the (C, 256, N) chunk-partial-sum table ONCE and caches it in
    the returned tree as a ``lut`` leaf (so the per-batch work is pure
    gather-and-accumulate). Layers routed "unpack" are left untouched.

    Both backends consume a tree annotated by the same deterministic plan:
    the packed backend executes the gather route, the float reference
    backend the fold-order emulation — the planning decision, like the int8
    threshold fold, is part of the math both sides agree on. The reference
    side never gathers, so ``build_tables=False`` (what ``InferenceSession``
    uses for backends with ``wants_lut_tables = False``) annotates LUT
    layers with a cheap boolean flag instead of the (C, 256, N) tables.
    Returns ``(annotated_tree, plan)`` with ``plan`` mapping layer paths to
    routes.
    """
    t = cfg.timesteps
    g = -(-t // 8)
    m_tok = batch_size * cfg.tokens
    plan = {}

    def shapes_for(path):
        """Packed-route matmul shape (m, live planes, groups) at ``path``."""
        if path.startswith("scs/conv"):
            i = int(path.removeprefix("scs/conv"))
            m = batch_size * (cfg.img_size // 2 ** (i + 1)) ** 2
            # conv0 is SSSC: always 8 value planes, one group
            return (m, 8, 1) if i == 0 else (m, t, g)
        return m_tok, t, g

    def annotate(path, layer):
        wq = layer["kernel"]
        m, tt, gg = shapes_for(path)
        k, n = wq.shape
        route = choose_route(m=m, k=k, n=n, g=gg, t=tt,
                             weights_are_int=jnp.issubdtype(
                                 wq.dtype, jnp.integer),
                             max_table_bytes=max_table_bytes)
        plan[path] = route
        # drop any stale annotation first — re-planning an annotated tree
        # must not leave a previous plan's "lut" leaf on an unpack layer
        layer = {k2: v for k2, v in layer.items() if k2 != "lut"}
        if route == "lut":
            layer["lut"] = lut_matmul.build_lut(wq) if build_tables else True
        return layer

    return map_folded_layers(folded, annotate), plan


def strip_lut_annotations(folded):
    """Remove every ``lut`` leaf from a folded tree (shallow copies only) —
    what ``route="unpack"`` uses to pin the mirrored-dot oracle route even
    on a tree a previous planner annotated."""
    return map_folded_layers(
        folded, lambda _, l: {k: v for k, v in l.items() if k != "lut"})


class InferenceSession:
    """Compiled, fixed-shape Spikformer classifier over a chosen backend."""

    def __init__(self, params, cfg: SpikformerConfig, *, backend="packed",
                 batch_size: int = 8, folded: bool = False,
                 weight_dtype: str | None = None,
                 pallas: bool | None = None, jit: bool = True,
                 route: str = "auto"):
        """``params`` is a training param tree (BN folded here) unless
        ``folded=True``, in which case it is already a fold_inference_params
        tree (possibly pre-quantized). ``batch_size`` is the static compile
        shape.

        ``weight_dtype="int8"`` quantizes the folded kernels per-out-channel
        to int8 (``infer.quant``); the dequantization scale is folded into
        each layer's LIF threshold, so the packed matmuls stay integer.
        "float32" keeps the BN-folded floats (the exactness reference for
        the float route; with int8, the "reference" backend is the bit-exact
        float *emulation* of the same quantized math). The default ``None``
        means "whatever the tree carries": float32 for a fresh fold, int8
        for a pre-quantized tree.

        ``route="auto"`` runs the per-layer planner (``plan_routes``): layers
        where the unpack-free byte-LUT datapath wins get a cached table;
        ``route="unpack"`` pins every layer to the mirrored-dot oracle
        route. Parity pairs must be built with the same ``route`` argument —
        the plan is part of the math."""
        if weight_dtype is not None and weight_dtype not in WEIGHT_DTYPES:
            raise ValueError(f"unknown weight_dtype {weight_dtype!r}; "
                             f"expected one of {WEIGHT_DTYPES}")
        if route not in ("auto", "unpack"):
            raise ValueError(f"unknown route {route!r}; "
                             "expected 'auto' or 'unpack'")
        self.cfg = cfg
        self.batch_size = int(batch_size)
        self.backend = get_backend(backend, pallas=pallas)
        self.folded = params if folded else fold_inference_params(params, cfg)
        already_quantized = "scale" in self.folded["scs"]["conv0"]
        if weight_dtype == "float32" and already_quantized:
            raise ValueError(
                "weight_dtype='float32' requested but the folded tree is "
                "already int8-quantized; pass the float tree or drop the "
                "weight_dtype argument")
        if weight_dtype == "int8" and not already_quantized:
            self.folded = quantize_folded(self.folded)
        self.weight_dtype = ("int8" if weight_dtype == "int8"
                             or already_quantized else "float32")
        if route == "auto":
            self.folded, self.plan = plan_routes(
                self.folded, cfg, batch_size=self.batch_size,
                build_tables=getattr(self.backend, "wants_lut_tables", True))
        else:
            # the pin must hold even for a pre-annotated folded tree: stale
            # "lut" leaves would silently keep the LUT route alive
            self.folded = strip_lut_annotations(self.folded)
            self.plan = {}

        def fwd(folded_tree, images):
            return spikformer.forward_folded(folded_tree, images, cfg,
                                             backend=self.backend)

        self._fwd = jax.jit(fwd) if jit else fwd

    @property
    def input_shape(self):
        c = self.cfg
        return (self.batch_size, c.img_size, c.img_size, c.in_channels)

    def warmup(self):
        """Compile (and time) the fixed-shape step on zero images."""
        t0 = time.perf_counter()
        jax.block_until_ready(
            self._fwd(self.folded, jnp.zeros(self.input_shape, jnp.uint8)))
        return time.perf_counter() - t0

    def logits(self, images_u8):
        """images_u8: (N, H, W, C) uint8, any N >= 1 -> (N, classes) f32."""
        images_u8 = jnp.asarray(images_u8, jnp.uint8)
        n = images_u8.shape[0]
        bs = self.batch_size
        pad = (-n) % bs
        if pad:
            images_u8 = jnp.concatenate(
                [images_u8, jnp.zeros((pad, *images_u8.shape[1:]),
                                      jnp.uint8)], axis=0)
        outs = [self._fwd(self.folded, images_u8[i:i + bs])
                for i in range(0, n + pad, bs)]
        return jnp.concatenate(outs, axis=0)[:n]

    def classify(self, images_u8):
        """(N, H, W, C) uint8 -> (N,) int32 argmax class ids."""
        return jnp.argmax(self.logits(images_u8), axis=-1).astype(jnp.int32)

    def __call__(self, images_u8):
        return self.logits(images_u8)


def benchmark_session(sess: InferenceSession, *, batches: int = 4,
                      seed: int = 0, repeats: int = 3):
    """Throughput probe: images/sec over ``batches`` full compiled batches
    of random uint8 images (excludes compile via warmup). The window is
    repeated ``repeats`` times and the best wall-time wins — the standard
    throughput convention, and the only way to get a stable number on a
    noisy shared machine. Returns a dict."""
    compile_s = sess.warmup()
    imgs = jax.random.randint(jax.random.PRNGKey(seed), sess.input_shape,
                              0, 256, jnp.uint8)
    wall = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        for _ in range(batches):
            jax.block_until_ready(sess._fwd(sess.folded, imgs))
        wall = min(wall, time.perf_counter() - t0)
    n = batches * sess.batch_size
    return {
        "backend": sess.backend.name,
        "weight_dtype": sess.weight_dtype,
        "batch_size": sess.batch_size,
        "images": n,
        "repeats": repeats,
        "compile_s": round(compile_s, 3),
        "wall_s": round(wall, 4),
        "images_per_s": round(n / wall, 2),
    }
