"""InferenceSession: deprecation shim over the compile/serve split.

The session API grew bottom-up — ``__init__`` interleaved BN folding,
quantization, route planning, and jitting. That pipeline now lives in
``repro.infer.compile`` as named passes under an ``ExecutionPlan``, and
the serving loop in ``repro.infer.engine``. This class survives so
existing callers keep working:

    sess = InferenceSession(params, cfg, backend="packed", batch_size=8)
    # ==  (modulo a DeprecationWarning)
    model = compile(params, cfg, ExecutionPlan(backend="packed",
                                               batch_buckets=(8,)))

Every attribute of the old surface (``folded``, ``plan``, ``backend``,
``weight_dtype``, ``logits``/``classify``/``warmup``, the private
``_fwd``) delegates to the underlying ``CompiledModel``. New code should
call ``compile()`` directly — it gets multi-bucket steps and a
serializable plan; the shim is single-bucket by construction.

``plan_routes`` / ``strip_lut_annotations`` re-export the compile passes
under their historical names; ``benchmark_session`` times either a session
or a ``CompiledModel``.
"""
from __future__ import annotations

import time
import warnings

import jax
import jax.numpy as jnp

from .compile import (CompiledModel, ExecutionPlan, compile as _compile,
                      plan_route_tables, strip_lut_annotations)  # noqa: F401
from ..core.spikformer import SpikformerConfig


def plan_routes(folded, cfg: SpikformerConfig, *, batch_size: int,
                max_table_bytes=None, build_tables: bool = True):
    """Historical name of the route-planning pass; see
    ``compile.plan_route_tables`` (which also takes autotuned constants
    and pinned routes)."""
    kw = {} if max_table_bytes is None else \
        {"max_table_bytes": max_table_bytes}
    return plan_route_tables(folded, cfg, batch_size=batch_size,
                             build_tables=build_tables, **kw)


class InferenceSession:
    """Deprecated: compiled fixed-shape Spikformer classifier — now a thin
    shim over ``compile()`` with a single batch bucket."""

    def __init__(self, params, cfg: SpikformerConfig, *, backend="packed",
                 batch_size: int = 8, folded: bool = False,
                 weight_dtype: str | None = None,
                 pallas: bool | None = None, jit: bool = True,
                 route: str = "auto"):
        """Arguments keep their pre-split meanings: ``batch_size`` is the
        static compile shape (one bucket), ``weight_dtype`` as in
        ``compile.quantize_weights``, ``route="unpack"`` pins the
        mirrored-dot oracle route (``plan == {}``). Parity pairs must be
        built with the same ``route`` — the plan is part of the math."""
        warnings.warn(
            "InferenceSession is deprecated; use repro.infer.compile() "
            "with an ExecutionPlan (and repro.infer.engine for serving)",
            DeprecationWarning, stacklevel=2)
        options = {} if pallas is None else {"pallas": pallas}
        plan = ExecutionPlan(backend=backend, weight_dtype=weight_dtype,
                             batch_buckets=(int(batch_size),), route=route,
                             backend_options=options)
        self._compiled = _compile(params, cfg, plan, folded=folded, jit=jit)

    # -- the old surface, delegated -----------------------------------------

    @property
    def compiled(self) -> CompiledModel:
        """The underlying ``CompiledModel`` (the migration escape hatch)."""
        return self._compiled

    @property
    def cfg(self):
        return self._compiled.cfg

    @property
    def backend(self):
        return self._compiled.backend

    @property
    def folded(self):
        return self._compiled.folded

    @property
    def plan(self) -> dict:
        """The per-layer route dict (the resolved ``ExecutionPlan.routes``)."""
        return self._compiled.plan.routes

    @property
    def weight_dtype(self) -> str:
        return self._compiled.weight_dtype

    @property
    def batch_size(self) -> int:
        return self._compiled.batch_size

    @property
    def _fwd(self):
        return self._compiled._fwd

    @property
    def input_shape(self):
        return self._compiled.input_shape()

    def warmup(self):
        """Compile (and time) the fixed-shape step on zero images."""
        return self._compiled.warmup()

    def logits(self, images_u8):
        """images_u8: (N, H, W, C) uint8, any N >= 1 -> (N, classes) f32."""
        return self._compiled.logits(images_u8)

    def classify(self, images_u8):
        """(N, H, W, C) uint8 -> (N,) int32 argmax class ids."""
        return self._compiled.classify(images_u8)

    def __call__(self, images_u8):
        return self.logits(images_u8)


def benchmark_session(sess, *, batches: int = 4, seed: int = 0,
                      repeats: int = 3):
    """Throughput probe: images/sec over ``batches`` full compiled batches
    of random uint8 images (excludes compile via warmup). Accepts an
    ``InferenceSession`` or a ``CompiledModel`` (largest bucket is timed).
    The window is repeated ``repeats`` times and the best wall-time wins —
    the standard throughput convention, and the only way to get a stable
    number on a noisy shared machine. Returns a dict."""
    compile_s = sess.warmup()
    shape = sess.input_shape() if callable(getattr(sess, "input_shape")) \
        else sess.input_shape
    imgs = jax.random.randint(jax.random.PRNGKey(seed), shape,
                              0, 256, jnp.uint8)
    wall = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        for _ in range(batches):
            jax.block_until_ready(sess._fwd(sess.folded, imgs))
        wall = min(wall, time.perf_counter() - t0)
    n = batches * sess.batch_size
    return {
        "backend": sess.backend.name,
        "weight_dtype": sess.weight_dtype,
        "batch_size": sess.batch_size,
        "images": n,
        "repeats": repeats,
        "compile_s": round(compile_s, 3),
        "wall_s": round(wall, 4),
        "images_per_s": round(n / wall, 2),
    }
