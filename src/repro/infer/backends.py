"""Execution backends for the BN-folded Spikformer inference graph.

``core.spikformer.forward_folded`` drives the layer sequence; a backend
decides how activations are represented and which kernels execute each of the
four unified dataflows:

  FloatBackend  — spikes are {0,1} float32 tensors with an explicit leading T
                  axis, every op runs through ``core.unified`` (the training
                  reference). Activation shapes: (T, B, H, W, C) / (T, B, N, D).
  PackedBackend — spikes are packed uint8 *plane groups*: a leading axis of
                  G = ceil(T/8) bytes per neuron, bit j of group g = timestep
                  8g+j, dispatched through the batched packed entry points in
                  ``kernels.ops`` (Pallas on TPU, the mirrored-reshape CPU
                  oracle elsewhere). Activation shapes: (G, B, H, W, C) /
                  (G, B, N, D) uint8 — 8x (x 32/T) less inter-layer traffic,
                  the paper's Small-Input/Output-SRAM packing, for ANY T.

Every ``*_lif`` method takes an optional per-output-channel ``scale`` leaf
(present when the folded tree was quantized by ``infer.quant``): the kernel
is then int8 and the scale is folded into the LIF bias/threshold instead of
the accumulator (see ``infer.quant`` for the math). FloatBackend applies the
identical scale-folded ops to the dequantized-integer float graph, making it
the bit-exact *emulation oracle* for the packed int8 route.

Each matmul method also takes an optional ``lut`` leaf — the byte-LUT table
the session planner cached for that layer (``kernels.lut_matmul``). When
present, PackedBackend runs the unpack-free gather route and FloatBackend
runs the *fold-order emulation* of the same reduction tree
(``lut_matmul_planes``) instead of its single dot: float32 sums are not
reorderable, so the reference follows the route plan exactly as it already
follows the int8 threshold fold. Both sessions of a parity pair plan the
same routes from the same static shapes, which keeps end-to-end logits
bit-identical.

The CPU route of PackedBackend performs operation-for-operation the same
float32 arithmetic as FloatBackend (same reshapes, same dots or the same
gather/fold tree, same reduction orders), so their logits are bit-identical
— spikes are binary, there is no tolerance to hide behind, and the parity
tests assert exact equality. The Pallas LUT route keeps the same contract:
its gather kernel replays lut_matmul's defined ascending-chunk fold with
one-hot-matmul row selects (exact — 255 of 256 products are exact zeros),
so table-planned sessions are bit-identical across ALL of {reference,
packed CPU, packed Pallas}; only the Pallas unpack-dot route on float32
weights relaxes to reduction-order tolerance (pin "lut" routes there).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from . import registry
from ..core import unified
from ..core.lif import V_TH, tflif
from ..core.spike import (bitplanes_u8, packed_occupancy, rate_decode,
                          space_to_depth)
from ..kernels import ops
from ..kernels import lut_matmul as lut


# ---------------------------------------------------------------------------
# Packed-popcount occupancy readouts. These live next to PackedBackend.rate
# (the popcount classification readout) because they are the same trick
# pointed at telemetry: sparsity statistics read straight off the packed
# bytes, no unpacking. All three return plain python floats — they are
# calibration/telemetry utilities, not jittable graph ops.
# ---------------------------------------------------------------------------

def spike_occupancy(x_packed, t: int) -> float:
    """Firing rate of a packed spike tensor: fraction of set bits over the
    ``t`` live planes. One implementation — ``core.spike.packed_occupancy``
    — shared with the event front end's per-window readout, so the number
    a DVS window reports at ingestion is the number serving calibrates
    with."""
    return packed_occupancy(x_packed, t)


def chunk_occupancy(x_packed, t: int) -> float:
    """CHUNK occupancy of a packed spike tensor: the fraction of nonzero
    per-plane chunk-index bytes — exactly the quantity the zero-chunk-
    skipping gather scales with (a zero byte = one skippable 8-row gather),
    and what ``choose_route``/``sparse_budget`` take as ``occupancy``."""
    idx = lut.plane_indices(x_packed)[:t]
    return float(jnp.mean((idx != 0).astype(jnp.float32)))


def value_chunk_occupancy(x_u8) -> float:
    """Chunk occupancy of uint8 *value* bytes (the SSSC operand): the
    8 bit-planes of the values are the LUT index source directly."""
    return chunk_occupancy(x_u8[None], 8)


class FloatBackend:
    """Reference backend: float spike trains through ``core.unified``."""

    name = "reference"
    # route planning reads this: the reference only needs the "lut" leaf as
    # a *flag* to switch to the fold-order emulation — caching the (C,256,N)
    # tables into its tree would be dead weight
    wants_lut_tables = False

    @staticmethod
    def _acc_and_vth(op, x, kernel, bias, scale):
        """Pre-LIF accumulator and firing threshold for ``op(x, k, b)``.
        int8 layers (``scale`` given) fold the per-channel scale into the
        bias/threshold — the float emulation of exactly the packed int8
        math."""
        if scale is None:
            return op(x, kernel, bias), V_TH
        acc = op(x, kernel.astype(jnp.float32), None) + (bias / scale)
        return acc, V_TH / scale

    # -- fold-order emulations of the byte-LUT route (plan says "lut") ------
    # Same signatures as the ``core.unified`` ops they stand in for; the
    # arithmetic replays lut_matmul's defined reduction tree on float planes.

    @staticmethod
    def _wssl_emu(spikes, kernel, bias=None):
        t, lead, d = spikes.shape[0], spikes.shape[1:-1], spikes.shape[-1]
        planes = spikes.reshape(t, -1, d).astype(jnp.float32)
        y = lut.lut_matmul_planes(planes, kernel)       # (t, M, N)
        if bias is not None:
            y = y + bias.astype(y.dtype)
        return y.reshape((t, *lead, kernel.shape[-1]))

    @classmethod
    def _zsc_emu(cls, spikes, kernel, bias=None):
        return cls._wssl_emu(space_to_depth(spikes, 2),
                             kernel.reshape(-1, kernel.shape[-1]), bias)

    @staticmethod
    def _sssc_emu(image_u8, kernel, bias=None):
        x = space_to_depth(image_u8, 2)                 # (B, h, w, 4C) u8
        lead = x.shape[:-1]
        planes = bitplanes_u8(x).reshape(8, -1, x.shape[-1])
        per = lut.lut_matmul_planes(planes, kernel)     # (8, M, N)
        y = lut.shift_sum_fold(per)
        if bias is not None:
            y = y + bias.astype(y.dtype)
        return y.reshape((*lead, kernel.shape[-1]))

    # ``occupancy`` (the sparse-route calibration) is accepted and IGNORED:
    # the zero-chunk-skipping gather only drops exact-zero identity entries
    # from the fold, so its bit-exact float emulation is the same
    # ``lut_matmul_planes`` replay the dense LUT route already uses.

    def sssc_lif(self, images_u8, kernel, bias, *, t: int, scale=None,
                 lut=None, occupancy=None):
        op = unified.sssc if lut is None else self._sssc_emu
        y, vth = self._acc_and_vth(op, images_u8, kernel, bias,
                                   scale)                # (B, H/2, W/2, F)
        y = jnp.broadcast_to(y[None], (t, *y.shape))    # image constant in T
        return tflif(y, v_th=vth)

    def zsc_lif(self, x, kernel, bias, *, t: int, scale=None, lut=None,
                occupancy=None):
        op = unified.zsc if lut is None else self._zsc_emu
        y, vth = self._acc_and_vth(op, x, kernel, bias, scale)
        return tflif(y, v_th=vth)

    def wssl_lif(self, x, kernel, bias, *, t: int, scale=None, lut=None,
                 occupancy=None):
        op = unified.wssl if lut is None else self._wssl_emu
        y, vth = self._acc_and_vth(op, x, kernel, bias, scale)
        return tflif(y, v_th=vth)

    def stdp_lif(self, q, k, v, *, heads: int, scale: float, t: int):
        tt, b, n, d = q.shape
        dh = d // heads

        def to_heads(z):
            return z.reshape(tt, b, n, heads, dh).transpose(0, 1, 3, 2, 4)

        att = unified.stdp(to_heads(q), to_heads(k), to_heads(v), scale=scale)
        att = tflif(att)                                # (T, B, H, N, dh)
        return att.transpose(0, 1, 3, 2, 4).reshape(tt, b, n, d)

    def residual(self, new, res, mode: str):
        if mode == "iand":
            return (1.0 - new) * res
        return new + res

    def to_tokens(self, x):
        tt, b, h, w, c = x.shape
        return x.reshape(tt, b, h * w, c)

    def rate(self, x, *, t: int):
        return rate_decode(x, axis=0).mean(axis=1)      # (B, D)


class PackedBackend:
    """Hardware-shaped backend: packed uint8 plane groups through
    ``kernels.ops``.

    ``pallas=None`` auto-selects (Pallas on TPU, CPU oracle otherwise);
    pass True/False to force either route.
    """

    name = "packed"

    # Route planning reads this: BOTH branches now consume the (C,256,N)
    # tables — the CPU gather route directly, the Pallas branch through the
    # VMEM-resident byte-LUT gather kernel (``lut_matmul_pallas``) and the
    # fused pack->TFLIF->matmul kernel. A session planned without tables
    # still runs: the Pallas route falls back to the grouped unpack-dot
    # kernel (bit-exact only for integer weights).
    wants_lut_tables = True

    def __init__(self, *, pallas: bool | None = None,
                 fuse_mlp: bool = True):
        self.pallas = pallas
        # fuse the MLP fc1 -> LIF -> fc2 step into one Pallas kernel when
        # possible (see ``mlp_pair_lif``); only consulted on the Pallas
        # branch — the CPU oracle always runs the two-layer composition
        self.fuse_mlp = fuse_mlp

    def _lif(self, acc, bias, scale):
        """acc (T, ...) -> (G, ...) packed; int8 layers fold their
        per-channel scale into the bias/threshold, never the accumulator."""
        if scale is None:
            return ops.tflif_pack(acc, bias, pallas=self.pallas)
        return ops.tflif_pack(acc, bias / scale, v_th=V_TH / scale,
                              pallas=self.pallas)

    @staticmethod
    def _w(kernel, scale):
        """How an int8 kernel enters the packed matmul (single spot)."""
        return kernel if scale is None else kernel.astype(jnp.float32)

    # ``occupancy`` is the plan's static per-layer chunk-occupancy
    # calibration (present only for "lut_sparse"-routed layers); the ops
    # layer derives the zero-chunk-skipping gather budget from it.

    def sssc_lif(self, images_u8, kernel, bias, *, t: int, scale=None,
                 lut=None, occupancy=None):
        x = space_to_depth(images_u8, 2)                # (B,H/2,W/2,4C) u8
        acc = ops.sssc_linear(x, self._w(kernel, scale), None,
                              pallas=self.pallas, table=lut,
                              occupancy=occupancy)
        acc = jnp.broadcast_to(acc[None], (t, *acc.shape))
        return self._lif(acc, bias, scale)              # (G,B,H/2,W/2,F) u8

    def zsc_lif(self, x, kernel, bias, *, t: int, scale=None, lut=None,
                occupancy=None):
        acc = ops.spike_linear(space_to_depth(x, 2), self._w(kernel, scale),
                               None, t=t, pallas=self.pallas, table=lut,
                               occupancy=occupancy)
        return self._lif(acc, bias, scale)

    def wssl_lif(self, x, kernel, bias, *, t: int, scale=None, lut=None,
                 occupancy=None):
        acc = ops.spike_linear(x, self._w(kernel, scale), None, t=t,
                               pallas=self.pallas, table=lut,
                               occupancy=occupancy)
        return self._lif(acc, bias, scale)

    def mlp_pair_lif(self, x, fc1, fc2, *, t: int, occupancy=None):
        """Fused MLP pair: fc1 matmul -> (LIF + pack + fc2 byte-LUT gather
        in ONE Pallas kernel) -> fc2 LIF. The unpacked fc1 spike tensor
        never reaches HBM (``kernels.fused``); the emitted logits are
        bit-identical to the two-layer path, so ``forward_folded`` may take
        either.

        Returns None when the fused kernel does not apply — CPU-oracle
        sessions, ``fuse_mlp=False``, or no (C,256,N) table planned for fc2
        — and the caller falls back to the unfused two-layer composition.
        ``occupancy`` is fc1's input calibration, forwarded to its matmul.
        """
        if not (self.fuse_mlp and ops.use_pallas(self.pallas)):
            return None
        tbl2 = fc2.get("lut")
        if not ops._have_table(tbl2):
            return None
        scale1 = fc1.get("scale")
        acc1 = ops.spike_linear(x, self._w(fc1["kernel"], scale1), None,
                                t=t, pallas=self.pallas,
                                table=fc1.get("lut"), occupancy=occupancy)
        # fc1's int8 scale folds into its LIF bias/threshold exactly as in
        # ``_lif`` — the fused kernel sees the same charge/compare operands
        b1 = fc1["bias"] if scale1 is None else fc1["bias"] / scale1
        v1 = V_TH if scale1 is None else V_TH / scale1
        _s1, acc2 = ops.tflif_lut(acc1, b1, table=tbl2, v_th=v1, t=t,
                                  pallas=self.pallas)
        return self._lif(acc2, fc2["bias"], fc2.get("scale"))

    def stdp_lif(self, q, k, v, *, heads: int, scale: float, t: int):
        g, b, n, d = q.shape
        dh = d // heads

        def to_heads(z):
            return z.reshape(g, b, n, heads, dh).transpose(0, 1, 3, 2, 4)

        # route="auto": the LUT score path engages at large token counts
        # (bit-identical either way — binary q/k/v keep every accumulator an
        # exact integer, so no reference-side emulation is needed)
        acc = ops.stdp_attention_packed(
            to_heads(q), to_heads(k), to_heads(v), t=t, scale=scale,
            pallas=self.pallas, route="auto")           # (t, B, H, N, dh)
        att = ops.tflif_pack(acc, pallas=self.pallas)   # (G, B, H, N, dh) u8
        return att.transpose(0, 1, 3, 2, 4).reshape(g, b, n, d)

    def residual(self, new, res, mode: str):
        if mode != "iand":
            raise ValueError(
                "packed activations are strictly binary; residual mode "
                f"{mode!r} requires the float reference backend")
        # SEW IAND on packed bytes, all plane groups at once: (NOT new) AND
        # res. Bits >= T in the last group are 0 in `res`, so the
        # complement's high bits are masked off for free.
        return jnp.bitwise_and(jnp.bitwise_not(new), res)

    def to_tokens(self, x):
        g, b, h, w, c = x.shape
        return x.reshape(g, b, h * w, c)

    def rate(self, x, *, t: int):
        # popcount readout: sum of bits per neuron without unpacking. The
        # count is an exact integer (any summation order), and the /t
        # mirrors rate_decode's mean division, so this matches the float
        # reference bit for bit.
        counts = lax.population_count(x).astype(jnp.int32).sum(axis=0)
        rate = counts.astype(jnp.float32) / jnp.float32(t)
        return rate.mean(axis=1)


class OccupancyRecorder(PackedBackend):
    """A ``PackedBackend`` that records the chunk occupancy of every linear
    layer's packed matmul operand, in forward call order.

    ``infer.compile.calibrate_layer_occupancy`` runs one UN-JITTED forward
    through this backend (each readout concretizes to a python float, which
    a trace cannot do) and zips ``trace`` with the layer paths in the same
    deterministic order ``forward_folded`` visits them. The measured
    quantity is exactly what ``choose_route``/``sparse_budget`` consume:
    the fraction of nonzero chunk-index bytes the gather would visit.
    """

    def __init__(self):
        super().__init__(pallas=False)
        self.trace: list[float] = []

    def sssc_lif(self, images_u8, kernel, bias, *, t: int, scale=None,
                 lut=None, occupancy=None):
        self.trace.append(value_chunk_occupancy(space_to_depth(images_u8, 2)))
        return super().sssc_lif(images_u8, kernel, bias, t=t, scale=scale,
                                lut=lut)

    def zsc_lif(self, x, kernel, bias, *, t: int, scale=None, lut=None,
                occupancy=None):
        self.trace.append(chunk_occupancy(space_to_depth(x, 2), t))
        return super().zsc_lif(x, kernel, bias, t=t, scale=scale, lut=lut)

    def wssl_lif(self, x, kernel, bias, *, t: int, scale=None, lut=None,
                 occupancy=None):
        self.trace.append(chunk_occupancy(x, t))
        return super().wssl_lif(x, kernel, bias, t=t, scale=scale, lut=lut)


# ---------------------------------------------------------------------------
# Registration: the built-in backends enter the registry here; ``get_backend``
# is now a registry lookup (kept importable from this module for callers of
# the pre-registry API).
# ---------------------------------------------------------------------------

# keyword-only factories: a misspelled option key must raise TypeError,
# not silently run the default route. Every factory accepts + ignores
# ``interpret`` — it is the registry's device-gate escape hatch (see
# ``registry.get_backend``), consumed there, but also forwarded here so a
# pre-resolved options dict round-trips.
registry.register_backend(
    "packed",
    lambda *, pallas=None, fuse_mlp=True, interpret=None:
        PackedBackend(pallas=pallas, fuse_mlp=fuse_mlp),
    weight_dtypes=("float32", "int8"),
    device_kinds=("cpu", "tpu"),
    wants_lut_tables=True,      # both branches gather from planned tables
    overwrite=True)             # survive importlib.reload of this module

registry.register_backend(
    "reference",
    lambda *, pallas=None, interpret=None: FloatBackend(),
    weight_dtypes=("float32", "int8"),
    device_kinds=("cpu", "gpu", "tpu"),
    wants_lut_tables=False,     # plan flags only, never (C,256,N) tables
    aliases=("float",),
    overwrite=True)

# The Pallas-pinned packed backend: the registration path the registry
# docstring promises, as a real registration. Same PackedBackend class,
# pallas=True forced — the real kernels on TPU, interpret mode elsewhere
# (the registry's device gate makes off-TPU use an explicit
# ``backend_options={'interpret': True}`` opt-in). Route planning DOES
# build (C,256,N) tables for it: the Pallas byte-LUT gather kernel and the
# fused MLP kernel consume them from VMEM.
def _packed_pallas_factory(*, pallas=True, fuse_mlp=True, interpret=None):
    if pallas is not True:
        # this registration *is* the Pallas pin; a pallas=False instance
        # here would belie every capability the spec declares — reject at
        # the door, don't quietly run the CPU route under the wrong name
        raise ValueError("packed_pallas pins pallas=True; for the CPU "
                         "route use backend='packed' (optionally with "
                         "backend_options={'pallas': False})")
    return PackedBackend(pallas=True, fuse_mlp=fuse_mlp)


registry.register_backend(
    "packed_pallas",
    _packed_pallas_factory,
    weight_dtypes=("float32", "int8"),
    device_kinds=("tpu",),
    wants_lut_tables=True,
    aliases=("pallas",),
    overwrite=True)             # survive importlib.reload of this module

get_backend = registry.get_backend
