"""Compile/serve split: ``compile(params, cfg, plan) -> CompiledModel``.

Everything decided *before the first batch* lives in an ``ExecutionPlan`` —
backend, weight dtype, the static batch buckets the step is compiled for,
the byte-LUT table budget, and the ``choose_route`` cost constants (host
properties, autotunable). Compilation is then an explicit pass pipeline
over the folded tree:

    fold_bn  ->  quantize_weights  ->  plan_route_tables  ->  lower

each pass a named function, so tests and the autotuner can run them in
isolation. The result is a ``CompiledModel``: a jit-compiled fixed-shape
step per batch bucket plus the resolved plan (per-layer routes filled in),
which ``to_json``/``from_json`` turn into a committable artifact — serving
a model under a reviewed plan replays exactly the route decisions the plan
records, never a fresh heuristic call.

    from repro.infer import ExecutionPlan, compile
    plan = ExecutionPlan(backend="packed", weight_dtype="int8",
                         batch_buckets=(2, 8))
    model = compile(params, cfg, plan)
    logits = model.logits(images_u8)          # any N; bucketed + padded
    pathlib.Path("plan.json").write_text(model.plan.to_json())

The serving loop over a ``CompiledModel`` is ``repro.infer.engine``;
``replicate_model`` places copies of one for the multi-replica fleet.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from . import backends as _backends  # noqa: F401  (registers built-ins)
from . import registry
from .quant import WEIGHT_DTYPES, map_folded_layers, quantize_folded
from ..core import spikformer
from ..core.spikformer import SpikformerConfig, fold_inference_params
from ..kernels import lut_matmul
from ..kernels.lut_matmul import RouteConstants
from ..kernels.ops import choose_pallas_route, choose_route, use_pallas

ROUTES = ("auto", "unpack", "lut")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Everything decided before the first batch, as one committable value.

    ``batch_buckets`` are the static shapes the step compiles for; the
    engine picks the smallest bucket covering its backlog, so low-occupancy
    traffic stops padding to the full batch. Route planning runs once at
    the LARGEST bucket and every bucket shares the annotated tree — the
    per-image math is row-independent, which is what keeps logits identical
    across buckets (the multi-bucket parity contract).

    ``routes`` is the resolved per-layer plan (path -> "lut" |
    "lut_sparse" | "unpack"). ``None`` means "decide at compile time via
    ``route_constants``"; a non-None mapping PINS the decisions — that is
    what a deserialized plan carries, so a committed plan is replayed, not
    re-derived.

    ``layer_occupancy`` maps layer paths to calibrated chunk-occupancy
    floats (fraction of nonzero chunk-index bytes at that layer's input,
    from ``calibrate_layer_occupancy``). It is what lets ``choose_route``
    consider the sparse gather route, and what sizes the static gather
    budget at lowering time — sparsity claims are measured and committed
    with the plan, never assumed.
    """
    backend: str = "packed"
    weight_dtype: str | None = None     # None: whatever the tree carries
    batch_buckets: tuple[int, ...] = (8,)
    max_table_bytes: int = lut_matmul.MAX_TABLE_BYTES
    route: str = "auto"                 # "auto" | "unpack" | "lut"
    route_constants: RouteConstants = dataclasses.field(
        default_factory=RouteConstants)
    routes: dict | None = None          # resolved: layer path -> route
    layer_occupancy: dict | None = None  # path -> calibrated chunk occupancy
    backend_options: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.route not in ROUTES:
            raise ValueError(f"unknown route {self.route!r}; "
                             f"expected one of {ROUTES}")
        if (self.weight_dtype is not None
                and self.weight_dtype not in WEIGHT_DTYPES):
            raise ValueError(f"unknown weight_dtype {self.weight_dtype!r}; "
                             f"expected one of {WEIGHT_DTYPES}")
        buckets = tuple(sorted({int(b) for b in self.batch_buckets}))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"batch_buckets must be >= 1, got "
                             f"{self.batch_buckets!r}")
        object.__setattr__(self, "batch_buckets", buckets)
        if isinstance(self.route_constants, dict):
            object.__setattr__(self, "route_constants",
                               RouteConstants.from_dict(self.route_constants))
        if self.layer_occupancy is not None:
            occ = {}
            for path, o in self.layer_occupancy.items():
                o = float(o)
                if not 0.0 <= o <= 1.0:
                    raise ValueError(
                        f"layer_occupancy[{path!r}] = {o!r}; occupancy is a "
                        "fraction of nonzero chunk bytes in [0, 1]")
                occ[str(path)] = o
            object.__setattr__(self, "layer_occupancy", occ)

    @property
    def plan_batch(self) -> int:
        """The bucket route planning keys its (M, K, N, G) shapes on."""
        return self.batch_buckets[-1]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["batch_buckets"] = list(self.batch_buckets)
        return d

    def to_json(self, *, indent: int | None = 1) -> str:
        if not isinstance(self.backend, str):
            raise TypeError("plans holding a backend *instance* are not "
                            "serializable; register it and use the name")
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "ExecutionPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        bad = set(d) - known
        if bad:
            raise ValueError(f"unknown ExecutionPlan keys {sorted(bad)}; "
                             f"expected a subset of {sorted(known)}")
        d = dict(d)
        if "batch_buckets" in d:
            d["batch_buckets"] = tuple(d["batch_buckets"])
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "ExecutionPlan":
        """Accepts a full plan or any fragment of one (autotune emits just
        ``{"route_constants": ...}``); missing fields keep their defaults."""
        return cls.from_dict(json.loads(text))


# ---------------------------------------------------------------------------
# The pass pipeline. Each pass is a named function over the folded tree so
# tests and the autotuner can run them in isolation.
# ---------------------------------------------------------------------------

def fold_bn(params, cfg: SpikformerConfig, *, folded: bool = False):
    """Pass 1 — BN folding: training params -> inference tree of
    {kernel, bias} layers (``core.spikformer.fold_inference_params``).
    ``folded=True`` passes a pre-folded (possibly pre-quantized) tree
    through untouched."""
    return params if folded else fold_inference_params(params, cfg)


def quantize_weights(tree, weight_dtype: str | None):
    """Pass 2 — weight quantization. Returns ``(tree, resolved_dtype)``.

    ``None`` resolves to whatever the tree carries (int8 for a
    pre-quantized tree, float32 for a fresh fold); an explicit "float32"
    on an already-quantized tree fails loudly rather than silently running
    int8."""
    if weight_dtype is not None and weight_dtype not in WEIGHT_DTYPES:
        raise ValueError(f"unknown weight_dtype {weight_dtype!r}; "
                         f"expected one of {WEIGHT_DTYPES}")
    already_quantized = "scale" in tree["scs"]["conv0"]
    if weight_dtype == "float32" and already_quantized:
        raise ValueError(
            "weight_dtype='float32' requested but the folded tree is "
            "already int8-quantized; pass the float tree or drop the "
            "weight_dtype argument")
    if weight_dtype == "int8" and not already_quantized:
        tree = quantize_folded(tree)
    resolved = ("int8" if weight_dtype == "int8" or already_quantized
                else "float32")
    return tree, resolved


def plan_route_tables(folded, cfg: SpikformerConfig, *, batch_size: int,
                      max_table_bytes: int = lut_matmul.MAX_TABLE_BYTES,
                      build_tables: bool = True,
                      constants: RouteConstants | None = None,
                      routes: dict | None = None,
                      layer_occupancy: dict | None = None,
                      force: str | None = None,
                      pallas: bool = False):
    """Pass 3 — per-layer matmul route planning: the byte-LUT's precompute.

    For every folded layer this computes the packed-route matmul shape
    (M, K, N, G) the compiled step will see at ``batch_size`` and decides
    between the unpack-free byte-LUT datapath and the unpack-then-dot
    oracle — via ``kernels.ops.choose_route`` under ``constants`` when
    ``routes`` is None, or by REPLAYING a pinned ``routes`` mapping (what a
    deserialized plan carries). Where the LUT wins, the (C, 256, N)
    chunk-partial-sum table is built ONCE and cached in the returned tree
    as a ``lut`` leaf, so the per-batch work is pure gather-and-accumulate.

    Both backends of a parity pair consume trees annotated by the same
    deterministic plan: the packed backend executes the gather route, the
    float reference the fold-order emulation — the planning decision, like
    the int8 threshold fold, is part of the math both sides agree on. The
    reference side never gathers, so ``build_tables=False`` (what
    ``compile()`` uses for backends whose capability says no tables)
    annotates LUT layers with a cheap boolean flag instead.

    ``layer_occupancy`` (path -> calibrated chunk occupancy) lets
    ``choose_route`` weigh the zero-chunk-skipping gather route; a layer
    with no calibrated value never routes "lut_sparse" — the sparse budget
    is sized from the measurement, so an unmeasured layer has nothing to
    size it with. The same rule holds for pinned plans: replaying a
    "lut_sparse" pin without the occupancy that produced it is an error,
    not a silent densification.

    ``pallas=True`` plans for the Pallas kernel branch: the heuristic is
    ``choose_pallas_route`` (the one-hot-gather vs in-register-dot cost
    model with its own constants) and its "lut" tables feed the VMEM
    gather kernel. ``force`` (what ``plan.route == "lut"`` sets) pins that
    route on EVERY layer instead of consulting the heuristic — the
    bit-exactness pin for float32 weights on the Pallas branch, where the
    unpack-dot kernel is reduction-order-tolerant. Pinned ``routes``
    always win over both (a committed plan replays verbatim).

    Returns ``(annotated_tree, plan)`` with ``plan`` mapping layer paths
    to routes.
    """
    t = cfg.timesteps
    g = -(-t // 8)
    m_tok = batch_size * cfg.tokens
    plan = {}
    occ_map = layer_occupancy or {}
    choose = choose_pallas_route if pallas else choose_route

    def shapes_for(path):
        """Packed-route matmul shape (m, live planes, groups) at ``path``."""
        if path.startswith("scs/conv"):
            i = int(path.removeprefix("scs/conv"))
            m = batch_size * (cfg.img_size // 2 ** (i + 1)) ** 2
            # conv0 is SSSC: always 8 value planes, one group
            return (m, 8, 1) if i == 0 else (m, t, g)
        return m_tok, t, g

    def annotate(path, layer):
        wq = layer["kernel"]
        if routes is None:
            m, tt, gg = shapes_for(path)
            k, n = wq.shape
            is_int = jnp.issubdtype(wq.dtype, jnp.integer)
            route = force or choose(m=m, k=k, n=n, g=gg, t=tt,
                                    weights_are_int=is_int,
                                    max_table_bytes=max_table_bytes,
                                    constants=constants,
                                    occupancy=occ_map.get(path))
        else:
            try:
                route = routes[path]
            except KeyError:
                raise ValueError(
                    f"pinned route plan has no entry for layer {path!r} — "
                    "the plan was built for a different config") from None
            if route not in ("lut", "lut_sparse", "unpack"):
                raise ValueError(f"pinned route {route!r} for {path!r}; "
                                 "expected 'lut', 'lut_sparse' or 'unpack'")
        if route == "lut_sparse" and occ_map.get(path) is None:
            raise ValueError(
                f"route 'lut_sparse' for {path!r} requires a calibrated "
                "occupancy in the plan's layer_occupancy — the static "
                "gather budget is sized from it")
        plan[path] = route
        # drop any stale annotation first — re-planning an annotated tree
        # must not leave a previous plan's "lut" leaf on an unpack layer
        layer = {k2: v for k2, v in layer.items() if k2 != "lut"}
        if route in ("lut", "lut_sparse"):
            layer["lut"] = (lut_matmul.build_lut(wq) if build_tables
                            else True)
        return layer

    return map_folded_layers(folded, annotate), plan


def strip_lut_annotations(folded):
    """Remove every ``lut`` leaf from a folded tree (shallow copies only) —
    what ``route="unpack"`` uses to pin the mirrored-dot oracle route even
    on a tree a previous planner annotated."""
    return map_folded_layers(
        folded, lambda _, l: {k: v for k, v in l.items() if k != "lut"})


def linear_layer_paths(cfg: SpikformerConfig) -> list:
    """Layer paths in FORWARD-CALL order — the order a single
    ``forward_folded`` pass hits each spiking linear, which is the order
    ``backends.OccupancyRecorder`` appends its trace in. (``map_folded_layers``
    walks the same paths but in tree order; calibration needs call order.)"""
    paths = [f"scs/conv{i}" for i in range(len(cfg.scs_channels))]
    for i in range(cfg.depth):
        paths += [f"blocks/b{i}/ssa/{w}" for w in ("wq", "wk", "wv", "wo")]
        paths += [f"blocks/b{i}/mlp/fc1", f"blocks/b{i}/mlp/fc2"]
    return paths


def calibrate_layer_occupancy(params, cfg: SpikformerConfig, images_u8, *,
                              folded: bool = False,
                              weight_dtype: str | None = None) -> dict:
    """Measure per-layer chunk occupancy on a calibration batch.

    Runs ONE un-jitted forward through ``backends.OccupancyRecorder`` (a
    packed backend that notes, before each spiking linear, the fraction of
    nonzero chunk-index bytes in its input) and zips the trace with
    ``linear_layer_paths``. The result is the ``layer_occupancy`` mapping
    an ``ExecutionPlan`` commits — measured on real data, JSON-serializable,
    replayable.

    The calibration forward runs the plain dense routes (the recorder
    delegates without occupancy), so calibration never depends on the
    decisions it is about to inform.
    """
    tree = fold_bn(params, cfg, folded=folded)
    tree, _ = quantize_weights(tree, weight_dtype)
    recorder = _backends.OccupancyRecorder()
    fwd = lower(tree, cfg, recorder, jit=False)
    fwd(tree, jnp.asarray(images_u8, jnp.uint8))
    paths = linear_layer_paths(cfg)
    if len(recorder.trace) != len(paths):
        raise RuntimeError(
            f"occupancy trace has {len(recorder.trace)} entries but the "
            f"config has {len(paths)} spiking linears — recorder and "
            "forward_folded disagree about the layer sequence")
    return dict(zip(paths, recorder.trace))


def profile_layer_paths(cfg: SpikformerConfig) -> list:
    """Every timed op of one profiled forward pass, in call order: the
    spiking linears (``linear_layer_paths``) interleaved with each block's
    STDP attention (``blocks/b{i}/ssa/stdp``) exactly where
    ``forward_folded`` calls it. The two-layer MLP path is assumed — a
    profiling backend never exposes ``mlp_pair_lif``, so the op sequence
    is deterministic regardless of the serving backend's fusion."""
    paths = [f"scs/conv{i}" for i in range(len(cfg.scs_channels))]
    for i in range(cfg.depth):
        paths += [f"blocks/b{i}/ssa/{w}" for w in ("wq", "wk", "wv")]
        paths += [f"blocks/b{i}/ssa/stdp"]
        paths += [f"blocks/b{i}/ssa/wo"]
        paths += [f"blocks/b{i}/mlp/fc1", f"blocks/b{i}/mlp/fc2"]
    return paths


class _LayerTimer:
    """A backend wrapper that times every dataflow layer sync-barriered:
    each op's output is ``block_until_ready`` before the clock stops, so
    a layer's wall time is its own, not its successor's dispatch queue.
    Appends ``(t0, t1)`` to ``trace`` in forward call order (the
    ``OccupancyRecorder`` idiom). Deliberately does NOT expose
    ``mlp_pair_lif``: the two-layer MLP composition runs, keeping the op
    sequence aligned with ``profile_layer_paths``. Bookkeeping ops
    (residual, to_tokens, rate) delegate untimed — they are reshapes and
    popcounts, not the PE-array work VESTA's area budget is about."""

    def __init__(self, inner, *, clock=time.perf_counter):
        self._inner = inner
        self._clock = clock
        self.trace: list[tuple] = []

    def _timed(self, fn, *args, **kw):
        t0 = self._clock()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        self.trace.append((t0, self._clock()))
        return out

    def sssc_lif(self, *args, **kw):
        return self._timed(self._inner.sssc_lif, *args, **kw)

    def zsc_lif(self, *args, **kw):
        return self._timed(self._inner.zsc_lif, *args, **kw)

    def wssl_lif(self, *args, **kw):
        return self._timed(self._inner.wssl_lif, *args, **kw)

    def stdp_lif(self, *args, **kw):
        return self._timed(self._inner.stdp_lif, *args, **kw)

    def residual(self, *args, **kw):
        return self._inner.residual(*args, **kw)

    def to_tokens(self, *args, **kw):
        return self._inner.to_tokens(*args, **kw)

    def rate(self, *args, **kw):
        return self._inner.rate(*args, **kw)


def lower(folded, cfg: SpikformerConfig, backend, *, jit: bool = True,
          layer_occupancy: dict | None = None):
    """Pass 4 — lowering: the annotated tree becomes one step callable
    (jitted unless ``jit=False``; each batch bucket compiles its own
    fixed-shape executable under it on first use / warmup).

    ``layer_occupancy`` (path -> static occupancy float, for layers routed
    "lut_sparse") is CLOSED OVER, not threaded through the traced tree —
    the sparse gather budget must be a trace-time constant, and the folded
    tree is a jit argument whose leaves become tracers."""
    def fwd(folded_tree, images):
        return spikformer.forward_folded(folded_tree, images, cfg,
                                         backend=backend,
                                         layer_occupancy=layer_occupancy)

    return jax.jit(fwd) if jit else fwd


# ---------------------------------------------------------------------------
# compile() and its result
# ---------------------------------------------------------------------------

def plan_chunks(n: int, buckets) -> list:
    """Split ``n`` rows into bucket-shaped steps, minimizing padded rows and
    then step count: whole largest buckets peel off first, the remainder is
    solved exactly over the bucket set (3 rows over buckets (2, 8) run 2+2
    with one pad row, not 3 padded to 8 — but 7 rows run one 8-bucket, not
    four 2-buckets, because the pad is the same and one dispatch beats
    four). Returns ``[(rows, bucket), ...]``.

    Module-level (not just the ``CompiledModel`` method) because the serve
    scheduler makes its wait-vs-dispatch decisions over the SAME split the
    model will execute — one implementation, no drift.
    """
    buckets = tuple(sorted({int(b) for b in buckets}))
    if not buckets or buckets[0] < 1:
        raise ValueError(f"buckets must be >= 1, got {buckets!r}")
    chunks = []
    bmax = buckets[-1]
    while n >= bmax:
        chunks.append((bmax, bmax))
        n -= bmax
    if n == 0:
        return chunks
    # exact DP on the remainder (< largest bucket): lexicographic
    # (padded rows, steps) minimum, reconstructed front-first
    best = {0: (0, 0, None)}            # rows left -> (pad, steps, b)
    for r in range(1, n + 1):
        best[r] = min((best[r - min(b, r)][0] + b - min(b, r),
                       best[r - min(b, r)][1] + 1, b)
                      for b in buckets)
    while n:
        b = best[n][2]
        chunks.append((min(b, n), b))
        n -= min(b, n)
    return chunks


class CompiledModel:
    """A Spikformer lowered under an ``ExecutionPlan``: one jit-compiled
    fixed-shape step per batch bucket over an annotated folded tree.

    ``plan`` is the RESOLVED plan — ``weight_dtype`` concretized and the
    per-layer ``routes`` filled in — so ``model.plan.to_json()`` is the
    committable artifact that replays this exact compilation.
    """

    def __init__(self, *, cfg, backend, folded, plan: ExecutionPlan, fwd,
                 jit: bool = True):
        self.cfg = cfg
        self.backend = backend
        self.folded = folded
        self.plan = plan
        self._fwd = fwd
        self.jit = jit       # how _fwd was lowered; replicate_model re-lowers
        self.buckets = plan.batch_buckets   # with the same choice

    # -- shapes -------------------------------------------------------------

    @property
    def batch_size(self) -> int:
        """The largest compiled bucket (the planning shape)."""
        return self.buckets[-1]

    @property
    def weight_dtype(self) -> str:
        return self.plan.weight_dtype

    def input_shape(self, bucket: int | None = None):
        c = self.cfg
        b = self.batch_size if bucket is None else bucket
        return (b, c.img_size, c.img_size, c.in_channels)

    def bucket_for(self, n: int) -> int:
        """Smallest compiled bucket covering ``n`` rows (the largest bucket
        when nothing covers it — the caller chunks)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def plan_chunks(self, n: int) -> list:
        """Split ``n`` rows into compiled-bucket steps via the module-level
        pad-minimizing ``plan_chunks`` over this model's bucket set."""
        return plan_chunks(n, self.buckets)

    # -- execution ----------------------------------------------------------

    def warmup(self):
        """Compile (and time) every bucket's fixed-shape step on zeros."""
        t0 = time.perf_counter()
        for b in self.buckets:
            jax.block_until_ready(
                self._fwd(self.folded, jnp.zeros(self.input_shape(b),
                                                 jnp.uint8)))
        return time.perf_counter() - t0

    def step(self, images_u8):
        """One compiled step: images MUST already be a whole bucket."""
        if images_u8.shape[0] not in self.buckets:
            raise ValueError(
                f"batch of {images_u8.shape[0]} is not a compiled bucket "
                f"{self.buckets}; pad to one (the engine does this)")
        return self._fwd(self.folded, jnp.asarray(images_u8, jnp.uint8))

    def logits(self, images_u8):
        """images_u8: (N, H, W, C) uint8, any N >= 1 -> (N, classes) f32.

        Bucketed dispatch via ``plan_chunks`` — pad rows are dropped
        before returning.
        """
        images_u8 = jnp.asarray(images_u8, jnp.uint8)
        outs, i = [], 0
        for rows, b in self.plan_chunks(images_u8.shape[0]):
            chunk = images_u8[i:i + rows]
            if b > rows:
                chunk = jnp.concatenate(
                    [chunk, jnp.zeros((b - rows, *chunk.shape[1:]),
                                      jnp.uint8)], axis=0)
            outs.append(self.step(chunk)[:rows])
            i += rows
        return jnp.concatenate(outs, axis=0)

    def classify(self, images_u8):
        """(N, H, W, C) uint8 -> (N,) int32 argmax class ids."""
        return jnp.argmax(self.logits(images_u8), axis=-1).astype(jnp.int32)

    # -- profiling ----------------------------------------------------------

    def profile_step(self, images_u8=None, *, tracer=None,
                     clock=time.perf_counter) -> list:
        """Per-layer wall times for ONE forward pass, sync-barriered.

        Runs an un-jitted forward through a ``_LayerTimer`` wrapping this
        model's backend (the ``calibrate_layer_occupancy`` recipe: eager
        ops, trace zipped with the known call order) and returns one row
        per timed op::

            {"path": "blocks/b0/ssa/wq", "route": "lut_sparse",
             "seconds": 1.3e-4, "occupancy": 0.31}

        ``route`` is the resolved plan's decision for that layer ("stdp"
        for the attention op — it has no matmul route); ``occupancy`` is
        the plan's calibrated chunk occupancy, or None if uncalibrated.
        Defaults to zeros at the largest bucket (the planning shape) when
        no ``images_u8`` is given — layer timing is shape-bound, and real
        pixels matter only when the sparse route's work depends on them,
        in which case pass the calibration batch.

        Eager per-op timing measures the op-level kernels a fused jit
        step would optimize across, so the rows are RELATIVE weight — the
        measured table ``scripts/autotune_routes.py --profile`` prints to
        seed route-constant fits — not a goodput prediction; the jitted
        ``step()`` stays the serving truth.

        With a ``tracer``, each row is also emitted as a ``("layer",
        path)`` span tagged with the route (as ``bucket=None`` — routes
        are strings, so the route rides in the row; spans carry the
        occupancy and ``value=seconds``).
        """
        if images_u8 is None:
            images_u8 = jnp.zeros(self.input_shape(), jnp.uint8)
        images_u8 = jnp.asarray(images_u8, jnp.uint8)
        if images_u8.shape[0] not in self.buckets:
            raise ValueError(
                f"profile batch of {images_u8.shape[0]} is not a compiled "
                f"bucket {self.buckets}; profiling times the shapes serving "
                "will run")
        timer = _LayerTimer(self.backend, clock=clock)
        occ_all = self.plan.layer_occupancy or {}
        sparse_occ = {p: occ_all[p]
                      for p, r in (self.plan.routes or {}).items()
                      if r == "lut_sparse"} or None
        fwd = lower(self.folded, self.cfg, timer, jit=False,
                    layer_occupancy=sparse_occ)
        jax.block_until_ready(fwd(self.folded, images_u8))
        paths = profile_layer_paths(self.cfg)
        if len(timer.trace) != len(paths):
            raise RuntimeError(
                f"layer-timing trace has {len(timer.trace)} entries but the "
                f"config has {len(paths)} timed ops — timer and "
                "forward_folded disagree about the op sequence")
        routes = self.plan.routes or {}
        rows = []
        for path, (t0, t1) in zip(paths, timer.trace):
            occ = occ_all.get(path)
            default = "stdp" if path.endswith("/stdp") else "unpack"
            rows.append({
                "path": path,
                "route": routes.get(path, default),
                "seconds": t1 - t0,
                "occupancy": occ,
            })
            if tracer is not None and tracer.enabled:
                tracer.span("layer", path, t0=t0, t1=t1,
                            occupancy=occ, value=t1 - t0)
        return rows

    def __call__(self, images_u8):
        return self.logits(images_u8)


def compile(params, cfg: SpikformerConfig, plan: ExecutionPlan | None = None,
            *, folded: bool = False, jit: bool = True,
            **plan_overrides) -> CompiledModel:
    """Run the pass pipeline under ``plan`` and return a ``CompiledModel``.

    ``params`` is a training tree (BN folded here) unless ``folded=True``,
    in which case it is already a ``fold_inference_params`` tree (possibly
    pre-quantized, possibly pre-annotated). ``plan_overrides`` are
    convenience ``dataclasses.replace`` fields on the plan::

        compile(params, cfg)                                # all defaults
        compile(params, cfg, backend="reference")
        compile(params, cfg, ExecutionPlan.from_json(text)) # replay

    ``jit=False`` lowers to the uncompiled step (debugging, error paths
    that must raise eagerly).
    """
    plan = ExecutionPlan() if plan is None else plan
    if plan_overrides:
        plan = dataclasses.replace(plan, **plan_overrides)

    backend = registry.get_backend(plan.backend, **plan.backend_options)
    spec = (registry.backend_spec(plan.backend)
            if isinstance(plan.backend, str) else None)

    def check_dtype(dtype):
        if spec is not None and dtype not in spec.weight_dtypes:
            raise ValueError(
                f"backend {spec.name!r} does not support weight_dtype "
                f"{dtype!r} (capabilities: {spec.weight_dtypes})")

    if plan.weight_dtype is not None:
        check_dtype(plan.weight_dtype)    # fail before paying to quantize
    tree = fold_bn(params, cfg, folded=folded)
    tree, weight_dtype = quantize_weights(tree, plan.weight_dtype)
    check_dtype(weight_dtype)             # dtype=None resolved from the tree

    if plan.route in ("auto", "lut"):
        # plan for the branch the backend will actually execute: a Pallas
        # backend (pinned, or auto-selected on TPU) routes via the Pallas
        # cost model and consumes real tables in its gather kernels
        is_pallas = use_pallas(getattr(backend, "pallas", False))
        tree, routes = plan_route_tables(
            tree, cfg, batch_size=plan.plan_batch,
            max_table_bytes=plan.max_table_bytes,
            build_tables=registry.wants_lut_tables(plan.backend, backend),
            constants=plan.route_constants, routes=plan.routes,
            layer_occupancy=plan.layer_occupancy,
            force="lut" if plan.route == "lut" else None,
            pallas=is_pallas)
    else:
        # the pin must hold even for a pre-annotated folded tree: stale
        # "lut" leaves would silently keep the LUT route alive
        tree = strip_lut_annotations(tree)
        routes = {}

    # static per-path occupancy, only for layers the plan routed sparse —
    # closed over at lowering, never a leaf of the traced tree
    occ_all = plan.layer_occupancy or {}
    sparse_occ = {p: occ_all[p]
                  for p, r in routes.items() if r == "lut_sparse"} or None

    resolved = dataclasses.replace(plan, weight_dtype=weight_dtype,
                                   routes=routes)
    return CompiledModel(cfg=cfg, backend=backend, folded=tree,
                         plan=resolved, jit=jit,
                         fwd=lower(tree, cfg, backend, jit=jit,
                                   layer_occupancy=sparse_occ))


def replicate_model(model: CompiledModel, *, device=None) -> CompiledModel:
    """A data-parallel serving copy of a compiled model — the fleet's
    per-replica plumbing.

    The RESOLVED ``ExecutionPlan`` is shared verbatim: replicas of one
    fleet run the same plan by construction (routes are already pinned in
    ``model.plan.routes``, so nothing can silently re-plan). With
    ``device=None`` the copy shares the folded tree AND the jitted step —
    jit executables are thread-safe, so thread-backed replicas on one
    device pay zero extra memory or compile time. With a ``device``, the
    folded tree is placed there and the plan re-lowers into a fresh step,
    so that replica's compute (weights committed to its device) runs
    data-parallel to the others."""
    if device is None:
        return CompiledModel(cfg=model.cfg, backend=model.backend,
                             folded=model.folded, plan=model.plan,
                             fwd=model._fwd, jit=model.jit)
    folded = jax.device_put(model.folded, device)
    occ_all = model.plan.layer_occupancy or {}
    sparse_occ = {p: occ_all[p]
                  for p, r in (model.plan.routes or {}).items()
                  if r == "lut_sparse"} or None
    return CompiledModel(cfg=model.cfg, backend=model.backend, folded=folded,
                         plan=model.plan, jit=model.jit,
                         fwd=lower(folded, model.cfg, model.backend,
                                   jit=model.jit,
                                   layer_occupancy=sparse_occ))
