"""Backend registry: inference backends as declared capabilities, not
string-matched branches.

``compile()`` resolves an ``ExecutionPlan.backend`` name through this
registry; a backend is a *registration* — name, factory, and the
capabilities the compile pipeline consults — so adding one (the forthcoming
Pallas/TPU backend, a sparse-event backend, ...) never edits core dispatch:

    from repro.infer.registry import register_backend

    register_backend("pallas_tpu", lambda **opts: PallasBackend(**opts),
                     weight_dtypes=("float32", "int8"),
                     device_kinds=("tpu",), wants_lut_tables=False)

Capabilities:

* ``weight_dtypes`` — which ``ExecutionPlan.weight_dtype`` values the
  backend's kernels execute; ``compile()`` rejects a plan outside the set.
* ``device_kinds`` — JAX platform names the backend is built for.
  ``get_backend`` enforces this against the current JAX platform: asking
  for a TPU-only backend on a CPU host fails up front with the available
  platforms named, instead of tracing kernels that cannot lower. Passing
  ``interpret=True`` in the options is the explicit escape hatch — every
  backend here also runs in Pallas interpret/oracle mode, which is exactly
  how tier-1 exercises the ``packed_pallas`` kernels on CPU.
* ``wants_lut_tables`` — whether the route planner should build and cache
  the (C, 256, N) byte-LUT tables into this backend's folded tree, or only
  flag planned layers. ``None`` defers to the backend *instance* (the
  packed backend answers per ``pallas`` mode).

The built-in "packed" and "reference" backends register themselves when
``repro.infer.backends`` imports (any ``repro.infer`` import does).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered backend: how to build it and what it can do."""
    name: str
    factory: Callable[..., Any]
    weight_dtypes: tuple[str, ...] = ("float32", "int8")
    device_kinds: tuple[str, ...] = ("cpu", "gpu", "tpu")
    wants_lut_tables: bool | None = None   # None: ask the instance
    aliases: tuple[str, ...] = ()

    def make(self, **options):
        return self.factory(**options)


_REGISTRY: dict[str, BackendSpec] = {}
_ALIASES: dict[str, str] = {}


def register_backend(name: str, factory: Callable[..., Any], *,
                     weight_dtypes=("float32", "int8"),
                     device_kinds=("cpu", "gpu", "tpu"),
                     wants_lut_tables: bool | None = None,
                     aliases=(), overwrite: bool = False) -> BackendSpec:
    """Register ``factory(**options) -> backend`` under ``name``.

    ``overwrite=False`` (the default) refuses to shadow an existing
    registration — re-registering a name is almost always an import-order
    accident, and a silent swap would corrupt every plan naming it.
    """
    taken = {name, *aliases} & ({*_REGISTRY} | {*_ALIASES})
    if taken and not overwrite:
        raise ValueError(f"backend name(s) {sorted(taken)} already "
                         "registered; pass overwrite=True to replace")
    # an overwrite must actually take: every name the new spec claims is
    # evicted first — a directly-registered spec goes entirely (with its
    # aliases); a claimed *alias* is detached from its owner, which keeps
    # its primary name. Either way resolution can't silently keep routing
    # an old spec through a stale entry.
    for key in {name, *aliases}:
        old = _REGISTRY.pop(key, None)
        if old is not None:
            for a in old.aliases:
                _ALIASES.pop(a, None)
            continue
        owner = _ALIASES.pop(key, None)
        if owner is not None and owner in _REGISTRY:
            kept = _REGISTRY[owner]
            _REGISTRY[owner] = dataclasses.replace(
                kept, aliases=tuple(a for a in kept.aliases if a != key))
    spec = BackendSpec(name=name, factory=factory,
                       weight_dtypes=tuple(weight_dtypes),
                       device_kinds=tuple(device_kinds),
                       wants_lut_tables=wants_lut_tables,
                       aliases=tuple(aliases))
    _REGISTRY[name] = spec
    for a in aliases:
        _ALIASES[a] = name
    return spec


def unregister_backend(name: str) -> None:
    """Remove a registration, by name or alias (tests use this to clean
    up); removing via an alias drops the whole spec and its aliases."""
    spec = _REGISTRY.pop(_ALIASES.get(name, name), None)
    if spec is not None:
        for a in spec.aliases:
            _ALIASES.pop(a, None)


def backend_spec(name: str) -> BackendSpec:
    """Spec by name or alias; unknown names fail with the available set."""
    key = _ALIASES.get(name, name)
    spec = _REGISTRY.get(key)
    if spec is None:
        raise ValueError(f"unknown inference backend {name!r}; registered: "
                         f"{sorted(_REGISTRY)}")
    return spec


def list_backends(*, weight_dtype: str | None = None,
                  device_kind: str | None = None) -> list[str]:
    """Registered backend names, filtered by capability."""
    names = []
    for name, spec in sorted(_REGISTRY.items()):
        if weight_dtype is not None and weight_dtype not in spec.weight_dtypes:
            continue
        if device_kind is not None and device_kind not in spec.device_kinds:
            continue
        names.append(name)
    return names


def get_backend(name, **options):
    """Backend *instance* by registered name; instances pass through
    (callers may hand ``compile()`` a pre-built backend). ``options`` go to the factory — unknown keys are the
    factory's problem, by design.

    The spec's ``device_kinds`` is enforced here: a backend built for
    hardware this host does not have fails loudly, naming the platforms
    that ARE available and the ``interpret=True`` escape hatch that runs
    its kernels under the Pallas interpreter instead (the tier-1 testing
    mode). The hatch is an explicit opt-in so nobody mistakes interpreted
    timings for the real thing.
    """
    if not isinstance(name, str):
        return name
    spec = backend_spec(name)
    if not options.get("interpret"):
        import jax
        platform = jax.default_backend()
        if platform not in spec.device_kinds:
            available = sorted({d.platform for d in jax.devices()})
            raise ValueError(
                f"backend {spec.name!r} targets device kind(s) "
                f"{sorted(spec.device_kinds)} but the current JAX platform "
                f"is {platform!r} (available: {available}); pass "
                "backend_options={'interpret': True} to run its Pallas "
                "kernels in interpret mode on this host (bit-exact, "
                "test-speed only)")
    return spec.make(**options)


def wants_lut_tables(name_or_instance, backend) -> bool:
    """Resolve the table capability: spec declaration first, else the
    instance's own ``wants_lut_tables`` attribute, else True."""
    if isinstance(name_or_instance, str):
        declared = backend_spec(name_or_instance).wants_lut_tables
        if declared is not None:
            return declared
    return bool(getattr(backend, "wants_lut_tables", True))
