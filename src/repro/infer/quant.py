"""int8 weight quantization for the packed inference datapath.

VESTA's PE multiplies one 8-bit-integer weight by one binary spike; the float
route of this reproduction uses f32 weights only because they fall out of BN
folding. This module closes the gap: every BN-folded kernel is quantized to
int8 with a per-output-channel symmetric scale, and — the part that keeps the
datapath integer — the scale is never applied to the accumulators. Instead it
is folded into the LIF threshold comparison:

    acc      = sum_k spike_k * wq[k, n]              (exact small integers)
    fires    <=>  h(acc*s + bias) >= v_th
             <=>  h(acc  + bias/s) >= v_th / s       (LIF dynamics are
                                                      per-channel linear)

so the packed route runs LIF on the raw integer accumulators with a
per-channel bias ``bias/s`` and threshold ``v_th/s`` (see
``kernels.ops.tflif_pack``'s vector ``v_th``). The LIF recurrence
``h = v + (x + b - v)/tau``, the hard reset, and the comparison are all
homogeneous of degree 1 in (x, b, v, v_th), so the rescaled dynamics fire on
exactly the same set of timesteps.

The exactness reference for this route is the *float emulation*: the same
quantized integer weights run through the float graph with the same
scale-folded bias/threshold (``FloatBackend`` with a quantized tree). The two
are bit-identical on CPU; quantization *error* vs the original float weights
is a model-accuracy question, measured end-to-end, not hidden in kernels.

STDP attention has no weights (binary q/k/v), and the classifier head runs on
float rates — both stay untouched.
"""
from __future__ import annotations

import jax.numpy as jnp

WEIGHT_DTYPES = ("float32", "int8")


def map_folded_layers(folded, fn):
    """Apply ``fn(path, layer) -> layer`` to every conv/linear layer dict of
    a ``fold_inference_params`` tree, rebuilding the scs/blocks schema and
    passing every other top-level key (head, ...) through untouched. The ONE
    place the folded-tree layer schema is enumerated — quantization, route
    planning, and annotation stripping all walk through here."""
    out = dict(folded)
    out["scs"] = {name: fn(f"scs/{name}", layer)
                  for name, layer in folded["scs"].items()}
    out["blocks"] = {
        bname: {grp: {wn: fn(f"blocks/{bname}/{grp}/{wn}", layer)
                      for wn, layer in sub.items()}
                for grp, sub in blk.items()}
        for bname, blk in folded["blocks"].items()}
    return out


def quantize_layer(layer):
    """{kernel, bias} -> {kernel: int8, scale: (N,) f32, bias} per-channel
    symmetric quantization over the output-channel (last) axis."""
    w = layer["kernel"].astype(jnp.float32)
    amax = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)))
    scale = jnp.where(amax > 0, amax / 127.0, jnp.float32(1.0))
    wq = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return {"kernel": wq, "scale": scale, "bias": layer["bias"]}


def quantize_folded(folded):
    """Quantize a ``fold_inference_params`` tree to int8 weights.

    Every SCS conv and every SSA/MLP linear gains a ``scale`` leaf and an
    int8 ``kernel``; the float head is passed through unchanged. Backends
    detect the ``scale`` leaf and switch to the threshold-folded LIF.
    """
    return map_folded_layers(folded, lambda _, layer: quantize_layer(layer))
