"""Packed-bit Spikformer inference: the bridge from the float training
reference to VESTA's unified-PE datapath, behind a compile/serve split —
``compile(params, cfg, plan)`` lowers to a ``CompiledModel``,
``MicroBatchEngine`` serves it. See README.md in this directory."""
from .backends import FloatBackend, PackedBackend, get_backend
from .compile import (CompiledModel, ExecutionPlan, compile, fold_bn,
                      lower, plan_route_tables, quantize_weights,
                      strip_lut_annotations)
from .engine import PAPER_FPS, MicroBatchEngine, Request
from .quant import quantize_folded, quantize_layer
from .registry import (BackendSpec, backend_spec, list_backends,
                       register_backend, unregister_backend)
from .session import InferenceSession, benchmark_session, plan_routes

__all__ = [
    # compile half
    "ExecutionPlan", "CompiledModel", "compile",
    "fold_bn", "quantize_weights", "plan_route_tables", "lower",
    "strip_lut_annotations",
    # serve half
    "MicroBatchEngine", "Request", "PAPER_FPS",
    # backends + registry
    "FloatBackend", "PackedBackend", "get_backend",
    "BackendSpec", "register_backend", "unregister_backend",
    "backend_spec", "list_backends",
    # quantization
    "quantize_folded", "quantize_layer",
    # deprecated shim
    "InferenceSession", "benchmark_session", "plan_routes",
]
