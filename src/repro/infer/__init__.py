"""Packed-bit Spikformer inference: the bridge from the float training
reference to VESTA's unified-PE datapath. See README.md in this directory."""
from .backends import FloatBackend, PackedBackend, get_backend
from .quant import quantize_folded, quantize_layer
from .session import InferenceSession, benchmark_session, plan_routes

__all__ = ["FloatBackend", "PackedBackend", "get_backend",
           "InferenceSession", "benchmark_session", "plan_routes",
           "quantize_folded", "quantize_layer"]
