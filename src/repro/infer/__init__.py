"""Packed-bit Spikformer inference: the bridge from the float training
reference to VESTA's unified-PE datapath, behind a compile/serve split —
``compile(params, cfg, plan)`` lowers to a ``CompiledModel``,
``MicroBatchEngine`` serves it (and ``replicate_model`` places copies for
the multi-replica fleet). Every serving surface implements the
``ServeClient`` protocol with the versioned ``serve_stats`` schema. See
README.md in this directory."""
from .backends import (FloatBackend, OccupancyRecorder, PackedBackend,
                       chunk_occupancy, get_backend, spike_occupancy,
                       value_chunk_occupancy)
from .compile import (CompiledModel, ExecutionPlan,
                      calibrate_layer_occupancy, compile, fold_bn,
                      linear_layer_paths, lower, plan_route_tables,
                      profile_layer_paths, quantize_weights,
                      replicate_model, strip_lut_annotations)
from .engine import (PAPER_FPS, SERVE_STATS_VERSION, MicroBatchEngine,
                     QueueDepthWatermark, Request, ServeClient,
                     batch_occupancy, serve_stats)
from .quant import quantize_folded, quantize_layer
from .registry import (BackendSpec, backend_spec, list_backends,
                       register_backend, unregister_backend)

__all__ = [
    # compile half
    "ExecutionPlan", "CompiledModel", "compile", "replicate_model",
    "fold_bn", "quantize_weights", "plan_route_tables", "lower",
    "strip_lut_annotations",
    "calibrate_layer_occupancy", "linear_layer_paths",
    "profile_layer_paths",
    # serve half
    "MicroBatchEngine", "Request", "PAPER_FPS", "batch_occupancy",
    "ServeClient", "serve_stats", "SERVE_STATS_VERSION",
    "QueueDepthWatermark",
    # backends + registry
    "FloatBackend", "PackedBackend", "OccupancyRecorder", "get_backend",
    "spike_occupancy", "chunk_occupancy", "value_chunk_occupancy",
    "BackendSpec", "register_backend", "unregister_backend",
    "backend_spec", "list_backends",
    # quantization
    "quantize_folded", "quantize_layer",
]
