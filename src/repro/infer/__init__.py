"""Packed-bit Spikformer inference: the bridge from the float training
reference to VESTA's unified-PE datapath, behind a compile/serve split —
``compile(params, cfg, plan)`` lowers to a ``CompiledModel``,
``MicroBatchEngine`` serves it. See README.md in this directory."""
from .backends import (FloatBackend, OccupancyRecorder, PackedBackend,
                       chunk_occupancy, get_backend, spike_occupancy,
                       value_chunk_occupancy)
from .compile import (CompiledModel, ExecutionPlan,
                      calibrate_layer_occupancy, compile, fold_bn,
                      linear_layer_paths, lower, plan_route_tables,
                      quantize_weights, strip_lut_annotations)
from .engine import PAPER_FPS, MicroBatchEngine, Request, batch_occupancy
from .quant import quantize_folded, quantize_layer
from .registry import (BackendSpec, backend_spec, list_backends,
                       register_backend, unregister_backend)
from .session import InferenceSession, benchmark_session, plan_routes

__all__ = [
    # compile half
    "ExecutionPlan", "CompiledModel", "compile",
    "fold_bn", "quantize_weights", "plan_route_tables", "lower",
    "strip_lut_annotations",
    "calibrate_layer_occupancy", "linear_layer_paths",
    # serve half
    "MicroBatchEngine", "Request", "PAPER_FPS", "batch_occupancy",
    # backends + registry
    "FloatBackend", "PackedBackend", "OccupancyRecorder", "get_backend",
    "spike_occupancy", "chunk_occupancy", "value_chunk_occupancy",
    "BackendSpec", "register_backend", "unregister_backend",
    "backend_spec", "list_backends",
    # quantization
    "quantize_folded", "quantize_layer",
    # deprecated shim
    "InferenceSession", "benchmark_session", "plan_routes",
]
