"""Sharded, checkpointable data pipeline.

Design (1000-node posture):
  * every *host* owns a disjoint shard of the global batch — `host_id` /
    `n_hosts` select it deterministically from the stream index, so adding a
    host never reshuffles another host's data (elastic-friendly);
  * the pipeline is a pure function of (seed, step) => restart-safe: the
    checkpoint stores ONLY the integer step; no iterator pickling;
  * a background prefetch thread keeps `prefetch` batches ready so host
    input never blocks the device step;
  * sources: synthetic LM tokens (zipf-ish unigram mixture — compressible
    structure so loss curves are meaningful), a binary token-file reader
    (memory-mapped, fixed-length records), and spikformer image batches.

The same pipeline object also serves the *global-array* path: on a multi-
host deployment each host feeds its local rows and
``jax.make_array_from_process_local_data`` assembles the sharded global
batch. On this single-process container that reduces to a device_put.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq: int = 1024
    global_batch: int = 8
    vocab: int = 50_000
    seed: int = 0
    kind: str = "synthetic_lm"      # synthetic_lm | token_file | images
    path: str | None = None         # token_file: .bin of uint32 tokens
    image_size: int = 32            # images
    n_classes: int = 10             # images
    host_id: int = 0
    n_hosts: int = 1
    prefetch: int = 2

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0, \
            (self.global_batch, self.n_hosts)
        return self.global_batch // self.n_hosts


# ---------------------------------------------------------------------------
# deterministic per-(step, host) generation
# ---------------------------------------------------------------------------

def _rng_for(cfg: DataConfig, step: int, row: int) -> np.random.Generator:
    # stable across restarts and host counts: keyed by the GLOBAL row index
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, row]))


def _synthetic_row(cfg: DataConfig, step: int, grow: int) -> np.ndarray:
    """One (seq+1,) token row: mixture of a zipf unigram draw and short
    repeated motifs — learnable structure for real loss curves."""
    rng = _rng_for(cfg, step, grow)
    n = cfg.seq + 1
    # zipf over the vocab, clipped
    toks = rng.zipf(1.3, size=n).astype(np.int64)
    toks = np.clip(toks, 1, cfg.vocab - 1)
    # motif: repeat a short pattern at a random offset (copy task structure);
    # cap the motif so it fits even for very short sequences
    hi = max(9, min(32, n // 2 + 1))
    mlen = int(rng.integers(min(8, hi - 1), hi))
    motif = rng.integers(1, cfg.vocab, size=mlen)
    reps = max(1, n // (4 * mlen))
    for r in range(reps):
        off = int(rng.integers(0, max(1, n - mlen)))
        toks[off:off + mlen] = motif
    return toks.astype(np.int32)


def synthetic_lm_batch(cfg: DataConfig, step: int) -> dict:
    rows = []
    for local_row in range(cfg.local_batch):
        grow = cfg.host_id * cfg.local_batch + local_row
        rows.append(_synthetic_row(cfg, step, grow))
    arr = np.stack(rows)                                    # (B, S+1)
    return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def image_batch(cfg: DataConfig, step: int) -> dict:
    """Synthetic labeled images: class-conditional blobs (learnable)."""
    imgs, labels = [], []
    for local_row in range(cfg.local_batch):
        grow = cfg.host_id * cfg.local_batch + local_row
        rng = _rng_for(cfg, step, grow)
        label = int(rng.integers(0, cfg.n_classes))
        base = np.full((cfg.image_size, cfg.image_size, 3),
                       20 * label + 30, np.float32)
        # class-dependent stripe pattern + noise
        xs = np.arange(cfg.image_size)
        stripe = 60.0 * np.sin(xs * (label + 1) / 3.0)
        base += stripe[None, :, None]
        base += rng.normal(0, 12, base.shape)
        imgs.append(np.clip(base, 0, 255).astype(np.uint8))
        labels.append(label)
    return {"image": np.stack(imgs), "label": np.array(labels, np.int32)}


class TokenFileSource:
    """Memory-mapped uint32 token file; rows are contiguous seq+1 windows
    strided deterministically by (step, row) so restart is exact."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        self.n_windows = max(1, (len(self.tokens) - 1) // (cfg.seq + 1))

    def batch(self, step: int) -> dict:
        rows = []
        for local_row in range(self.cfg.local_batch):
            grow = self.cfg.host_id * self.cfg.local_batch + local_row
            w = (step * self.cfg.global_batch + grow) % self.n_windows
            start = w * (self.cfg.seq + 1)
            rows.append(np.asarray(
                self.tokens[start:start + self.cfg.seq + 1], np.int32))
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

class DataPipeline:
    """Checkpointable prefetching pipeline. State == one integer (`step`)."""

    def __init__(self, cfg: DataConfig, *, start_step: int = 0,
                 sharding=None):
        self.cfg = cfg
        self.step = start_step
        self.sharding = sharding
        self._file = TokenFileSource(cfg) if cfg.kind == "token_file" else None
        self._q: queue.Queue = queue.Queue(maxsize=max(1, cfg.prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- generation ---------------------------------------------------------
    def _make(self, step: int) -> dict:
        if self.cfg.kind == "synthetic_lm":
            return synthetic_lm_batch(self.cfg, step)
        if self.cfg.kind == "token_file":
            return self._file.batch(step)
        if self.cfg.kind == "images":
            return image_batch(self.cfg, step)
        raise ValueError(self.cfg.kind)

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    # -- consumption ---------------------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, batch = self._q.get()
        # prefetch thread races ahead; trust its step accounting
        self.step = step + 1
        if self.sharding is not None:
            batch = {k: jax.device_put(v, self.sharding.get(k))
                     for k, v in batch.items()}
        else:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return batch

    # -- checkpointing --------------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": int(self.step), "seed": self.cfg.seed}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict, **kw) -> "DataPipeline":
        assert state.get("seed", cfg.seed) == cfg.seed, \
            "restoring a pipeline with a different data seed"
        return cls(cfg, start_step=int(state["step"]), **kw)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
