"""Gradient compression with error feedback.

Two layers:
  1. Numerics (works under jit-SPMD): ``ef_compress`` quantizes gradients to
     int8 (or top-k sparsifies) with an error-feedback accumulator, modelling
     exactly what a compressed cross-pod reduction delivers to the optimizer.
  2. Transport (shard_map): ``compressed_psum_int8`` — the actual collective
     a multi-pod deployment runs across the DCN boundary: int8 payload +
     fp32 scale all-gather, local dequant+mean. 4x fewer bytes on the wire
     than an fp32 all-reduce; HLO collective bytes drop accordingly (see
     benchmarks/compression_bench.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(grads, ef, *, method: str = "int8", topk_frac: float = 0.01):
    """Quantize/sparsify grads with error feedback. Returns (grads', ef')."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        if method == "int8":
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g32 / scale), -127, 127)
            deq = q * scale
        elif method == "topk":
            k = max(1, int(g32.size * topk_frac))
            flat = g32.reshape(-1)
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            deq = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(g32.shape)
        else:
            raise ValueError(method)
        return deq, g32 - deq

    out = jax.tree_util.tree_map(one, grads, ef)
    deq = jax.tree_util.tree_map(lambda t: t[0], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_ef


def compressed_psum_int8(x, axis_name: str):
    """shard_map collective: mean of `x` across `axis_name` with an int8
    payload (the cross-pod DCN reduction of a 1000-node deployment)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    n = jax.lax.psum(1, axis_name)
    qs = jax.lax.all_gather(q, axis_name)                # int8 on the wire
    scales = jax.lax.all_gather(scale, axis_name)        # fp32 scalars
    deq = (qs.astype(jnp.float32)
           * scales.reshape((-1,) + (1,) * x.ndim))
    return deq.sum(axis=0) / n
