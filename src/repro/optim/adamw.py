"""AdamW with global-norm clipping and warmup+cosine schedule (pure JAX).

Moments can be stored in bf16 (``state_dtype``) for the >=100B configs — the
update math always runs in fp32. State is a pytree congruent with params, so
the same sharding rules apply leaf-for-leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def _decayable(path: str, p) -> bool:
    """No weight decay for 1-D params (norm scales, biases)."""
    return p.ndim >= 2


def update(grads, state, params, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return (new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype))

    out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
