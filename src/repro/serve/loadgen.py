"""Open-loop load generation against the async serving runtime.

A closed-loop driver (submit everything, drain, divide) measures the
server's best case: arrivals conveniently wait for capacity. Real-time
claims — VESTA's sustained ~30 fps — are open-loop properties: requests
arrive on their OWN schedule whether or not the server kept up, and the
numbers that matter are goodput (work completed within its SLO per second
of wall time), tail latency under that arrival process (p99, not mean),
and SLO attainment. This module produces exactly those numbers.

    trace = poisson_trace(rps=60, duration_s=3, seed=0)
    with AsyncServeRuntime(model, policy=ServePolicy(slo_ms=100)) as rt:
        metrics = run_open_loop(rt, trace,
                                image_maker(model.input_shape()[1:], seed=1),
                                slo_ms=100)

The driver speaks only the ``ServeClient`` protocol (submit that may
raise ``QueueFull``, handles whose ``result`` blocks), so the same trace
drives the sync ``MicroBatchEngine``, the ``AsyncServeRuntime``, or a
multi-replica ``ServeFleet`` without an isinstance anywhere —
``run_replica_sweep`` exploits that to replay one trace across fleet
sizes and report goodput scaling.

The trace is a plain list of ``Arrival`` values, deterministic from its
seed, so a trace can be replayed — through the async runtime, or through
the sync engine for the bit-identical-labels parity check — and committed
next to a benchmark record. (The rid-aligned replay comparison assumes a
ZERO-REJECTION run: a rejected submit consumes no runtime rid, shifting
every later rid relative to a replay that submits all arrivals. Align on
per-request labels from the returned handles when rejections are
possible.)
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from ..infer.engine import latency_summary
from .scheduler import QueueFull


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: submit ``n_images`` at ``t_s`` seconds after
    the run starts."""
    t_s: float
    n_images: int


def validate_trace(trace) -> list:
    """Materialize any ``Arrival`` iterable and enforce the open-loop
    contract: timestamps non-negative and sorted non-decreasing, image
    counts >= 1. ``run_open_loop`` and the trace-replay path both call
    this at the door — a replay that silently reordered arrivals would
    produce a decision table that never happened, so a violation is a
    loud ``ValueError`` naming the offending index, never a sort."""
    trace = list(trace)
    prev = 0.0
    for k, a in enumerate(trace):
        if a.t_s < prev:
            raise ValueError(
                f"arrival {k} at t_s={a.t_s!r} precedes "
                f"{'arrival ' + str(k - 1) if k else 'the run start'} at "
                f"t_s={prev!r}; traces must be sorted non-decreasing")
        if a.n_images < 1:
            raise ValueError(
                f"arrival {k} carries n_images={a.n_images!r}; every "
                f"arrival must carry at least one image")
        prev = a.t_s
    return trace


def poisson_trace(*, rps: float, duration_s: float, seed: int,
                  images_per_request=(1, 1)) -> list:
    """Poisson arrival process: exponential inter-arrival times at ``rps``
    requests/second for ``duration_s``, each request carrying a uniform
    number of images in ``images_per_request`` (inclusive bounds).
    Deterministic from ``seed``."""
    if rps <= 0 or duration_s <= 0:
        raise ValueError(f"rps and duration_s must be > 0, got "
                         f"{rps!r}, {duration_s!r}")
    lo, hi = images_per_request
    rng = np.random.default_rng(seed)
    trace, t = [], 0.0
    while True:
        t += float(rng.exponential(1.0 / rps))
        if t >= duration_s:
            return trace
        trace.append(Arrival(t_s=t, n_images=int(rng.integers(lo, hi + 1))))


def burst_trace(*, rps_on: float, on_s: float, off_s: float,
                duration_s: float, seed: int, rps_off: float = 0.0,
                images_per_request=(1, 1)) -> list:
    """ON/OFF (interrupted Poisson) arrival process — the bursty shape a
    real event-camera workload produces: Poisson arrivals at ``rps_on``
    during ON periods of ``on_s`` seconds, then ``rps_off`` (default:
    silence) for ``off_s``, repeating for ``duration_s``. Deterministic
    from ``seed``. Same mean rate as Poisson at the duty-cycled average,
    but a far higher index of dispersion — exactly the traffic that makes
    queue-depth high-watermarks and admission control earn their keep."""
    if rps_on <= 0 or on_s <= 0 or off_s < 0 or duration_s <= 0:
        raise ValueError(
            f"need rps_on, on_s, duration_s > 0 and off_s >= 0, got "
            f"rps_on={rps_on!r}, on_s={on_s!r}, off_s={off_s!r}, "
            f"duration_s={duration_s!r}")
    if rps_off < 0:
        raise ValueError(f"rps_off must be >= 0, got {rps_off!r}")
    lo, hi = images_per_request
    rng = np.random.default_rng(seed)
    trace, t, period = [], 0.0, on_s + off_s
    while t < duration_s:
        phase = t % period
        rate = rps_on if phase < on_s else rps_off
        if rate <= 0:
            # silent phase: jump to the next ON boundary, no draws
            t = (t // period) * period + period
            continue
        t += float(rng.exponential(1.0 / rate))
        if t >= duration_s or (t % period) >= on_s and rate == rps_on:
            # a draw that crossed out of its phase is discarded, not kept:
            # keeping it would smear the OFF edge
            continue
        trace.append(Arrival(t_s=t, n_images=int(rng.integers(lo, hi + 1))))
    return trace


def burstiness(trace, *, window_s: float = 0.1) -> dict:
    """Burstiness accounting for an arrival trace: the index of dispersion
    (variance/mean of per-``window_s`` arrival counts — 1.0 for Poisson,
    >> 1 for ON/OFF bursts) and the peak-to-mean window rate. These are
    properties of the OFFERED load, computed from the trace alone, so a
    loadgen report can say "the server survived D=12 traffic", not just
    "some traffic". ``None`` values when the trace spans < 2 windows."""
    trace = list(trace)
    if not trace:
        return {"dispersion_index": None, "peak_to_mean_rate": None}
    span = trace[-1].t_s
    n_windows = int(np.ceil(span / window_s)) if span > 0 else 1
    if n_windows < 2:
        return {"dispersion_index": None, "peak_to_mean_rate": None}
    counts = np.zeros(n_windows, np.int64)
    for a in trace:
        counts[min(int(a.t_s / window_s), n_windows - 1)] += 1
    mean = counts.mean()
    return {
        "dispersion_index": (round(float(counts.var() / mean), 4)
                             if mean else None),
        "peak_to_mean_rate": (round(float(counts.max() / mean), 4)
                              if mean else None),
    }


def image_maker(image_shape, *, seed: int):
    """A deterministic ``make(index, n) -> (n, H, W, C) uint8`` factory for
    synthetic request payloads; same seed + same call sequence = same
    images (what lets a trace replay bit-identically through the sync and
    async paths)."""
    image_shape = tuple(int(d) for d in image_shape)
    rng = np.random.default_rng(seed)

    def make(index: int, n: int):
        return rng.integers(0, 256, (n, *image_shape), dtype=np.uint8)

    return make


def run_open_loop(runtime, trace, make_images, *, slo_ms: float,
                  result_timeout_s: float = 60.0, clock=time.perf_counter,
                  sleep=time.sleep, on_accept=None) -> dict:
    """Replay ``trace`` open-loop against ``runtime`` and measure.

    ``trace`` is ANY iterable of sorted ``Arrival`` values — a
    ``poisson_trace``/``burst_trace`` list, a generator, or arrivals
    loaded from a recorded event trace; it is materialized and validated
    at the door (``validate_trace`` — non-monotonic timestamps are a loud
    ``ValueError``, because the replay contract depends on arrival order).

    Each arrival is submitted at its scheduled time regardless of what has
    completed — when the server falls behind, latency (and eventually
    admission-control rejections) absorb the difference; the generator
    never throttles. After the last arrival the run waits for every
    ACCEPTED request; one that fails to complete within
    ``result_timeout_s`` counts as ``dropped`` — the acceptance contract is
    zero, because an accepted request is a promise.

    ``on_accept(k, handle)`` (optional) is called per arrival with the
    submit handle, or ``None`` when admission control rejected it — the
    hook trace replay uses to align labels with arrivals even though
    runtime rids only cover accepted submits.

    Returns the serving-under-load metrics: offered vs completed rates,
    goodput (within-SLO images/s over the whole open-loop window),
    p50/p95/p99 latency, SLO attainment, and the offered trace's
    burstiness (index of dispersion, peak-to-mean window rate).
    """
    trace = validate_trace(trace)
    slo_s = slo_ms / 1e3
    accepted, rejected = [], 0
    t0 = clock()
    for k, a in enumerate(trace):
        delay = t0 + a.t_s - clock()
        if delay > 0:
            sleep(delay)
        imgs = make_images(k, a.n_images)
        try:
            handle = runtime.submit(imgs)
        except QueueFull:
            rejected += 1
            handle = None
        else:
            accepted.append(handle)
        if on_accept is not None:
            on_accept(k, handle)
    # "done" is decided by FUTURE resolution, not t_done: a request that
    # times out here counts as dropped and must stay out of the completed
    # metrics even if the worker finishes it later in this wait loop —
    # one request, one bucket, metrics row internally consistent.
    # result_timeout_s is ONE shared drain deadline, not per-request: a
    # wedged worker fails the whole drain after that budget instead of
    # stalling accepted_requests x timeout (hours at bench rates).
    done, dropped = [], 0
    drain_deadline = clock() + result_timeout_s
    for req in accepted:
        try:
            req.result(timeout=max(0.0, drain_deadline - clock()))
            done.append(req)
        except Exception:
            dropped += 1
    elapsed = clock() - t0
    images_done = sum(len(r.labels) for r in done)
    within = [r for r in done if r.latency_s <= slo_s]
    duration = trace[-1].t_s if trace else 0.0
    return {
        "requests_offered": len(trace),
        "requests_accepted": len(accepted),
        "requests_rejected": rejected,
        "requests_dropped": dropped,          # accepted but never completed
        "offered_rps": round(len(trace) / duration, 2) if duration else 0.0,
        "elapsed_s": round(elapsed, 4),
        "images_completed": images_done,
        "completed_fps": round(images_done / elapsed, 2) if elapsed else 0.0,
        "goodput_fps": round(sum(len(r.labels) for r in within) / elapsed, 2)
        if elapsed else 0.0,
        "slo_ms": slo_ms,
        "slo_attainment": round(len(within) / len(done), 4) if done else None,
        **burstiness(trace),
        **latency_summary(r.latency_s for r in done),
    }


def run_replica_sweep(make_client, trace, make_images_factory, *,
                      replica_counts=(1, 2), slo_ms: float,
                      result_timeout_s: float = 60.0,
                      clock=time.perf_counter, sleep=time.sleep) -> list:
    """Replay ONE trace across fleet sizes and measure goodput scaling.

    ``make_client(n)`` builds a fresh ``ServeClient`` with ``n`` replicas
    (closed here after its run); ``make_images_factory()`` returns a fresh
    deterministic image maker per run, so every fleet size sees the exact
    same arrival schedule AND payload bytes — the only variable is the
    replica count. Returns one metrics row per count (the ``run_open_loop``
    schema plus ``replicas`` and ``goodput_scaling``, normalized to the
    first count's goodput — run counts smallest-first so the baseline is
    the 1-replica row)."""
    rows, base = [], None
    for n in replica_counts:
        client = make_client(n)
        try:
            metrics = run_open_loop(
                client, trace, make_images_factory(), slo_ms=slo_ms,
                result_timeout_s=result_timeout_s, clock=clock, sleep=sleep)
        finally:
            client.close()
        row = {"replicas": int(n), **metrics}
        if base is None:
            base = row["goodput_fps"]
        row["goodput_scaling"] = (round(row["goodput_fps"] / base, 4)
                                  if base else None)
        rows.append(row)
    return rows


def replay_decisions(trace, scheduler, *, service_s, drain=True) -> list:
    """Replay an arrival trace through a scheduler as a pure discrete-event
    simulation and return the full decision table.

    Live runs thread real wall time through ``decide``; this replay
    threads a virtual clock instead, so the SAME trace + the SAME policy +
    the SAME service-time model always produce the IDENTICAL table — the
    determinism half of the trace-replay contract, and the tool that lets
    a test pin exactly how a bursty ON/OFF trace sheds (``QueueFull``) at
    the burst peak and recovers once it passes.

    ``scheduler`` is a fresh ``ContinuousBatchingScheduler`` (one modeled
    worker) or ``FleetScheduler`` (its ``n_replicas`` workers, busy masks
    and placement included). ``service_s`` models step time: a
    ``{bucket: seconds}`` dict or a ``f(bucket) -> seconds`` callable — a
    live scheduler's ``service_snapshot()`` is a ready-made dict. Each
    dispatch occupies its replica for the modeled service time and feeds
    ``observe_step``, so the policy's EWMAs evolve exactly as they would
    have.

    Table rows (time rounded to 6 decimals, chronological):
    ``{"t", "event": "reject", "images", "backlog"}`` for an admission
    shed, ``{"t", "event": "dispatch", "bucket", "rows", "replica",
    "reason", "backlog"}`` for a dispatch (``backlog`` = images left
    AFTER the action). With ``drain=True`` (default) the tail of the
    queue dispatches under draining rules once arrivals are exhausted —
    every admitted image leaves the table, the simulated promise."""
    trace = validate_trace(trace)
    service = (service_s if callable(service_s)
               else lambda b, _m=dict(service_s): float(_m[b]))
    is_fleet = hasattr(scheduler, "place")
    n = getattr(scheduler, "n_replicas", 1)
    queue: deque = deque()          # per-image submit times, FIFO
    busy_until = [0.0] * n
    table, i, now = [], 0, 0.0
    while i < len(trace) or queue:
        # deliver every arrival due by the virtual clock
        while i < len(trace) and trace[i].t_s <= now:
            a = trace[i]
            if scheduler.admit(len(queue), a.n_images):
                queue.extend([a.t_s] * a.n_images)
            else:
                table.append({"t": round(a.t_s, 6), "event": "reject",
                              "images": int(a.n_images),
                              "backlog": len(queue)})
            i += 1
        if not is_fleet and busy_until[0] > now:
            # the single runtime's worker cannot decide mid-step: jump to
            # whichever comes first, the step finishing or the next arrival
            now = (min(busy_until[0], trace[i].t_s) if i < len(trace)
                   else busy_until[0])
            continue
        draining = drain and i >= len(trace)
        kwargs = dict(backlog=len(queue),
                      oldest_submit_s=queue[0] if queue else None,
                      now_s=now, draining=draining)
        if is_fleet:
            d = scheduler.decide(
                busy=tuple(busy_until[r] > now for r in range(n)), **kwargs)
        else:
            d = scheduler.decide(**kwargs)
        if d.action == "dispatch":
            r = 0 if d.replica is None else d.replica
            rows = min(d.rows, len(queue))
            for _ in range(rows):
                queue.popleft()
            svc = float(service(d.bucket))
            busy_until[r] = now + svc
            if is_fleet:
                scheduler.observe_step(d.bucket, svc, replica=r)
            else:
                scheduler.observe_step(d.bucket, svc)
            table.append({"t": round(now, 6), "event": "dispatch",
                          "bucket": int(d.bucket), "rows": int(rows),
                          "replica": int(r), "reason": d.reason,
                          "backlog": len(queue)})
            continue
        # "wait" / "idle": advance the clock to the next state change
        nexts = []
        if i < len(trace):
            nexts.append(trace[i].t_s)
        if d.action == "wait":
            nexts.append(now + max(d.wait_s, 1e-9))
        frees = [b for b in busy_until if b > now]
        if frees:
            nexts.append(min(frees))
        if not nexts:
            break   # idle, nothing left to happen
        now = min(nexts)
    return table
