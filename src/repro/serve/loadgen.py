"""Open-loop load generation against the async serving runtime.

A closed-loop driver (submit everything, drain, divide) measures the
server's best case: arrivals conveniently wait for capacity. Real-time
claims — VESTA's sustained ~30 fps — are open-loop properties: requests
arrive on their OWN schedule whether or not the server kept up, and the
numbers that matter are goodput (work completed within its SLO per second
of wall time), tail latency under that arrival process (p99, not mean),
and SLO attainment. This module produces exactly those numbers.

    trace = poisson_trace(rps=60, duration_s=3, seed=0)
    with AsyncServeRuntime(model, policy=ServePolicy(slo_ms=100)) as rt:
        metrics = run_open_loop(rt, trace,
                                image_maker(model.input_shape()[1:], seed=1),
                                slo_ms=100)

The driver speaks only the ``ServeClient`` protocol (submit that may
raise ``QueueFull``, handles whose ``result`` blocks), so the same trace
drives the sync ``MicroBatchEngine``, the ``AsyncServeRuntime``, or a
multi-replica ``ServeFleet`` without an isinstance anywhere —
``run_replica_sweep`` exploits that to replay one trace across fleet
sizes and report goodput scaling.

The trace is a plain list of ``Arrival`` values, deterministic from its
seed, so a trace can be replayed — through the async runtime, or through
the sync engine for the bit-identical-labels parity check — and committed
next to a benchmark record. (The rid-aligned replay comparison assumes a
ZERO-REJECTION run: a rejected submit consumes no runtime rid, shifting
every later rid relative to a replay that submits all arrivals. Align on
per-request labels from the returned handles when rejections are
possible.)
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..infer.engine import latency_summary
from .scheduler import QueueFull


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: submit ``n_images`` at ``t_s`` seconds after
    the run starts."""
    t_s: float
    n_images: int


def poisson_trace(*, rps: float, duration_s: float, seed: int,
                  images_per_request=(1, 1)) -> list:
    """Poisson arrival process: exponential inter-arrival times at ``rps``
    requests/second for ``duration_s``, each request carrying a uniform
    number of images in ``images_per_request`` (inclusive bounds).
    Deterministic from ``seed``."""
    if rps <= 0 or duration_s <= 0:
        raise ValueError(f"rps and duration_s must be > 0, got "
                         f"{rps!r}, {duration_s!r}")
    lo, hi = images_per_request
    rng = np.random.default_rng(seed)
    trace, t = [], 0.0
    while True:
        t += float(rng.exponential(1.0 / rps))
        if t >= duration_s:
            return trace
        trace.append(Arrival(t_s=t, n_images=int(rng.integers(lo, hi + 1))))


def image_maker(image_shape, *, seed: int):
    """A deterministic ``make(index, n) -> (n, H, W, C) uint8`` factory for
    synthetic request payloads; same seed + same call sequence = same
    images (what lets a trace replay bit-identically through the sync and
    async paths)."""
    image_shape = tuple(int(d) for d in image_shape)
    rng = np.random.default_rng(seed)

    def make(index: int, n: int):
        return rng.integers(0, 256, (n, *image_shape), dtype=np.uint8)

    return make


def run_open_loop(runtime, trace, make_images, *, slo_ms: float,
                  result_timeout_s: float = 60.0, clock=time.perf_counter,
                  sleep=time.sleep) -> dict:
    """Replay ``trace`` open-loop against ``runtime`` and measure.

    Each arrival is submitted at its scheduled time regardless of what has
    completed — when the server falls behind, latency (and eventually
    admission-control rejections) absorb the difference; the generator
    never throttles. After the last arrival the run waits for every
    ACCEPTED request; one that fails to complete within
    ``result_timeout_s`` counts as ``dropped`` — the acceptance contract is
    zero, because an accepted request is a promise.

    Returns the serving-under-load metrics: offered vs completed rates,
    goodput (within-SLO images/s over the whole open-loop window),
    p50/p95/p99 latency, and SLO attainment.
    """
    slo_s = slo_ms / 1e3
    accepted, rejected = [], 0
    t0 = clock()
    for k, a in enumerate(trace):
        delay = t0 + a.t_s - clock()
        if delay > 0:
            sleep(delay)
        imgs = make_images(k, a.n_images)
        try:
            accepted.append(runtime.submit(imgs))
        except QueueFull:
            rejected += 1
    # "done" is decided by FUTURE resolution, not t_done: a request that
    # times out here counts as dropped and must stay out of the completed
    # metrics even if the worker finishes it later in this wait loop —
    # one request, one bucket, metrics row internally consistent.
    # result_timeout_s is ONE shared drain deadline, not per-request: a
    # wedged worker fails the whole drain after that budget instead of
    # stalling accepted_requests x timeout (hours at bench rates).
    done, dropped = [], 0
    drain_deadline = clock() + result_timeout_s
    for req in accepted:
        try:
            req.result(timeout=max(0.0, drain_deadline - clock()))
            done.append(req)
        except Exception:
            dropped += 1
    elapsed = clock() - t0
    images_done = sum(len(r.labels) for r in done)
    within = [r for r in done if r.latency_s <= slo_s]
    duration = trace[-1].t_s if trace else 0.0
    return {
        "requests_offered": len(trace),
        "requests_accepted": len(accepted),
        "requests_rejected": rejected,
        "requests_dropped": dropped,          # accepted but never completed
        "offered_rps": round(len(trace) / duration, 2) if duration else 0.0,
        "elapsed_s": round(elapsed, 4),
        "images_completed": images_done,
        "completed_fps": round(images_done / elapsed, 2) if elapsed else 0.0,
        "goodput_fps": round(sum(len(r.labels) for r in within) / elapsed, 2)
        if elapsed else 0.0,
        "slo_ms": slo_ms,
        "slo_attainment": round(len(within) / len(done), 4) if done else None,
        **latency_summary(r.latency_s for r in done),
    }


def run_replica_sweep(make_client, trace, make_images_factory, *,
                      replica_counts=(1, 2), slo_ms: float,
                      result_timeout_s: float = 60.0,
                      clock=time.perf_counter, sleep=time.sleep) -> list:
    """Replay ONE trace across fleet sizes and measure goodput scaling.

    ``make_client(n)`` builds a fresh ``ServeClient`` with ``n`` replicas
    (closed here after its run); ``make_images_factory()`` returns a fresh
    deterministic image maker per run, so every fleet size sees the exact
    same arrival schedule AND payload bytes — the only variable is the
    replica count. Returns one metrics row per count (the ``run_open_loop``
    schema plus ``replicas`` and ``goodput_scaling``, normalized to the
    first count's goodput — run counts smallest-first so the baseline is
    the 1-replica row)."""
    rows, base = [], None
    for n in replica_counts:
        client = make_client(n)
        try:
            metrics = run_open_loop(
                client, trace, make_images_factory(), slo_ms=slo_ms,
                result_timeout_s=result_timeout_s, clock=clock, sleep=sleep)
        finally:
            client.close()
        row = {"replicas": int(n), **metrics}
        if base is None:
            base = row["goodput_fps"]
        row["goodput_scaling"] = (round(row["goodput_fps"] / base, 4)
                                  if base else None)
        rows.append(row)
    return rows
