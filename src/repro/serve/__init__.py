"""Asynchronous continuous-batching serving over a ``CompiledModel`` —
the open-loop half of the serving story. ``AsyncServeRuntime`` accepts
requests from caller threads into a bounded queue and completes futures as
the background worker's bucket steps finish; every scheduling decision is
the pure, clock-injected ``ContinuousBatchingScheduler``; ``loadgen``
measures goodput / tail latency / SLO attainment under a real arrival
process. See README.md in this directory."""
from .loadgen import Arrival, image_maker, poisson_trace, run_open_loop
from .runtime import AsyncRequest, AsyncServeRuntime
from .scheduler import (ContinuousBatchingScheduler, Decision, QueueFull,
                        ServePolicy)

__all__ = [
    "AsyncRequest", "AsyncServeRuntime",
    "ContinuousBatchingScheduler", "Decision", "QueueFull", "ServePolicy",
    "Arrival", "image_maker", "poisson_trace", "run_open_loop",
]
