"""Asynchronous continuous-batching serving over a ``CompiledModel`` —
the open-loop half of the serving story. ``AsyncServeRuntime`` accepts
requests from caller threads into a bounded queue and completes futures as
the background worker's bucket steps finish; ``ServeFleet`` scales that
shape to N replicas behind one placement-aware ``FleetScheduler``; every
scheduling decision is pure and clock-injected; ``loadgen`` measures
goodput / tail latency / SLO attainment under a real arrival process.
All three serving surfaces (sync ``MicroBatchEngine``, async runtime,
fleet) speak the ``ServeClient`` protocol — submit / stats / close —
with one versioned stats schema. See README.md in this directory."""
from ..infer.engine import SERVE_STATS_VERSION, ServeClient
from .fleet import ServeFleet
from .loadgen import (Arrival, burst_trace, burstiness, image_maker,
                      poisson_trace, replay_decisions, run_open_loop,
                      run_replica_sweep, validate_trace)
from .runtime import AsyncRequest, AsyncServeRuntime
from .scheduler import (ContinuousBatchingScheduler, Decision,
                        FleetScheduler, QueueFull, ServePolicy)

__all__ = [
    "ServeClient", "SERVE_STATS_VERSION",
    "AsyncRequest", "AsyncServeRuntime", "ServeFleet",
    "ContinuousBatchingScheduler", "FleetScheduler", "Decision",
    "QueueFull", "ServePolicy",
    "Arrival", "image_maker", "poisson_trace", "burst_trace", "burstiness",
    "replay_decisions", "run_open_loop", "run_replica_sweep",
    "validate_trace",
]
