"""``ServeFleet`` — N compiled replicas behind ONE continuous-batching
scheduler: the "millions of users" axis of the serving story.

The async runtime is one worker over one ``CompiledModel``; this module
scales that shape out. One bounded queue and one admission door (the same
``ServeClient`` submit contract), one pure placement-aware scheduler
(``FleetScheduler``), and N replicas — each a ``CompiledModel`` plus a
worker thread. Replica placement follows ``repro.sharding.rules``: on a
multi-device host ``replica_devices`` assigns each replica its own device
along the 1-D data-parallel serving mesh and
``repro.infer.compile.replicate_model`` places its weights there; on a
single-device host the assignment degrades to thread-backed replicas that
share the template's folded tree and jitted step.

Replica lifecycle (the state machine ``health()`` reports)::

    created -> warming -> ready <-> draining -> stopped
                             \\______________/
                                 hot swap

* **warmup** — ``start()`` compiles every bucket on every replica before
  the first request (a replica that jits on live traffic blows its first
  SLO).
* **health probes** — ``probe()`` pushes a zeros step through each ready
  replica and reports per-replica liveness/latency without touching the
  request queue.
* **draining** — a draining replica takes no new chunks; its in-flight
  step completes normally. ``close()`` drains the whole fleet: every
  accepted request resolves, exactly like the single runtime.
* **plan hot-swap** — ``swap(new_model)`` rolls a new
  ``ExecutionPlan``/weights across the fleet one replica at a time: the
  candidate is replicated and warmed OFF-path, the replica drains, the
  model pointer flips, the replica returns to ready — accepted requests
  keep completing on the other replicas throughout, so a weight push
  never drops a promise.

Placement is pure policy: ``FleetScheduler.decide(..., busy=mask)``
extends ``Decision`` with a ``replica`` index, chosen from per-replica
sparse/dense step-time EWMAs — so the full fleet decision table replays
deterministically under an injected clock (see ``tests/test_serve.py``).

``pace_fps`` models each replica as a fixed-rate accelerator core (the
paper's deployment unit: one VESTA core sustains ~30 fps): a replica's
step holds the slot for at least ``bucket_rows / pace_fps`` seconds.
Compute still runs — labels are real — but service time is the modeled
core's, so fleet scaling curves measure scheduling and placement rather
than how many host cores a CI runner happens to have. Leave it ``None``
(the default) to serve at raw hardware speed.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from ..infer.compile import replicate_model
from ..infer.engine import (QueueDepthWatermark, Request, StepAccounting,
                            assemble_batch, batch_occupancy, serve_stats,
                            validate_images)
from ..obs.metrics import LatencyHistogram
from ..obs.trace import NULL_TRACER
from ..sharding.rules import replica_devices
from .runtime import AsyncRequest
from .scheduler import FleetScheduler, QueueFull, ServePolicy

# replica lifecycle states (health()/stats() vocabulary)
CREATED, WARMING, READY, DRAINING, STOPPED = (
    "created", "warming", "ready", "draining", "stopped")


class _Replica:
    """One fleet member: a compiled model, a device, a worker, and its
    lifecycle state. All mutable fields are guarded by the fleet's
    condition variable."""

    def __init__(self, idx: int, model, device=None):
        self.idx = idx
        self.model = model
        self.device = device
        self.state = CREATED
        self.steps = 0
        self.failures = 0
        self.swaps = 0
        self.warmup_s: float | None = None
        self.last_step_s: float | None = None
        self.last_probe_s: float | None = None
        self.acct = StepAccounting()
        self._work = None          # (Decision, [(request, image idx), ...])
        self.thread: threading.Thread | None = None

    @property
    def busy(self) -> bool:
        return self.state != READY or self._work is not None


class ServeFleet:
    """N-replica continuous-batching serving — the ``ServeClient``
    protocol (submit / stats / close) over one shared queue and a
    placement-aware scheduler.

        fleet = ServeFleet(model, replicas=4,
                           policy=ServePolicy(slo_ms=100)).start()
        req = fleet.submit(images_u8)       # same door as the runtime
        labels = req.result(timeout=5)
        fleet.swap(new_model)               # roll a new plan, zero drops
        fleet.close()                       # drain: every promise kept

    Determinism contract: per-image math is row-independent and
    bucket-invariant, and every replica runs the same resolved plan
    (``replicate_model`` shares it verbatim), so an identical request
    trace produces bit-identical labels through 1 replica or N.
    """

    def __init__(self, model, *, replicas: int = 1,
                 policy: ServePolicy | None = None,
                 scheduler: FleetScheduler | None = None,
                 devices=None, pace_fps: float | None = None,
                 tracer=None):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas!r}")
        if scheduler is not None and policy is not None:
            raise ValueError("pass either policy or a prebuilt scheduler")
        if pace_fps is not None and pace_fps <= 0:
            raise ValueError(f"pace_fps must be > 0 (or None), got "
                             f"{pace_fps!r}")
        self.model = model          # the template (validation, shapes)
        self.pace_fps = pace_fps
        if scheduler is not None:
            if not hasattr(scheduler, "place"):
                raise ValueError(
                    "fleet scheduler must speak placement (FleetScheduler: "
                    "decide(busy=...) -> Decision.replica)")
            if scheduler.n_replicas != replicas:
                raise ValueError(
                    f"scheduler plans {scheduler.n_replicas} replicas but "
                    f"the fleet has {replicas}")
            self.scheduler = scheduler
        else:
            self.scheduler = FleetScheduler(model.buckets, policy,
                                            n_replicas=replicas)
        if devices is None:
            devices = replica_devices(replicas)
        if len(devices) != replicas:
            raise ValueError(f"{len(devices)} devices for {replicas} "
                             f"replicas")
        self.replicas = [
            _Replica(i, model if dev is None
                     else replicate_model(model, device=dev), device=dev)
            for i, dev in enumerate(devices)]
        self._clock = time.perf_counter
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._cv = threading.Condition()
        self._queue: deque = deque()        # (request, image index)
        self._pending: dict[int, int] = {}  # rid -> images left
        self._inflight: dict[int, AsyncRequest] = {}
        self._next_rid = 0
        self.done: list[AsyncRequest] = []
        self.rejected = 0
        self._queue_depth = QueueDepthWatermark()
        self.latency_hist = LatencyHistogram()
        self.acct = StepAccounting()
        self.failed_requests = 0
        self.swaps = 0
        self._closing = False
        self._stopping = False
        self._started = False
        self._error: BaseException | None = None
        self._dispatcher = threading.Thread(
            target=self._dispatch, daemon=True, name="repro-fleet-dispatch")

    @property
    def queue_depth_peak(self) -> int:
        return self._queue_depth.peak

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "ServeFleet":
        """Warm every replica (compile all buckets off-path), then start
        the dispatcher and replica workers. Idempotent; ``submit``
        auto-starts."""
        with self._cv:
            if self._started:
                return self
            self._started = True
            for rep in self.replicas:
                rep.state = WARMING
        for rep in self.replicas:
            if hasattr(rep.model, "warmup"):
                rep.warmup_s = rep.model.warmup()
        with self._cv:
            for rep in self.replicas:
                rep.state = READY
                rep.thread = threading.Thread(
                    target=self._replica_worker, args=(rep,), daemon=True,
                    name=f"repro-fleet-replica-{rep.idx}")
                rep.thread.start()
            self._dispatcher.start()
            self._cv.notify_all()
        return self

    def close(self, timeout: float | None = None) -> None:
        """Drain the fleet and stop every worker. Every accepted request
        resolves before the last thread exits; new submits are refused the
        moment closing begins. A ``drain_replica``'d replica rejoins the
        pool here — the final drain must be able to dispatch even if the
        caller had drained every replica."""
        with self._cv:
            self._closing = True
            for rep in self.replicas:
                if rep.state == DRAINING:
                    rep.state = READY
            started = self._started
            self._cv.notify_all()
        if not started:
            return
        self._dispatcher.join(timeout)
        for rep in self.replicas:
            if rep.thread is not None:
                rep.thread.join(timeout)

    def __enter__(self) -> "ServeFleet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submit door (identical contract to the runtime) --------------------

    def submit(self, images, *, rid: int | None = None,
               on_image=None) -> AsyncRequest:
        """Queue one request; returns immediately with an ``AsyncRequest``
        whose future resolves to the label list. Same door as
        ``AsyncServeRuntime.submit``: validation here, ``QueueFull`` on
        admission rejection, rid conflicts fail loudly."""
        t_enter = self._clock()
        arr = validate_images(images, self.model.input_shape()[1:])
        tr = self.tracer
        with self._cv:
            if self._error is not None:
                raise RuntimeError(f"fleet died: {self._error!r}")
            if self._closing:
                raise RuntimeError("fleet is closed")
            if rid is None:
                rid = self._next_rid
            if rid in self._pending:
                raise ValueError(f"request id {rid} is already in flight")
            if not self.scheduler.admit(len(self._queue), len(arr)):
                self.rejected += 1
                raise QueueFull(
                    f"queue holds {len(self._queue)} images; admitting "
                    f"{len(arr)} more would exceed max_queue_images="
                    f"{self.scheduler.policy.max_queue_images}")
            self._next_rid = max(self._next_rid, rid + 1)
            req = AsyncRequest(rid=rid, images=arr, on_image=on_image)
            req.t_submit = self._clock()
            req.labels = [None] * len(arr)
            if not len(arr):
                req.t_done = req.t_submit
                self.done.append(req)
                self.latency_hist.observe(0.0)
                if tr.enabled:
                    tr.span("request", "admit", t0=t_enter, t1=req.t_submit,
                            rid=req.rid, value=0)
                    tr.span("request", "complete", t0=req.t_submit,
                            t1=req.t_done, rid=req.rid)
                req.future.set_result([])
                return req
            self._pending[rid] = len(arr)
            self._inflight[rid] = req
            for i in range(len(arr)):
                self._queue.append((req, i))
            self._queue_depth.observe(len(self._queue))
            if tr.enabled:
                tr.span("request", "admit", t0=t_enter, t1=req.t_submit,
                        rid=req.rid, value=len(arr))
                tr.counter("queue_depth", len(self._queue), t=req.t_submit)
            must_start = not self._started
            self._cv.notify_all()
        if must_start:
            self.start()
        return req

    # -- dispatcher ---------------------------------------------------------

    def _dispatch(self) -> None:
        try:
            self._dispatch_loop()
        except BaseException as exc:
            self._abort(exc)
            raise

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    if self._stopping or self._error is not None:
                        return
                    now = self._clock()
                    oldest = (self._queue[0][0].t_submit if self._queue
                              else None)
                    busy = tuple(r.busy for r in self.replicas)
                    d = self.scheduler.decide(
                        backlog=len(self._queue), oldest_submit_s=oldest,
                        now_s=now, draining=self._closing, busy=busy)
                    if d.action == "dispatch":
                        break
                    if self._closing and d.action == "idle":
                        # queue drained; once in-flight steps land, stop
                        if all(r._work is None for r in self.replicas):
                            self._stopping = True
                            self._cv.notify_all()
                            return
                        self._cv.wait()       # a completion notifies
                        continue
                    # "idle": sleep until a submit; "wait": window deadline
                    # or all-replicas-busy — a completion notifies early
                    self._cv.wait(d.wait_s if d.action == "wait" else None)
                work = [self._queue.popleft()
                        for _ in range(min(d.rows, len(self._queue)))]
                rep = self.replicas[d.replica]
                rep._work = (d, work)
                tr = self.tracer
                if tr.enabled:
                    t_pop = self._clock()
                    tr.span("batch", "place", t0=now, t1=t_pop,
                            bucket=d.bucket, replica=d.replica,
                            value=len(work))
                    tr.counter("queue_depth", len(self._queue), t=t_pop)
                    for r, _ in work:
                        if not r.t_dequeue:    # first image leaves queue
                            r.t_dequeue = t_pop
                            tr.span("request", "queue", t0=r.t_submit,
                                    t1=t_pop, rid=r.rid, replica=d.replica)
                self._cv.notify_all()

    # -- replica workers ----------------------------------------------------

    def _replica_worker(self, rep: _Replica) -> None:
        try:
            self._replica_loop(rep)
        except BaseException as exc:
            self._abort(exc)
            raise

    def _replica_loop(self, rep: _Replica) -> None:
        pace = self.pace_fps
        while True:
            with self._cv:
                while rep._work is None and not self._stopping \
                        and self._error is None:
                    self._cv.wait()
                if rep._work is None:          # stopping / aborted
                    rep.state = STOPPED
                    self._cv.notify_all()
                    return
                d, work = rep._work
                model = rep.model
            # model step OUTSIDE the lock: other replicas keep running
            tr = self.tracer
            try:
                t_start = self._clock()
                batch, _ = assemble_batch(
                    [req.images[i] for req, i in work], d.bucket)
                occ = batch_occupancy(batch[:len(work)])  # real rows only
                t0 = self._clock()
                if tr.enabled:
                    tr.span("batch", "assemble", t0=t_start, t1=t0,
                            bucket=d.bucket, replica=rep.idx,
                            occupancy=occ, value=len(work))
                logits = np.asarray(model.step(batch))
                if pace is not None:
                    # emulated fixed-rate core: the slot is held for the
                    # modeled service time (pads cost too, as in hardware)
                    gap = d.bucket / pace - (self._clock() - t0)
                    if gap > 0:
                        time.sleep(gap)
                busy_s = self._clock() - t0
                if tr.enabled:
                    tr.span("batch", "step", t0=t0, t1=t0 + busy_s,
                            bucket=d.bucket, replica=rep.idx,
                            occupancy=occ, value=len(work))
                    tr.counter("occupancy", occ, t=t0, replica=rep.idx)
            except Exception as exc:
                self._fail_batch(rep, work, exc)
                continue
            labels = logits[:len(work)].argmax(axis=-1)
            now = self._clock()
            completed, live = [], []
            with self._cv:
                for (req, i), lab in zip(work, labels):
                    if self._inflight.get(req.rid) is not req:
                        # another replica's step failed this request while
                        # our chunk was in flight: its bookkeeping is purged
                        # and its future already failed — drop our result
                        continue
                    live.append((req, i, int(lab)))
                    req.labels[i] = int(lab)
                    self._pending[req.rid] -= 1
                    if self._pending[req.rid] == 0:
                        del self._pending[req.rid]
                        self._inflight.pop(req.rid, None)
                        req.t_done = now
                        # release the payload; labels/timing/count survive
                        req.images = np.empty((len(req.labels), 0, 0, 0),
                                              np.uint8)
                        self.done.append(req)
                        completed.append(req)
                        self.latency_hist.observe(now - req.t_submit)
                        if tr.enabled:
                            tr.span("request", "complete", t0=req.t_submit,
                                    t1=now, rid=req.rid, replica=rep.idx)
                wall_s = self._clock() - t_start
                self.acct.record_step(rows=len(work), bucket=d.bucket,
                                      busy_s=busy_s, wall_s=wall_s,
                                      occupancy=occ)
                rep.acct.record_step(rows=len(work), bucket=d.bucket,
                                     busy_s=busy_s, wall_s=wall_s,
                                     occupancy=occ)
                rep.steps += 1
                rep.last_step_s = busy_s
                self.scheduler.observe_step(d.bucket, busy_s, occupancy=occ,
                                            replica=rep.idx)
                rep._work = None
                self._cv.notify_all()
            # callbacks/futures OUTSIDE the lock: user code may submit
            for req, i, lab in live:
                if req.on_image is not None:
                    try:
                        req.on_image(req.rid, i, lab)
                    except Exception:
                        pass   # a streaming callback must not kill serving
            for req in completed:
                self._complete_safely(req.future, result=list(req.labels))

    # -- failure containment (same semantics as the runtime) ----------------

    @staticmethod
    def _complete_safely(future, *, result=None, exc=None) -> None:
        try:
            if exc is not None:
                future.set_exception(exc)
            else:
                future.set_result(result)
        except Exception:
            pass   # a cancelled future must never kill a worker

    def _fail_batch(self, rep: _Replica, work, exc: Exception) -> None:
        """A replica's step failed: fail every request with an image in
        that batch (purging their queued remainder), count the replica
        failure, and keep serving."""
        failed = {}
        with self._cv:
            for req, _ in work:
                # purge/count only requests still in flight under their rid:
                # a chunk whose request already failed on ANOTHER replica is
                # purged (and its future failed) there — never twice
                if self._inflight.get(req.rid) is req:
                    failed[req.rid] = req
            if failed:
                self._queue = deque((req, i) for req, i in self._queue
                                    if req.rid not in failed)
                for rid in failed:
                    del self._pending[rid]
                    del self._inflight[rid]
            self.failed_requests += len(failed)
            rep.failures += 1
            rep._work = None
            self._cv.notify_all()
        for req in failed.values():
            self._complete_safely(req.future, exc=exc)

    def _abort(self, exc: BaseException) -> None:
        """Last resort (a bug in fleet bookkeeping): never exit leaving
        accepted futures unresolved."""
        with self._cv:
            self._error = exc
            pending = list(self._inflight.values())
            self._queue.clear()
            self._pending.clear()
            self._inflight.clear()
            self.failed_requests += len(pending)
            self._stopping = True
            for rep in self.replicas:
                rep._work = None
            self._cv.notify_all()
        for req in pending:
            self._complete_safely(
                req.future, exc=RuntimeError(f"fleet died: {exc!r}"))

    # -- replica lifecycle: drain / resume / probe / swap -------------------

    def drain_replica(self, idx: int) -> None:
        """Stop placing new chunks on replica ``idx``; its in-flight step
        completes normally. The rest of the fleet keeps serving."""
        with self._cv:
            rep = self.replicas[idx]
            if rep.state == READY:
                rep.state = DRAINING
            self._cv.notify_all()

    def resume_replica(self, idx: int) -> None:
        """Return a draining replica to the ready pool."""
        with self._cv:
            rep = self.replicas[idx]
            if rep.state == DRAINING:
                rep.state = READY
            self._cv.notify_all()

    def probe(self) -> list:
        """Health probe: one zeros step of the smallest bucket through each
        replica, OFF the request queue (the compiled step is pure, so a
        probe never perturbs serving state). Returns one row per replica:
        state, ok, probe seconds — a stopped/draining replica is reported,
        not probed."""
        rows = []
        for rep in self.replicas:
            with self._cv:
                state, model = rep.state, rep.model
            row = {"replica": rep.idx, "state": state, "ok": False,
                   "probe_s": None}
            if state in (READY, DRAINING):
                try:
                    b = min(model.buckets)
                    t0 = self._clock()
                    out = np.asarray(model.step(
                        np.zeros(model.input_shape(b), np.uint8)))
                    row["probe_s"] = round(self._clock() - t0, 6)
                    row["ok"] = bool(np.isfinite(out).all())
                except Exception as exc:   # a sick replica is a report,
                    row["error"] = repr(exc)   # not a fleet crash
            with self._cv:
                rep.last_probe_s = row["probe_s"]
            rows.append(row)
        return rows

    def health(self) -> dict:
        """The fleet's lifecycle snapshot: per-replica state machine
        position, step/failure/swap counters, and queue pressure."""
        with self._cv:
            return {
                "replicas": [{
                    "replica": r.idx,
                    "state": r.state,
                    "device": None if r.device is None else str(r.device),
                    "steps": r.steps,
                    "failures": r.failures,
                    "swaps": r.swaps,
                    "warmup_s": (None if r.warmup_s is None
                                 else round(r.warmup_s, 4)),
                    "last_step_s": (None if r.last_step_s is None
                                    else round(r.last_step_s, 6)),
                    "last_probe_s": r.last_probe_s,
                    "busy": r.busy,
                } for r in self.replicas],
                "queued_images": len(self._queue),
                "inflight_requests": len(self._inflight),
                "closing": self._closing,
                "swaps": self.swaps,
            }

    def swap(self, new_model, *, timeout: float | None = None) -> None:
        """Hot-swap a new ``ExecutionPlan``/weights across the fleet, one
        replica at a time, WITHOUT dropping accepted requests.

        The contract: ``new_model`` must keep the template's bucket set
        and input shape (the scheduler and every queued request were
        admitted against them — changing shapes mid-queue would break
        promises already made). Per replica: the candidate is replicated
        onto the replica's device and warmed off-path, the replica drains
        (its in-flight step completes, new chunks route elsewhere), the
        model pointer flips, the replica rejoins ready. Requests accepted
        before, during, and after the swap all resolve."""
        if tuple(new_model.buckets) != tuple(self.model.buckets):
            raise ValueError(
                f"hot-swap must keep the bucket set: fleet serves "
                f"{tuple(self.model.buckets)}, new model compiles "
                f"{tuple(new_model.buckets)}")
        if tuple(new_model.input_shape()[1:]) != \
                tuple(self.model.input_shape()[1:]):
            raise ValueError(
                "hot-swap must keep the input shape: queued requests were "
                "validated against the old spec")
        deadline = None if timeout is None else self._clock() + timeout
        for rep in self.replicas:
            # replicate + warm the candidate OFF-path: the replica keeps
            # serving the old plan while the new one compiles
            candidate = (new_model if rep.device is None
                         else replicate_model(new_model, device=rep.device))
            if hasattr(candidate, "warmup"):
                candidate.warmup()
            with self._cv:
                if self._closing or self._error is not None:
                    raise RuntimeError("fleet is closed")
                was = rep.state
                if was == READY:
                    rep.state = DRAINING
                self._cv.notify_all()
                while rep._work is not None:
                    if deadline is not None and self._clock() >= deadline:
                        rep.state = was
                        self._cv.notify_all()
                        raise TimeoutError(
                            f"replica {rep.idx} did not drain in time")
                    self._cv.wait(
                        None if deadline is None
                        else max(1e-3, deadline - self._clock()))
                rep.model = candidate
                rep.swaps += 1
                rep.state = READY if was in (READY, DRAINING) else was
                self._cv.notify_all()
        with self._cv:
            self.model = new_model
            self.swaps += 1

    # -- accounting ---------------------------------------------------------

    def stats(self) -> dict:
        """Fleet serving metrics: the shared ServeClient schema plus the
        per-replica table."""
        with self._cv:
            done = list(self.done)
            acct = dataclasses.replace(self.acct)
            queue_peak = self.queue_depth_peak
            extra = {
                "queued_images": len(self._queue),
                "requests_rejected": self.rejected,
                "requests_failed": self.failed_requests,
                "replicas": len(self.replicas),
                "swaps": self.swaps,
                "pace_fps": self.pace_fps,
                "replica_stats": [{
                    "replica": r.idx,
                    "state": r.state,
                    "steps": r.steps,
                    "images": r.acct.images,
                    "failures": r.failures,
                    "busy_s": round(r.acct.busy_s, 4),
                    "fps": round(r.acct.fps, 2),
                    "occupancy": (None if r.acct.occupancy is None
                                  else round(r.acct.occupancy, 4)),
                } for r in self.replicas],
            }
            slo_s = self.scheduler.policy.slo_s
            if slo_s is not None and done:
                within = sum(1 for r in done if r.latency_s <= slo_s)
                extra["slo_ms"] = self.scheduler.policy.slo_ms
                extra["slo_attainment"] = round(within / len(done), 4)
        return serve_stats(acct=acct, done=done,
                           buckets=self.scheduler.buckets,
                           queue_depth_peak=queue_peak,
                           latency_hist=self.latency_hist, extra=extra)
