"""``AsyncServeRuntime`` — asynchronous continuous batching over a
``CompiledModel``.

The sync ``MicroBatchEngine`` is a drain loop: callers enqueue, then one
thread calls ``run()`` and everything completes before it returns — a
closed loop that can only measure throughput. This runtime is the open-loop
half of the serving story: caller threads ``submit()`` into a bounded
thread-safe queue and immediately get a future back; a single background
worker drives the model's jitted bucket steps, fusing images across
requests exactly like the sync engine (same ``assemble_batch``, same
``StepAccounting``, same pad-minimizing split), and completes each
request's future — with optional per-image streaming callbacks — as
batches finish.

Every scheduling *decision* (wait vs dispatch, admission) is delegated to
``ContinuousBatchingScheduler`` — a pure object tested against an injected
clock — so the thread code here contains no policy, just a condition
variable around the queue.

    model = compile(params, cfg, ExecutionPlan(batch_buckets=(2, 8)))
    with AsyncServeRuntime(model, policy=ServePolicy(max_wait_ms=10,
                                                     slo_ms=100)) as rt:
        req = rt.submit(images_u8)         # returns immediately
        labels = req.result(timeout=5)     # block this caller only
    # closing drains the queue; every accepted request completes

Determinism contract: per-image math is row-independent and bucket-
invariant (the multi-bucket parity contract in ``infer.compile``), so an
identical request trace yields bit-identical labels through this runtime
and the sync engine, regardless of how arrivals happened to batch.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

from ..infer.engine import (QueueDepthWatermark, Request, StepAccounting,
                            assemble_batch, batch_occupancy, serve_stats,
                            validate_images)
from ..obs.metrics import LatencyHistogram
from ..obs.trace import NULL_TRACER
from .scheduler import ContinuousBatchingScheduler, QueueFull, ServePolicy


@dataclasses.dataclass
class AsyncRequest(Request):
    """A ``Request`` plus async completion: a future resolving to the label
    list. The per-image streaming callback ``on_image(rid, index, label)``
    (fired as each image's batch finishes, i.e. possibly before the whole
    request completes) lives on the base ``Request`` — one field, one
    contract, sync and async."""
    future: Future = dataclasses.field(default_factory=Future)

    def result(self, timeout: float | None = None) -> list:
        """Block until every image in this request is classified; returns
        the labels in submit order."""
        return self.future.result(timeout=timeout)


class AsyncServeRuntime:
    """Continuous-batching serving runtime over a ``CompiledModel``.

    Implements the ``ServeClient`` protocol (submit / stats / close).
    Thread-safe ``submit()`` from any number of caller threads; one
    background worker owns the model. ``close()`` (or leaving the context
    manager) drains the queue — every accepted request completes; overload
    is rejected at the door (``QueueFull``), never buffered unboundedly.

    On completion a request's image payload is released (its ``labels``,
    timing, and image COUNT survive) — a long-lived server keeps serving
    history for ``stats()``, not every pixel it ever classified.
    """

    def __init__(self, model, *, policy: ServePolicy | None = None,
                 scheduler: ContinuousBatchingScheduler | None = None,
                 tracer=None):
        if scheduler is not None and policy is not None:
            raise ValueError("pass either policy or a prebuilt scheduler")
        self.model = model
        self.scheduler = (scheduler if scheduler is not None else
                          ContinuousBatchingScheduler(model.buckets, policy))
        # the runtime is wall-clock by design: Condition.wait sleeps real
        # time, so deadlines must be computed on the same clock. Injected
        # clocks (determinism) belong in the pure scheduler, not here —
        # span determinism tests therefore pin the per-request span NAME
        # chain, which is timestamp-free.
        self._clock = time.perf_counter
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._cv = threading.Condition()
        self._queue: deque = deque()        # (request, image index)
        self._pending: dict[int, int] = {}  # rid -> images left
        self._inflight: dict[int, AsyncRequest] = {}   # rid -> request
        self._next_rid = 0
        self.done: list[AsyncRequest] = []
        self.rejected = 0
        self._queue_depth = QueueDepthWatermark()
        self.latency_hist = LatencyHistogram()
        self.acct = StepAccounting()
        self._closing = False
        self._started = False
        self._worker_error: BaseException | None = None
        self.failed_requests = 0
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="repro-serve-worker")

    @property
    def queue_depth_peak(self) -> int:
        return self._queue_depth.peak

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "AsyncServeRuntime":
        """Start the worker thread (idempotent; ``submit`` auto-starts)."""
        with self._cv:
            if not self._started:
                self._started = True
                self._thread.start()
        return self

    def close(self, timeout: float | None = None) -> None:
        """Drain the queue and stop the worker. Every accepted request's
        future completes before the worker exits; new submits are refused
        the moment closing begins."""
        with self._cv:
            self._closing = True
            started = self._started
            self._cv.notify_all()
        if started:
            self._thread.join(timeout)

    def __enter__(self) -> "AsyncServeRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submit door --------------------------------------------------------

    def submit(self, images, *, rid: int | None = None,
               on_image=None) -> AsyncRequest:
        """Queue one request; returns immediately with an ``AsyncRequest``
        whose future resolves to the label list.

        Raises ``ValueError`` for malformed images (validated against the
        compiled model's input spec right here, not inside a jitted step),
        ``ValueError`` for an rid already in flight, and ``QueueFull`` when
        admission control rejects the request (bounded queue — the caller
        sheds or retries; nothing is silently buffered).
        """
        t_enter = self._clock()
        arr = validate_images(images, self.model.input_shape()[1:])
        tr = self.tracer
        with self._cv:
            if self._worker_error is not None:
                raise RuntimeError(
                    f"serve worker died: {self._worker_error!r}")
            if self._closing:
                raise RuntimeError("runtime is closed")
            if rid is None:
                rid = self._next_rid
            if rid in self._pending:
                raise ValueError(f"request id {rid} is already in flight")
            if not self.scheduler.admit(len(self._queue), len(arr)):
                self.rejected += 1
                raise QueueFull(
                    f"queue holds {len(self._queue)} images; admitting "
                    f"{len(arr)} more would exceed max_queue_images="
                    f"{self.scheduler.policy.max_queue_images}")
            self._next_rid = max(self._next_rid, rid + 1)
            req = AsyncRequest(rid=rid, images=arr, on_image=on_image)
            req.t_submit = self._clock()
            req.labels = [None] * len(arr)
            if not len(arr):
                # empty request: complete immediately, still counted
                req.t_done = req.t_submit
                self.done.append(req)
                self.latency_hist.observe(0.0)
                if tr.enabled:
                    tr.span("request", "admit", t0=t_enter, t1=req.t_submit,
                            rid=req.rid, value=0)
                    tr.span("request", "complete", t0=req.t_submit,
                            t1=req.t_done, rid=req.rid)
                req.future.set_result([])
                return req
            self._pending[rid] = len(arr)
            self._inflight[rid] = req
            for i in range(len(arr)):
                self._queue.append((req, i))
            self._queue_depth.observe(len(self._queue))
            if tr.enabled:
                tr.span("request", "admit", t0=t_enter, t1=req.t_submit,
                        rid=req.rid, value=len(arr))
                tr.counter("queue_depth", len(self._queue), t=req.t_submit)
            if not self._started:
                self._started = True
                self._thread.start()
            self._cv.notify_all()
        return req

    # -- worker -------------------------------------------------------------

    @staticmethod
    def _complete_safely(future: Future, *, result=None, exc=None) -> None:
        """Resolve a future, tolerating a caller who already cancelled it —
        a cancelled future must never kill the worker thread."""
        try:
            if exc is not None:
                future.set_exception(exc)
            else:
                future.set_result(result)
        except Exception:
            pass

    def _fail_batch(self, work, exc: Exception) -> None:
        """A model step failed: fail every request with an image in that
        batch (purging their remaining queued images) so their futures
        RAISE instead of blocking forever; serving continues for everyone
        else."""
        failed = {}
        with self._cv:
            for req, _ in work:
                failed.setdefault(req.rid, req)
            self._queue = deque((req, i) for req, i in self._queue
                                if req.rid not in failed)
            for rid in failed:
                self._pending.pop(rid, None)
                self._inflight.pop(rid, None)
            self.failed_requests += len(failed)
        for req in failed.values():
            self._complete_safely(req.future, exc=exc)

    def _abort(self, exc: BaseException) -> None:
        """Last resort (a bug in the worker's own bookkeeping): never exit
        leaving accepted futures unresolved — fail everything pending and
        refuse further submits."""
        with self._cv:
            self._worker_error = exc
            # EVERY in-flight request, including the popped batch the worker
            # was holding when it died — not just what is still queued
            pending = list(self._inflight.values())
            self._queue.clear()
            self._pending.clear()
            self._inflight.clear()
            self.failed_requests += len(pending)
        for req in pending:
            self._complete_safely(
                req.future, exc=RuntimeError(f"serve worker died: {exc!r}"))

    def _worker(self) -> None:
        try:
            self._worker_loop()
        except BaseException as exc:
            self._abort(exc)
            raise

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    now = self._clock()
                    oldest = (self._queue[0][0].t_submit if self._queue
                              else None)
                    d = self.scheduler.decide(
                        backlog=len(self._queue), oldest_submit_s=oldest,
                        now_s=now, draining=self._closing)
                    if d.action == "dispatch":
                        break
                    if self._closing:      # idle + closing: queue is drained
                        return
                    # "idle": sleep until a submit; "wait": until the window
                    # deadline (a submit may re-open a better decision first)
                    self._cv.wait(d.wait_s if d.action == "wait" else None)
                work = [self._queue.popleft()
                        for _ in range(min(d.rows, len(self._queue)))]
                tr = self.tracer
                if tr.enabled:
                    t_pop = self._clock()
                    tr.span("batch", "place", t0=now, t1=t_pop,
                            bucket=d.bucket, value=len(work))
                    tr.counter("queue_depth", len(self._queue), t=t_pop)
                    for req, _ in work:
                        if not req.t_dequeue:   # first image leaves queue
                            req.t_dequeue = t_pop
                            tr.span("request", "queue", t0=req.t_submit,
                                    t1=t_pop, rid=req.rid)
            # model step OUTSIDE the lock: submits stay concurrent
            try:
                t_start = self._clock()
                batch, _ = assemble_batch([req.images[i] for req, i in work],
                                          d.bucket)
                occ = batch_occupancy(batch[:len(work)])  # real rows only
                t0 = self._clock()
                if tr.enabled:
                    tr.span("batch", "assemble", t0=t_start, t1=t0,
                            bucket=d.bucket, occupancy=occ, value=len(work))
                logits = np.asarray(self.model.step(batch))
                busy_s = self._clock() - t0
                if tr.enabled:
                    tr.span("batch", "step", t0=t0, t1=t0 + busy_s,
                            bucket=d.bucket, occupancy=occ, value=len(work))
                    tr.counter("occupancy", occ, t=t0)
            except Exception as exc:
                self._fail_batch(work, exc)
                continue
            labels = logits[:len(work)].argmax(axis=-1)
            now = self._clock()
            completed = []
            with self._cv:
                for (req, i), lab in zip(work, labels):
                    req.labels[i] = int(lab)
                    self._pending[req.rid] -= 1
                    if self._pending[req.rid] == 0:
                        del self._pending[req.rid]   # rid leaves "in flight"
                        self._inflight.pop(req.rid, None)
                        req.t_done = now
                        # release the image payload (labels/timing stay for
                        # stats): a long-lived server must not accumulate
                        # every served pixel. Shape keeps the image COUNT so
                        # len(req.images) still matches len(req.labels).
                        req.images = np.empty((len(req.labels), 0, 0, 0),
                                              np.uint8)
                        self.done.append(req)
                        completed.append(req)
                        self.latency_hist.observe(now - req.t_submit)
                        if tr.enabled:
                            tr.span("request", "complete", t0=req.t_submit,
                                    t1=now, rid=req.rid)
                self.acct.record_step(rows=len(work), bucket=d.bucket,
                                      busy_s=busy_s,
                                      wall_s=self._clock() - t_start,
                                      occupancy=occ)
                self.scheduler.observe_step(d.bucket, busy_s, occupancy=occ)
            # callbacks/futures OUTSIDE the lock: user code may submit
            for (req, i), lab in zip(work, labels):
                if req.on_image is not None:
                    try:
                        req.on_image(req.rid, i, int(lab))
                    except Exception:
                        pass   # a streaming callback must not kill serving
            for req in completed:
                self._complete_safely(req.future, result=list(req.labels))

    # -- accounting ---------------------------------------------------------

    def stats(self) -> dict:
        """Serving metrics over everything processed so far (thread-safe).

        ``fps`` here is service capacity (images per second of step wall
        time); arrival-bounded numbers — goodput, SLO attainment under a
        real arrival process — come from ``repro.serve.loadgen``.
        """
        with self._cv:
            done = list(self.done)
            rejected = self.rejected
            failed = self.failed_requests
            queued = len(self._queue)
            queue_peak = self.queue_depth_peak
            acct = dataclasses.replace(self.acct)
        extra = {
            "queued_images": queued,
            "requests_rejected": rejected,    # loadgen's spelling: one
            "requests_failed": failed,        # vocabulary across reporters
        }
        slo_s = self.scheduler.policy.slo_s
        if slo_s is not None and done:
            within = sum(1 for r in done if r.latency_s <= slo_s)
            extra["slo_ms"] = self.scheduler.policy.slo_ms
            extra["slo_attainment"] = round(within / len(done), 4)
        return serve_stats(acct=acct, done=done,
                           buckets=self.scheduler.buckets,
                           queue_depth_peak=queue_peak,
                           latency_hist=self.latency_hist, extra=extra)
