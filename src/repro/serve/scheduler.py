"""Continuous-batching policy: wait-vs-dispatch and admission control as a
pure, separately-testable object.

The async runtime (``repro.serve.runtime``) owns threads, queues and
futures; every *decision* lives here, in methods that take the observable
state (backlog, oldest submit time, the current clock reading) as explicit
arguments and return a ``Decision`` value. Nothing in this module reads a
wall clock or sleeps, so a test can replay any schedule deterministically
and pin the full decision table.

The policy triangle:

* **Batching window** — a lone request is not dispatched the instant it
  arrives; waiting up to ``max_wait_ms`` lets later arrivals fill the
  bucket and amortize the step. The dispatch shape is the FIRST chunk of
  the pad-minimizing split the compiled model itself would run
  (``repro.infer.compile.plan_chunks`` — the same function, not a copy),
  so a backlog of 3 over buckets (2, 8) dispatches 2 now and leaves 1 to
  keep accumulating.
* **SLO pressure** — with ``slo_ms`` set, the window closes early: the
  oldest request must leave enough of its budget to actually run the step,
  estimated from an EWMA of observed per-bucket step times
  (``observe_step``). A scheduler that batches greedily but blows the
  latency target has optimized the wrong number.
* **Admission control** — ``admit()`` bounds the queue at
  ``max_queue_images``; overload is an explicit, accounted rejection
  (``QueueFull`` at the submit door), never silent unbounded growth.
"""
from __future__ import annotations

import dataclasses

from ..infer.compile import plan_chunks


class QueueFull(RuntimeError):
    """Admission control rejected a submit: the bounded queue is full.

    Raised at the submit door — the caller sheds or retries; the runtime
    never buffers beyond the configured depth.
    """


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    """The scheduler's knobs, all decided before serving starts.

    ``max_wait_ms`` — batching window: how long the oldest queued request
    may wait for companions before a (possibly padded) dispatch is forced.
    ``slo_ms`` — per-request latency target; ``None`` disables SLO pressure
    (the window is then bounded by ``max_wait_ms`` alone).
    ``max_queue_images`` — admission bound on queued images.
    ``sparse_occupancy`` — spike-occupancy threshold splitting observed
    step times into a "sparse" and a "dense" EWMA per bucket (a sparse
    batch through the zero-chunk-skipping route is measurably cheaper, and
    folding both populations into one EWMA makes the SLO deadline wrong
    for whichever class is current); ``None`` disables the split.
    """
    max_wait_ms: float = 25.0
    slo_ms: float | None = None
    max_queue_images: int = 512
    sparse_occupancy: float | None = 0.35

    def __post_init__(self):
        if self.max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got "
                             f"{self.max_wait_ms!r}")
        if self.slo_ms is not None and self.slo_ms <= 0:
            raise ValueError(f"slo_ms must be > 0 (or None), got "
                             f"{self.slo_ms!r}")
        if self.max_queue_images < 1:
            raise ValueError(f"max_queue_images must be >= 1, got "
                             f"{self.max_queue_images!r}")
        if (self.sparse_occupancy is not None
                and not 0.0 < self.sparse_occupancy <= 1.0):
            raise ValueError(f"sparse_occupancy must be in (0, 1] (or "
                             f"None), got {self.sparse_occupancy!r}")

    @property
    def max_wait_s(self) -> float:
        return self.max_wait_ms / 1e3

    @property
    def slo_s(self) -> float | None:
        return None if self.slo_ms is None else self.slo_ms / 1e3


@dataclasses.dataclass(frozen=True)
class Decision:
    """One scheduling decision, as a value.

    ``action`` is "idle" (nothing queued — sleep until a submit),
    "wait" (keep the batching window open for ``wait_s`` more seconds),
    or "dispatch" (run ``rows`` real rows in a ``bucket``-shaped step now).
    ``reason`` names the rule that fired — it surfaces in logs and pins the
    decision table in tests.

    ``replica`` is the placement extension (``FleetScheduler``): which
    replica runs a dispatched chunk. ``None`` means "the caller's only
    worker" — the single-runtime decisions are unchanged values.
    """
    action: str
    bucket: int = 0
    rows: int = 0
    wait_s: float = 0.0
    reason: str = ""
    replica: int | None = None


class ContinuousBatchingScheduler:
    """Wait-vs-dispatch policy over a compiled model's bucket set.

    Construct from the bucket tuple (``model.buckets``) and a
    ``ServePolicy``. All methods are deterministic functions of their
    arguments and the observed step-time EWMAs — no hidden clock.
    """

    def __init__(self, buckets, policy: ServePolicy | None = None):
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"buckets must be >= 1, got {buckets!r}")
        self.policy = policy or ServePolicy()
        self._step_s: dict[int, float] = {}   # bucket -> EWMA step seconds
        # (bucket, "sparse"|"dense") -> EWMA step seconds, fed only when
        # the runtime measures batch occupancy; the overall per-bucket
        # EWMA above always updates, so the class split can only refine
        self._class_step_s: dict[tuple, float] = {}
        self._occ_ewma: float | None = None   # EWMA of observed occupancy

    # -- admission ----------------------------------------------------------

    def admit(self, queued_images: int, new_images: int) -> bool:
        """May a request of ``new_images`` enter a queue currently holding
        ``queued_images``? Pure bound check; the runtime turns False into
        an explicit ``QueueFull`` at the submit door."""
        return queued_images + new_images <= self.policy.max_queue_images

    # -- service-time model -------------------------------------------------

    def _occupancy_class(self, occupancy: float) -> str | None:
        """"sparse" or "dense" under the policy threshold, ``None`` when
        the split is disabled."""
        thr = self.policy.sparse_occupancy
        if thr is None:
            return None
        return "sparse" if occupancy < thr else "dense"

    def observe_step(self, bucket: int, seconds: float,
                     occupancy: float | None = None) -> None:
        """Feed one measured step time into the per-bucket EWMA the SLO
        deadline uses. The runtime calls this after every step; when it
        also measured the batch's spike occupancy, the sample additionally
        updates the (bucket, sparse|dense) class EWMA so the deadline can
        condition on how cheap the current traffic actually is."""
        prev = self._step_s.get(bucket)
        self._step_s[bucket] = (seconds if prev is None
                                else 0.8 * prev + 0.2 * seconds)
        if occupancy is None:
            return
        self._occ_ewma = (occupancy if self._occ_ewma is None
                          else 0.8 * self._occ_ewma + 0.2 * occupancy)
        cls = self._occupancy_class(occupancy)
        if cls is not None:
            key = (bucket, cls)
            prev = self._class_step_s.get(key)
            self._class_step_s[key] = (seconds if prev is None
                                       else 0.8 * prev + 0.2 * seconds)

    def service_estimate(self, bucket: int,
                         occupancy: float | None = None) -> float:
        """Expected step seconds for ``bucket``: the (bucket, class) EWMA
        when an occupancy is given (or the running occupancy EWMA stands
        in) and that class has been observed; else the bucket's overall
        EWMA; else the slowest observed bucket (conservative —
        over-estimating dispatches earlier, never later); else 0 (no data:
        only ``max_wait_ms`` bounds the window)."""
        occ = occupancy if occupancy is not None else self._occ_ewma
        if occ is not None:
            cls = self._occupancy_class(occ)
            if cls is not None and (bucket, cls) in self._class_step_s:
                return self._class_step_s[(bucket, cls)]
        if bucket in self._step_s:
            return self._step_s[bucket]
        if self._step_s:
            return max(self._step_s.values())
        return 0.0

    def service_snapshot(self) -> dict:
        """The observed per-bucket step-second EWMAs, ``{bucket: seconds}``
        — the service-time model a deterministic decision replay
        (``repro.serve.loadgen.replay_decisions``) can feed back in, so a
        simulated table uses the service times a live run actually
        measured. A copy: mutating it never touches the live policy."""
        return dict(self._step_s)

    def debug_state(self) -> dict:
        """EVERY table behind the wait-vs-dispatch decision, as plain data
        — the inspectability hook for "why did the window close here?".
        Keys mirror the internal tables: ``step_s`` is ``{bucket: EWMA
        seconds}``, ``class_step_s`` is ``{"<bucket>/<sparse|dense>":
        EWMA seconds}`` (string keys: this dict feeds JSON debug
        endpoints and gauge names), ``occupancy_ewma`` the running
        occupancy estimate (``None`` before any measured step). A copy —
        mutating it never touches the live policy."""
        return {
            "buckets": list(self.buckets),
            "step_s": dict(self._step_s),
            "class_step_s": {f"{b}/{cls}": v for (b, cls), v
                             in self._class_step_s.items()},
            "occupancy_ewma": self._occ_ewma,
        }

    def publish(self, registry, *, prefix: str = "scheduler/") -> None:
        """Publish ``debug_state()`` into a ``repro.obs.MetricsRegistry``
        as gauges (``scheduler/step_s/<bucket>``, ``scheduler/
        class_step_s/<bucket>/<class>``, ...). Generic over the snapshot
        shape, so ``FleetScheduler``'s extra replica tables publish
        through this same method."""
        for section, table in self.debug_state().items():
            if section == "buckets":
                continue
            if isinstance(table, dict):
                for key, v in table.items():
                    registry.gauge(f"{prefix}{section}/{key}").set(float(v))
            elif table is not None:
                registry.gauge(f"{prefix}{section}").set(float(table))

    # -- the decision -------------------------------------------------------

    def decide(self, *, backlog: int, oldest_submit_s: float | None,
               now_s: float, draining: bool = False) -> Decision:
        """The wait-vs-dispatch decision for the current queue state.

        ``backlog`` is queued images, ``oldest_submit_s`` the submit
        timestamp of the request at the head of the queue (same clock as
        ``now_s``). ``draining=True`` (runtime shutdown) closes the
        batching window: anything queued dispatches immediately in its
        pad-minimizing shape.
        """
        if backlog <= 0:
            return Decision(action="idle", reason="queue empty")
        bmax = self.buckets[-1]
        if backlog >= bmax:
            # a full largest bucket never waits: zero pad, max amortization
            return Decision(action="dispatch", bucket=bmax, rows=bmax,
                            reason="backlog fills the largest bucket")
        rows, bucket = plan_chunks(backlog, self.buckets)[0]
        if draining:
            return Decision(action="dispatch", bucket=bucket, rows=rows,
                            reason="draining")
        if oldest_submit_s is None:
            raise ValueError("non-empty backlog requires oldest_submit_s")
        deadline = oldest_submit_s + self.policy.max_wait_s
        reason = "max_wait deadline reached"
        if self.policy.slo_s is not None:
            # Leave the oldest request enough budget to actually run — over
            # the WHOLE pad-minimizing split, not just the first chunk: the
            # oldest request's last image may land in the final chunk of a
            # multi-chunk backlog, so its completion pays every step in the
            # split, and reserving one step's worth under-budgets the rest.
            est = sum(self.service_estimate(b)
                      for _, b in plan_chunks(backlog, self.buckets))
            slo_deadline = oldest_submit_s + self.policy.slo_s - est
            if slo_deadline < deadline:
                deadline, reason = slo_deadline, "SLO pressure"
        if now_s >= deadline:
            return Decision(action="dispatch", bucket=bucket, rows=rows,
                            reason=reason)
        return Decision(action="wait", wait_s=deadline - now_s,
                        reason=f"batching window open ({reason.split()[0]} "
                               f"deadline in {deadline - now_s:.4f}s)")


class FleetScheduler(ContinuousBatchingScheduler):
    """Wait-vs-dispatch PLUS placement over ``n_replicas`` workers.

    Same pure contract as the base scheduler — every method is a
    deterministic function of its arguments and the observed EWMAs, so a
    fleet's full decision table (including which replica got which bucket
    chunk) replays under an injected clock. Placement policy:

    * each replica keeps its OWN per-bucket and per-(bucket, sparse|dense)
      step-time EWMAs, fed by ``observe_step(..., replica=i)`` — replicas
      on different devices (or a replica mid-degradation) have genuinely
      different service times, and one global estimate would route batches
      to whichever replica happened to be measured last;
    * ``place()`` sends a chunk to the FREE replica whose class-conditioned
      estimate for that bucket is lowest (ties break on the lowest index,
      keeping the table deterministic) — under sparse/dense SLO pressure
      that is the replica whose estimate meets the deadline;
    * when every replica is busy, ``decide()`` returns a bounded "wait"
      instead of a dispatch nobody can run; a completion re-opens the
      decision (the fleet's condition variable wakes the dispatcher).
    """

    def __init__(self, buckets, policy: ServePolicy | None = None, *,
                 n_replicas: int = 1):
        super().__init__(buckets, policy)
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas!r}")
        self.n_replicas = int(n_replicas)
        self._replica_step_s: dict[tuple, float] = {}   # (replica, bucket)
        # (replica, bucket, "sparse"|"dense") -> EWMA step seconds
        self._replica_class_step_s: dict[tuple, float] = {}

    def observe_step(self, bucket: int, seconds: float,
                     occupancy: float | None = None,
                     replica: int | None = None) -> None:
        """Feed one measured step: the global EWMAs (SLO pressure budgets
        the whole split regardless of where chunks ran) AND, when
        ``replica`` is named, that replica's own estimates."""
        super().observe_step(bucket, seconds, occupancy=occupancy)
        if replica is None:
            return
        key = (replica, bucket)
        prev = self._replica_step_s.get(key)
        self._replica_step_s[key] = (seconds if prev is None
                                     else 0.8 * prev + 0.2 * seconds)
        if occupancy is None:
            return
        cls = self._occupancy_class(occupancy)
        if cls is not None:
            ckey = (replica, bucket, cls)
            prev = self._replica_class_step_s.get(ckey)
            self._replica_class_step_s[ckey] = (
                seconds if prev is None else 0.8 * prev + 0.2 * seconds)

    def debug_state(self) -> dict:
        """The base tables plus the per-replica EWMAs placement reads:
        ``replica_step_s`` is ``{"<replica>/<bucket>": seconds}``,
        ``replica_class_step_s`` ``{"<replica>/<bucket>/<class>":
        seconds}``."""
        return {
            **super().debug_state(),
            "n_replicas": self.n_replicas,
            "replica_step_s": {f"{r}/{b}": v for (r, b), v
                               in self._replica_step_s.items()},
            "replica_class_step_s": {
                f"{r}/{b}/{cls}": v for (r, b, cls), v
                in self._replica_class_step_s.items()},
        }

    def replica_estimate(self, replica: int, bucket: int,
                         occupancy: float | None = None) -> float:
        """Expected step seconds for ``bucket`` ON ``replica``: the
        replica's (bucket, class) EWMA when an occupancy (or the running
        occupancy EWMA) selects an observed class, else the replica's
        bucket EWMA, else the fleet-wide ``service_estimate`` (a fresh or
        freshly-swapped replica borrows the fleet's estimate until it has
        history of its own)."""
        occ = occupancy if occupancy is not None else self._occ_ewma
        if occ is not None:
            cls = self._occupancy_class(occ)
            if cls is not None and (replica, bucket, cls) in \
                    self._replica_class_step_s:
                return self._replica_class_step_s[(replica, bucket, cls)]
        if (replica, bucket) in self._replica_step_s:
            return self._replica_step_s[(replica, bucket)]
        return self.service_estimate(bucket, occupancy)

    def place(self, bucket: int, *, busy, occupancy: float | None = None) \
            -> int | None:
        """The free replica with the lowest class-conditioned estimate for
        ``bucket`` (lowest index on ties); ``None`` when ``busy`` masks
        every replica."""
        free = [i for i in range(self.n_replicas) if not busy[i]]
        if not free:
            return None
        return min(free, key=lambda i: (self.replica_estimate(i, bucket,
                                                              occupancy), i))

    def decide(self, *, backlog: int, oldest_submit_s: float | None,
               now_s: float, draining: bool = False, busy=None) -> Decision:
        """The base wait-vs-dispatch decision, with a dispatch placed onto
        a replica. ``busy`` is the per-replica busy mask (default: all
        free). A dispatch with nowhere to run becomes a bounded wait —
        never a silent queue on a busy replica the policy did not pick."""
        d = super().decide(backlog=backlog, oldest_submit_s=oldest_submit_s,
                           now_s=now_s, draining=draining)
        if d.action != "dispatch":
            return d
        busy = (False,) * self.n_replicas if busy is None else tuple(busy)
        if len(busy) != self.n_replicas:
            raise ValueError(f"busy mask has {len(busy)} entries for "
                             f"{self.n_replicas} replicas")
        r = self.place(d.bucket, busy=busy)
        if r is None:
            return Decision(action="wait",
                            wait_s=max(self.policy.max_wait_s, 1e-3),
                            reason="all replicas busy")
        return dataclasses.replace(d, replica=r)
