"""Unified-PE Pallas kernel: packed binary planes x shared 8-bit weights.

This is VESTA's PE module mapped to the TPU. A PE unit = one 8-bit weight
shared by 8 binary inputs; here a *byte* of the packed activation tensor holds
those 8 binary planes, and one VMEM-resident weight tile serves all of them
(weight-stationary). Two reduction modes select the dataflow:

  mode="per_plane"  (WSSL / ZSC / STDP operands):
      Y[p] = S_p @ W  for p = 0..7        -> out (8, M, N)
      The 8 planes are *folded into the row dimension* of a single MXU dot —
      the TPU analogue of "all timesteps computed simultaneously".

  mode="shift_sum"  (SSSC):
      Y = sum_p 2^p * (S_p @ W)           -> out (M, N)
      The scaled combine happens at unpack time (sum_p 2^p S_p == the uint8
      value), so the MXU sees ONE dot instead of eight — a TPU-native
      improvement over the paper's 8-pass shift-and-sum, with identical math.

Memory win vs dense activations: the HBM->VMEM stream of S is 1 bit/plane
(uint8 carries 8 planes) instead of 8-32 bits — the same 8x traffic reduction
the paper gets from its Small-Input/Output SRAMs.

Grid: (M/bm, N/bn, K/bk), K innermost; f32 accumulator tile in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, mode: str, nk: int):
    """x_ref: (bm, bk) uint8 packed; w_ref: (bk, bn); o_ref: (8,bm,bn)|(bm,bn)."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    if mode == "per_plane":
        # (bm, bk) uint8 -> (8, bm, bk) bits -> (8*bm, bk) rows -> one MXU dot
        bits = (x[None, :, :] >> jnp.arange(8, dtype=jnp.uint8)[:, None, None]
                ) & jnp.uint8(1)
        planes = bits.reshape(8 * bm, bk).astype(jnp.float32)
        part = jnp.dot(planes, w, preferred_element_type=jnp.float32)
        acc_ref[...] += part.reshape(8, bm, w.shape[-1])
    else:  # shift_sum: the byte IS sum_p 2^p S_p — combine before the dot
        val = x.astype(jnp.float32)
        acc_ref[...] += jnp.dot(val, w, preferred_element_type=jnp.float32)

    @pl.when(k_step == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk", "interpret"))
def spike_matmul(x_packed, w, *, mode: str = "per_plane",
                 bm: int = 128, bn: int = 128, bk: int = 256,
                 interpret: bool = True):
    """x_packed: (M, K) uint8 (bit p of [m,k] = plane p's spike); w: (K, N).

    Returns (8, M, N) for mode="per_plane", (M, N) for mode="shift_sum".
    Shapes are padded to block multiples internally.
    """
    assert mode in ("per_plane", "shift_sum"), mode
    m, k = x_packed.shape
    k2, n = w.shape
    assert k == k2, (x_packed.shape, w.shape)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    if pm or pk:
        x_packed = jnp.pad(x_packed, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    mp, kp = x_packed.shape
    np_ = w.shape[1]
    grid = (mp // bm_, np_ // bn_, kp // bk_)

    if mode == "per_plane":
        out_shape = jax.ShapeDtypeStruct((8, mp, np_), jnp.float32)
        out_spec = pl.BlockSpec((8, bm_, bn_), lambda i, j, kk: (0, i, j))
        acc = pltpu.VMEM((8, bm_, bn_), jnp.float32)
    else:
        out_shape = jax.ShapeDtypeStruct((mp, np_), jnp.float32)
        out_spec = pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j))
        acc = pltpu.VMEM((bm_, bn_), jnp.float32)

    y = pl.pallas_call(
        functools.partial(_kernel, mode=mode, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[acc],
        interpret=interpret,
    )(x_packed, w)

    if mode == "per_plane":
        return y[:, :m, :n]
    return y[:m, :n]
