"""Unified-PE Pallas kernel: packed binary planes x shared 8-bit weights.

This is VESTA's PE module mapped to the TPU. A PE unit = one 8-bit weight
shared by 8 binary inputs; here a *byte* of the packed activation tensor holds
those 8 binary planes, and one VMEM-resident weight tile serves all of them
(weight-stationary). Two reduction modes select the dataflow:

  mode="per_plane"  (WSSL / ZSC / STDP operands):
      Y[p] = S_p @ W  for p = 0..7        -> out (8, M, N)
      The 8 planes are *folded into the row dimension* of a single MXU dot —
      the TPU analogue of "all timesteps computed simultaneously".

  mode="shift_sum"  (SSSC):
      Y = sum_p 2^p * (S_p @ W)           -> out (M, N)
      The scaled combine happens at unpack time (sum_p 2^p S_p == the uint8
      value), so the MXU sees ONE dot instead of eight — a TPU-native
      improvement over the paper's 8-pass shift-and-sum, with identical math.

Memory win vs dense activations: the HBM->VMEM stream of S is 1 bit/plane
(uint8 carries 8 planes) instead of 8-32 bits — the same 8x traffic reduction
the paper gets from its Small-Input/Output SRAMs.

Grid: (M/bm, N/bn, K/bk), K innermost; f32 accumulator tile in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, mode: str, nk: int,
            k_dim: int = 2):
    """x_ref: (bm, bk) uint8 packed (or (1, bm, bk) in the grouped grid);
    w_ref: (bk, bn); o_ref: (8,bm,bn) | (bm,bn) | (1,8,bm,bn) grouped."""
    k_step = pl.program_id(k_dim)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if x.ndim == 3:                     # grouped grid: squeeze the g block dim
        x = x[0]
    w = w_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    if mode == "per_plane":
        # (bm, bk) uint8 -> (8, bm, bk) bits -> (8*bm, bk) rows -> one MXU dot
        bits = (x[None, :, :] >> jnp.arange(8, dtype=jnp.uint8)[:, None, None]
                ) & jnp.uint8(1)
        planes = bits.reshape(8 * bm, bk).astype(jnp.float32)
        part = jnp.dot(planes, w, preferred_element_type=jnp.float32)
        acc_ref[...] += part.reshape(8, bm, w.shape[-1])
    else:  # shift_sum: the byte IS sum_p 2^p S_p — combine before the dot
        val = x.astype(jnp.float32)
        acc_ref[...] += jnp.dot(val, w, preferred_element_type=jnp.float32)

    @pl.when(k_step == nk - 1)
    def _done():
        acc = acc_ref[...].astype(o_ref.dtype)
        o_ref[...] = acc if o_ref.ndim == acc.ndim else acc[None]


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk", "interpret"))
def spike_matmul(x_packed, w, *, mode: str = "per_plane",
                 bm: int = 128, bn: int = 128, bk: int = 256,
                 interpret: bool = True):
    """x_packed: (M, K) uint8 (bit p of [m,k] = plane p's spike) or, for
    mode="per_plane" only, (G, M, K) plane groups; w: (K, N).

    Returns (8, M, N) for mode="per_plane" [(G, 8, M, N) grouped], (M, N) for
    mode="shift_sum". Shapes are padded to block multiples internally.

    Grouped route: the plane-group axis becomes the outermost grid dimension,
    so each (bk, bn) weight tile streamed into VMEM serves all 8 planes of a
    group before the grid advances — the weight-stationary property is per
    group of 8, exactly the VESTA PE contract.
    """
    assert mode in ("per_plane", "shift_sum"), mode
    if x_packed.ndim == 3:
        assert mode == "per_plane", "plane groups are temporal: per_plane only"
        return _spike_matmul_grouped(x_packed, w, bm=bm, bn=bn, bk=bk,
                                     interpret=interpret)
    m, k = x_packed.shape
    k2, n = w.shape
    assert k == k2, (x_packed.shape, w.shape)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    if pm or pk:
        x_packed = jnp.pad(x_packed, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    mp, kp = x_packed.shape
    np_ = w.shape[1]
    grid = (mp // bm_, np_ // bn_, kp // bk_)

    if mode == "per_plane":
        out_shape = jax.ShapeDtypeStruct((8, mp, np_), jnp.float32)
        out_spec = pl.BlockSpec((8, bm_, bn_), lambda i, j, kk: (0, i, j))
        acc = pltpu.VMEM((8, bm_, bn_), jnp.float32)
    else:
        out_shape = jax.ShapeDtypeStruct((mp, np_), jnp.float32)
        out_spec = pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j))
        acc = pltpu.VMEM((bm_, bn_), jnp.float32)

    y = pl.pallas_call(
        functools.partial(_kernel, mode=mode, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[acc],
        interpret=interpret,
    )(x_packed, w)

    if mode == "per_plane":
        return y[:, :m, :n]
    return y[:m, :n]


def gather256(tbl_c, idx_col, acc_dtype):
    """Gather one LUT chunk inside a kernel: ``tbl_c`` (256, bn) partial
    sums, ``idx_col`` (bm,) uint8 index bytes -> (bm, bn) gathered rows.

    Implemented as a one-hot matmul rather than a dynamic gather — the MXU
    has no gather unit, but a (bm, 256) one-hot against the VMEM-resident
    table IS the multiplexer select of VESTA's PE, and it is *exact in any
    reduction order*: 255 of the 256 products per output element are exact
    zeros (0 * v and 1 * v are both exact in IEEE), so the sum equals the
    selected table entry bit for bit regardless of how the hardware
    associates it (up to the sign of a zero, which ``==`` ignores).
    Integer tables accumulate in int32, exactly as the CPU gather.
    """
    iota = lax.broadcasted_iota(jnp.int32, (idx_col.shape[0], 256), 1)
    onehot = (idx_col.astype(jnp.int32)[:, None] == iota).astype(acc_dtype)
    return lax.dot_general(onehot, tbl_c.astype(acc_dtype),
                           (((1,), (0,)), ((), ())),
                           preferred_element_type=acc_dtype)


def _lut_kernel(idx_ref, tbl_ref, o_ref, acc_ref, *, nc: int, bc: int):
    """idx_ref: (1, bm, bc) uint8 per-plane index bytes; tbl_ref:
    (bc, 256, bn) chunk-partial-sum table tile in VMEM; o_ref: (1, bm, bn)
    f32; acc_ref: (bm, bn) f32/int32 scratch. Chunk tiles are visited
    ascending (innermost grid dim), and within a tile the fold is a static
    ascending python loop — together they replay ``lut_matmul``'s defined
    ascending-chunk reduction tree exactly."""
    c_step = pl.program_id(3)

    @pl.when(c_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[0]                    # (bm, bc)
    acc = acc_ref[...]
    for cc in range(bc):                # static unroll: the defined fold
        acc = acc + gather256(tbl_ref[cc], idx[:, cc], acc.dtype)
    acc_ref[...] = acc

    @pl.when(c_step == nc - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bc", "interpret"))
def lut_gather_matmul(idx, table, *, bm: int = 128, bn: int = 128,
                      bc: int = 32, interpret: bool = True):
    """Pallas byte-LUT matmul: (P, M, C) uint8 per-plane index bytes x
    (C, 256, N) chunk-partial-sum table -> (P, M, N) f32 accumulators.

    The grid (P, M/bm, N/bn, C/bc) extends ``_spike_matmul_grouped``'s
    plane-group structure: the plane axis is outermost so one (bc, 256, bn)
    table tile streamed into VMEM serves every plane before the grid
    advances — the table is the stationary operand, exactly the paper's
    weight-stationary PE with the 8-row chunk partial sums precomputed.
    Reduction follows ``lut_matmul``'s defined ascending-chunk fold (chunk
    tiles ascend in the innermost grid dim, a static ascending unroll
    inside each tile), with int32 accumulation for int16 tables, so the
    result is bit-exact against the CPU gather route and its
    ``lut_matmul_planes`` float oracle.

    Padding: M pads with zero index bytes (they gather the exact-zero
    ``table[c, 0, :]`` entry), N pads the table with zero columns, C pads
    the table with all-zero chunks — all are exact-identity adds, sliced
    off on return.
    """
    p, m, c = idx.shape
    c2, _, n = table.shape
    assert c == c2, (idx.shape, table.shape)
    bm_, bn_, bc_ = min(bm, m), min(bn, n), min(bc, c)
    pm, pn, pc = (-m) % bm_, (-n) % bn_, (-c) % bc_
    if pm or pc:
        idx = jnp.pad(idx, ((0, 0), (0, pm), (0, pc)))
    if pc or pn:
        table = jnp.pad(table, ((0, pc), (0, 0), (0, pn)))
    mp, cp = idx.shape[1:]
    np_ = table.shape[-1]
    grid = (p, mp // bm_, np_ // bn_, cp // bc_)
    acc_dtype = (jnp.int32 if jnp.issubdtype(table.dtype, jnp.integer)
                 else jnp.float32)

    y = pl.pallas_call(
        functools.partial(_lut_kernel, nc=grid[3], bc=bc_),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bc_), lambda pp, i, j, cc: (pp, i, cc)),
            pl.BlockSpec((bc_, 256, bn_), lambda pp, i, j, cc: (cc, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_),
                               lambda pp, i, j, cc: (pp, i, j)),
        out_shape=jax.ShapeDtypeStruct((p, mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), acc_dtype)],
        interpret=interpret,
    )(idx, table)
    return y[:, :m, :n]


def _spike_matmul_grouped(x_packed, w, *, bm: int, bn: int, bk: int,
                          interpret: bool):
    """(G, M, K) uint8 plane groups x (K, N) -> (G, 8, M, N) per-plane dots.

    Grid (G, M/bm, N/bn, K/bk): for each group the inner three dims replay the
    2D per_plane schedule, reusing the same (8, bm, bn) f32 accumulator tile.
    """
    g, m, k = x_packed.shape
    k2, n = w.shape
    assert k == k2, (x_packed.shape, w.shape)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    if pm or pk:
        x_packed = jnp.pad(x_packed, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    mp, kp = x_packed.shape[1:]
    np_ = w.shape[1]
    grid = (g, mp // bm_, np_ // bn_, kp // bk_)

    y = pl.pallas_call(
        functools.partial(_kernel, mode="per_plane", nk=grid[3], k_dim=3),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((bk_, bn_), lambda gg, i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((1, 8, bm_, bn_),
                               lambda gg, i, j, kk: (gg, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, 8, mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(x_packed, w)
    return y[:, :, :m, :n]
