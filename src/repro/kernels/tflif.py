"""TFLIF Pallas kernel: fused (BN-folded bias add) + LIF over T timesteps,
emitting bit-packed spikes.

The T axis stays in registers (statically unrolled), the bias (which already
carries the folded BN shift — "subtract the LIF threshold from the BN bias")
is added in the same pass, and the output is written as ``G = ceil(T/8)``
uint8 plane groups per neuron with bit j of group g holding the timestep
``8g+j`` spike: the paper's Output-SRAM packing, which is what keeps
inter-layer traffic at 1 bit/activation. The membrane potential is carried
across group boundaries inside the kernel — T > 8 costs extra output bytes,
never a second pass over the input.

The threshold is an (M,)-vector operand rather than a compile-time constant
so the int8-weight route can fold its per-channel dequantization scale into
the comparison (spike iff h >= v_th/s) without ever rescaling the integer
accumulators.

Elementwise (VPU) kernel; grid over flattened neurons.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.spike import num_plane_groups

TAU = 2.0
V_TH = 1.0


def lif_charge_fire(v, x_t, bias, v_th, *, tau: float):
    """One in-kernel LIF timestep: charge, compare, hard-reset.

    Returns ``(v_next, spike_bool)``. This is the single arithmetic
    definition both the standalone TFLIF kernel and the fused
    pack->TFLIF->matmul kernel (``kernels.fused``) execute — extracting it
    keeps the two bit-identical to each other and to ``ref.tflif_ref``
    (same op sequence: ``(x + bias) - v`` first, one divide by tau).
    """
    h = v + (x_t + bias - v) / tau
    s = h >= v_th
    return jnp.where(s, 0.0, h), s     # hard reset; v crosses group bounds


def _kernel(x_ref, b_ref, vth_ref, o_ref, *, t_steps: int, tau: float):
    """x_ref: (T, bm); b_ref, vth_ref: (bm,); o_ref: (G, bm) uint8 packed."""
    bias = b_ref[...]
    v_th = vth_ref[...]
    groups = o_ref.shape[0]
    v = jnp.zeros_like(x_ref[0])
    out = []
    for g in range(groups):            # static unroll: T lives in VREGs
        packed = jnp.zeros(x_ref.shape[1:], jnp.uint8)
        for j in range(min(8, t_steps - 8 * g)):
            v, s = lif_charge_fire(v, x_ref[8 * g + j], bias, v_th, tau=tau)
            packed = packed | (s.astype(jnp.uint8) << jnp.uint8(j))
        out.append(packed)
    o_ref[...] = jnp.stack(out)


@functools.partial(jax.jit, static_argnames=("tau", "bm", "interpret"))
def tflif_fused(x, bias=None, *, tau: float = TAU, v_th=V_TH,
                bm: int = 1024, interpret: bool = True):
    """x: (T, M) f32 pre-activation accumulators (BN scale already folded into
    the producing matmul); bias: (M,) BN-folded bias; v_th: scalar or (M,)
    per-neuron firing threshold. Returns (G, M) uint8, G = ceil(T/8), with
    bit j of group g = spike at timestep 8g+j."""
    t_steps, m = x.shape
    groups = num_plane_groups(t_steps)
    if bias is None:
        bias = jnp.zeros((m,), jnp.float32)
    v_th = jnp.broadcast_to(jnp.asarray(v_th, jnp.float32), (m,))
    bm_ = min(bm, m)
    pad = (-m) % bm_
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        bias = jnp.pad(bias, (0, pad))
        v_th = jnp.pad(v_th, (0, pad), constant_values=1.0)
    mp = x.shape[1]
    y = pl.pallas_call(
        functools.partial(_kernel, t_steps=t_steps, tau=tau),
        grid=(mp // bm_,),
        in_specs=[
            pl.BlockSpec((t_steps, bm_), lambda i: (0, i)),
            pl.BlockSpec((bm_,), lambda i: (i,)),
            pl.BlockSpec((bm_,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((groups, bm_), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((groups, mp), jnp.uint8),
        interpret=interpret,
    )(x.astype(jnp.float32), bias.astype(jnp.float32), v_th)
    return y[:, :m]
