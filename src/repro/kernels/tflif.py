"""TFLIF Pallas kernel: fused (BN-folded bias add) + LIF over T timesteps,
emitting bit-packed spikes.

The T axis stays in registers (T=4 unrolled), the bias (which already carries
the folded BN shift — "subtract the LIF threshold from the BN bias") is added
in the same pass, and the output is written as ONE uint8 per neuron with bit t
holding the timestep-t spike: the paper's Output-SRAM packing, which is what
keeps inter-layer traffic at 1 bit/activation.

Elementwise (VPU) kernel; grid over flattened neurons.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TAU = 2.0
V_TH = 1.0


def _kernel(x_ref, b_ref, o_ref, *, t_steps: int, tau: float, v_th: float):
    """x_ref: (T, bm); b_ref: (bm,); o_ref: (bm,) uint8 packed spikes."""
    bias = b_ref[...]
    v = jnp.zeros_like(x_ref[0])
    packed = jnp.zeros(x_ref.shape[1:], jnp.uint8)
    for t in range(t_steps):  # static unroll: T lives in VREGs
        h = v + (x_ref[t] + bias - v) / tau
        s = (h >= v_th)
        v = jnp.where(s, 0.0, h)
        packed = packed | (s.astype(jnp.uint8) << jnp.uint8(t))
    o_ref[...] = packed


@functools.partial(jax.jit, static_argnames=("tau", "v_th", "bm", "interpret"))
def tflif_fused(x, bias=None, *, tau: float = TAU, v_th: float = V_TH,
                bm: int = 1024, interpret: bool = True):
    """x: (T, M) f32 pre-activation accumulators (BN scale already folded into
    the producing matmul); bias: (M,) BN-folded bias. Returns (M,) uint8 with
    bit t = spike at timestep t. T must be <= 8."""
    t_steps, m = x.shape
    assert t_steps <= 8, t_steps
    if bias is None:
        bias = jnp.zeros((m,), jnp.float32)
    bm_ = min(bm, m)
    pad = (-m) % bm_
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        bias = jnp.pad(bias, (0, pad))
    mp = x.shape[1]
    y = pl.pallas_call(
        functools.partial(_kernel, t_steps=t_steps, tau=tau, v_th=v_th),
        grid=(mp // bm_,),
        in_specs=[
            pl.BlockSpec((t_steps, bm_), lambda i: (0, i)),
            pl.BlockSpec((bm_,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm_,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.uint8),
        interpret=interpret,
    )(x.astype(jnp.float32), bias.astype(jnp.float32))
    return y[:m]
