"""STDP Pallas kernel: tile-wise fused (Q Kt) V — softmax-free spiking attention.

VESTA's STDP consumes each column of V immediately after it is produced, never
holding the full V (or the N x N score matrix). The TPU tiling is identical in
spirit: the grid streams KV tiles; for each Q tile we compute
``scores = Q Kt_tile`` and immediately contract with ``V_tile`` into the
output accumulator. Because spiking attention has NO softmax, there is no
online-max/renormalization bookkeeping — this is FlashAttention minus softmax,
and it is exact.

Shapes: q, k, v: (BH, N, Dh) — leading batch*heads dim is grid dim 0.
Out: (BH, N, Dh) = (Q Kt) V * scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, *, nkv: int, scale: float):
    """q_ref: (1, bq, dh); k_ref/v_ref: (1, bkv, dh); o_ref: (1, bq, dh)."""
    kv_step = pl.program_id(2)

    @pl.when(kv_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(scores, v, preferred_element_type=jnp.float32)

    @pl.when(kv_step == nkv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bq", "bkv", "interpret"))
def stdp_attention(q, k, v, *, scale: float, bq: int = 128, bkv: int = 128,
                   interpret: bool = True):
    """q, k, v: (BH, N, Dh) spike-valued ({0,1}) or real tensors."""
    bh, n, dh = q.shape
    bq_, bkv_ = min(bq, n), min(bkv, n)
    pq = (-n) % bq_
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
        # K/V padding rows contribute zero scores only if K pad rows are zero
        k = jnp.pad(k, ((0, 0), (0, pq), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pq), (0, 0)))
    npad = q.shape[1]
    grid = (bh, npad // bq_, npad // bkv_)
    y = pl.pallas_call(
        functools.partial(_kernel, nkv=grid[2], scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv_, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv_, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, npad, dh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq_, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return y[:, :n, :]
