"""Fused pack -> TFLIF -> byte-LUT matmul Pallas kernel.

The inter-layer contract of the packed datapath is "1 bit per activation in
HBM"; this kernel closes the last gap in it. For a producer/consumer linear
pair (the encoder MLP's fc1 -> fc2 is the shape in the model), the unfused
route writes fc1's packed spikes to HBM, reads them back, bit-transposes
them into LUT index bytes, and gathers. Here all of that happens in VMEM
inside ONE kernel invocation:

    fc1 accumulators (T, bm, K)  --LIF-->  spike bits (in VREGs)
        --pack-->  packed planes (G, bm, K)   [written once, for telemetry
                                               and the residual consumer]
        --index-->  chunk index bytes (bm, C) per timestep
        --gather-->  fc2 accumulators (T, bm, N)

The *unpacked* (T, bm, K) spike tensor never exists outside registers, and
the LUT index bytes are built directly from the spike booleans — the 8x8
bit transpose the unfused route needs (``lut_matmul.plane_indices``) is
free here because the bits haven't been packed along time yet.

Exactness: the LIF step is ``tflif.lif_charge_fire`` (the same op sequence
as ``ref.tflif_ref``), the gather is ``spike_matmul.gather256`` folded in
ascending-chunk order (the same defined reduction tree as
``lut_matmul.lut_matmul``), and integer tables accumulate in int32 — so the
fused step is bit-exact against the unfused composition on every backend,
which is what lets the packed_pallas backend enable it by default.

Interpret mode (CPU tier-1) runs the same kernel body under the Pallas
interpreter; the VMEM-residency claim (whole (C, 256, N) table per grid
step) is a real-TPU sizing constraint documented in kernels/README.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .spike_matmul import gather256
from .tflif import TAU, V_TH, lif_charge_fire
from .lut_matmul import K_CHUNK, num_k_chunks
from ..core.spike import num_plane_groups


def _kernel(x_ref, b_ref, vth_ref, tbl_ref, s_ref, o_ref, *, t_steps: int,
            tau: float, acc_dtype):
    """x_ref: (T, bm, K) fc1 accumulators; b_ref, vth_ref: (K,); tbl_ref:
    (C, 256, N) fc2 chunk-partial-sum table (VMEM-resident); s_ref:
    (G, bm, K) uint8 packed spikes out; o_ref: (T, bm, N) f32 fc2
    accumulators out. K is pre-padded to C*8 by the wrapper."""
    bias = b_ref[...]
    v_th = vth_ref[...]
    groups = s_ref.shape[0]
    bm = x_ref.shape[1]
    c = tbl_ref.shape[0]
    v = jnp.zeros_like(x_ref[0])
    for g in range(groups):            # static unroll: T lives in VREGs
        packed = jnp.zeros((bm, x_ref.shape[2]), jnp.uint8)
        for j in range(min(8, t_steps - 8 * g)):
            v, s = lif_charge_fire(v, x_ref[8 * g + j], bias, v_th, tau=tau)
            su8 = s.astype(jnp.uint8)
            packed = packed | (su8 << jnp.uint8(j))
            # LUT index bytes straight from the spike bits: byte c's bit i
            # is the spike of input 8c+i — the same value plane_indices
            # computes from packed bytes, no bit transpose needed here
            sc = su8.reshape(bm, c, K_CHUNK)
            idx = sc[:, :, 0]
            for i in range(1, K_CHUNK):
                idx = idx | (sc[:, :, i] << jnp.uint8(i))
            y = gather256(tbl_ref[0], idx[:, 0], acc_dtype)
            for chunk in range(1, c):  # the defined ascending-chunk fold
                y = y + gather256(tbl_ref[chunk], idx[:, chunk], acc_dtype)
            o_ref[8 * g + j] = y.astype(jnp.float32)
        s_ref[g] = packed


@functools.partial(jax.jit,
                   static_argnames=("tau", "bm", "interpret"))
def tflif_lut_matmul(x, bias, table, *, v_th=V_TH, tau: float = TAU,
                     bm: int = 128, interpret: bool = True):
    """Fused TFLIF + pack + byte-LUT matmul over a linear pair.

    Args:
      x: (T, R, K) f32 pre-LIF accumulators of the producer layer (its
        BN-folded bias NOT yet added — it is applied inside the LIF charge,
        matching ``ops.tflif_pack``).
      bias: (K,) producer bias (or None); v_th: scalar or (K,) producer
        threshold (per-channel for the int8 scale fold).
      table: (C, 256, N) consumer ``build_lut`` table, C = ceil(K/8).

    Returns:
      ``(spikes, acc)``: spikes (G, R, K) uint8 packed plane groups (the
      producer's LIF output — the unfused route's inter-layer tensor, still
      emitted for any second consumer), and acc (T, R, N) f32 consumer
      pre-LIF accumulators (consumer bias NOT added — the caller's LIF
      applies it, as on every other route).
    """
    t_steps, r, k = x.shape
    c, _, n = table.shape
    assert c == num_k_chunks(k), (x.shape, table.shape)
    groups = num_plane_groups(t_steps)
    if bias is None:
        bias = jnp.zeros((k,), jnp.float32)
    bias = jnp.broadcast_to(jnp.asarray(bias, jnp.float32), (k,))
    v_th = jnp.broadcast_to(jnp.asarray(v_th, jnp.float32), (k,))
    bm_ = min(bm, r)
    pr, pk = (-r) % bm_, c * K_CHUNK - k
    if pr or pk:
        # padded K neurons see x=0, bias=0, v_th=1: v' = v/tau from v0=0
        # stays 0 < 1 forever, so their index bits are 0 and their gathers
        # hit the zero weight rows build_lut padded with — exact identity
        x = jnp.pad(x, ((0, 0), (0, pr), (0, pk)))
        bias = jnp.pad(bias, (0, pk))
        v_th = jnp.pad(v_th, (0, pk), constant_values=1.0)
    rp, kp = x.shape[1:]
    acc_dtype = (jnp.int32 if jnp.issubdtype(table.dtype, jnp.integer)
                 else jnp.float32)

    spikes, acc = pl.pallas_call(
        functools.partial(_kernel, t_steps=t_steps, tau=tau,
                          acc_dtype=acc_dtype),
        grid=(rp // bm_,),
        in_specs=[
            pl.BlockSpec((t_steps, bm_, kp), lambda i: (0, i, 0)),
            pl.BlockSpec((kp,), lambda i: (0,)),
            pl.BlockSpec((kp,), lambda i: (0,)),
            pl.BlockSpec((c, 256, n), lambda i: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((groups, bm_, kp), lambda i: (0, i, 0)),
            pl.BlockSpec((t_steps, bm_, n), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((groups, rp, kp), jnp.uint8),
            jax.ShapeDtypeStruct((t_steps, rp, n), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), bias, v_th, table)
    return spikes[:, :r, :k], acc[:, :r, :]
