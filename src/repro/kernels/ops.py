"""Jit'd dispatch wrappers for the Pallas kernels.

On a real TPU the Pallas kernels run compiled; on CPU (this container) they
run in interpret mode for correctness, and the pure-XLA reference path is used
wherever wall-time matters (training/benchmarks). ``use_pallas()`` picks the
default; every wrapper takes an explicit override.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .spike_matmul import spike_matmul as _spike_matmul_pallas
from .tflif import tflif_fused as _tflif_pallas
from .stdp_attention import stdp_attention as _stdp_pallas
from .flash_attention import flash_attention as _flash_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas(override: bool | None = None) -> bool:
    if override is not None:
        return override
    return on_tpu()


def spike_matmul(x_packed, w, *, mode: str = "per_plane",
                 pallas: bool | None = None, **blocks):
    if use_pallas(pallas):
        return _spike_matmul_pallas(x_packed, w, mode=mode,
                                    interpret=not on_tpu(), **blocks)
    return ref.spike_matmul_ref(x_packed, w, mode=mode)


def tflif_fused(x, bias=None, *, tau: float = 2.0, v_th: float = 1.0,
                pallas: bool | None = None):
    if use_pallas(pallas):
        return _tflif_pallas(x, bias, tau=tau, v_th=v_th,
                             interpret=not on_tpu())
    return ref.tflif_ref(x, bias, tau=tau, v_th=v_th)


def stdp_attention(q, k, v, *, scale: float, pallas: bool | None = None,
                   **blocks):
    if use_pallas(pallas):
        return _stdp_pallas(q, k, v, scale=scale, interpret=not on_tpu(),
                            **blocks)
    return ref.stdp_attention_ref(q, k, v, scale=scale)


def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    pallas: bool | None = None, **blocks):
    if use_pallas(pallas):
        return _flash_pallas(q, k, v, scale=scale, causal=causal,
                             interpret=not on_tpu(), **blocks)
    return ref.flash_attention_ref(q, k, v, scale=scale, causal=causal)
