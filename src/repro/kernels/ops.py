"""Jit'd dispatch wrappers for the Pallas kernels.

On a real TPU the Pallas kernels run compiled; on CPU (this container) they
run in interpret mode for correctness, and the pure-XLA reference path is used
wherever wall-time matters (training/benchmarks). ``use_pallas()`` picks the
default; every wrapper takes an explicit override.

Plane-group convention (the arbitrary-T packed representation): a T-timestep
binary activation is stored as ``G = ceil(T/8)`` uint8 *plane groups* with a
leading group axis — bit j of group g is the spike at timestep ``8g + j``,
and bits past T-1 in the last group are zero. ``G == 1`` still carries the
axis, so every packed tensor in the datapath is (G, ...) uint8. Packing /
unpacking lives in ``core.spike.pack_timesteps`` / ``unpack_timesteps``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from . import lut_matmul as lut
from .lut_matmul import (  # noqa: F401  (re-export: the dispatch heuristic)
    RouteConstants, choose_pallas_route, choose_route)
from .spike_matmul import spike_matmul as _spike_matmul_pallas
from .fused import tflif_lut_matmul as _tflif_lut_pallas
from .tflif import tflif_fused as _tflif_pallas
from .stdp_attention import stdp_attention as _stdp_pallas
from .flash_attention import flash_attention as _flash_pallas
from ..core.spike import bitplanes_u8, num_plane_groups, unpack_timesteps


def _resolve_route(route, table, *, m, k, n, g, t, weights_are_int,
                   constants=None, occupancy=None):
    """Route resolution for the packed CPU matmuls.

    ``None`` is the *safe* default: LUT only when the caller (the session
    planner) supplies a prebuilt table — so un-planned callers keep the
    single-dot unpack route that mirrors the float reference bit for bit;
    a calibrated ``occupancy`` alongside the table upgrades that default to
    the zero-chunk-skipping gather (bit-identical, see
    ``lut_matmul.lut_matmul_sparse``). "auto" applies ``choose_route``
    inline (``constants`` overrides the cost model — plans carry autotuned
    values); "lut"/"lut_sparse"/"unpack" force. The forced sparse route
    requires ``occupancy`` — the gather budget is a static compile-time
    value derived from it, not something to guess.
    """
    if route is None:
        if table is None:
            return "unpack"
        return "lut_sparse" if occupancy is not None else "lut"
    if route == "auto":
        return choose_route(m=m, k=k, n=n, g=g, t=t,
                            weights_are_int=weights_are_int,
                            constants=constants, occupancy=occupancy)
    if route not in ("lut", "lut_sparse", "unpack"):
        raise ValueError(f"unknown packed-matmul route {route!r}")
    if route == "lut_sparse" and occupancy is None:
        raise ValueError("route='lut_sparse' requires a calibrated "
                         "occupancy (the static gather budget comes from "
                         "it); measure with infer.backends.chunk_occupancy")
    return route


def _have_table(table) -> bool:
    """A real (C, 256, N) table vs None or a planner boolean flag. The
    flag case (``lut=True``, what ``build_tables=False`` annotates for
    backends that never gather) appears as a traced 0-d bool under jit —
    ``ndim == 3`` separates it from an actual table either way."""
    return table is not None and getattr(table, "ndim", 0) == 3


def _resolve_route_pallas(route, table, *, m, k, n, g, t, weights_are_int,
                          constants=None):
    """Route resolution for the Pallas branch: "lut" (the byte-LUT gather
    kernel over a VMEM-resident table) or "unpack" (the grouped
    unpack-in-register dot kernel).

    Mirrors ``_resolve_route``'s contract with two Pallas-specific rules:
    "auto" consults ``choose_pallas_route`` (its own cost model — one-hot
    MXU selects vs in-register plane dots have different constants than
    the CPU gather vs unpack-and-write), and a pinned "lut_sparse" runs
    the DENSE Pallas gather — there is no zero-chunk-skipping kernel, and
    the dense fold is bitwise identical to the sparse one by construction,
    so replaying a CPU-calibrated sparse plan on the Pallas backend is
    exact, just not sparse.
    """
    if route is None:
        return "lut" if _have_table(table) else "unpack"
    if route == "auto":
        return choose_pallas_route(m=m, k=k, n=n, g=g, t=t,
                                   weights_are_int=weights_are_int,
                                   constants=constants)
    if route not in ("lut", "lut_sparse", "unpack"):
        raise ValueError(f"unknown packed-matmul route {route!r}")
    return "lut" if route == "lut_sparse" else route


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas(override: bool | None = None) -> bool:
    if override is not None:
        return override
    return on_tpu()


def spike_matmul(x_packed, w, *, mode: str = "per_plane",
                 pallas: bool | None = None, **blocks):
    """Unified-PE matmul over packed binary planes.

    Args:
      x_packed: (M, K) uint8 — bit p of byte [m, k] is plane p's spike — or
        (G, M, K) uint8 plane groups (mode="per_plane" only).
      w: (K, N) weights, any float/int dtype (cast to f32 in the dot).
      mode: "per_plane" — each of the 8 bit planes gets its own output
        (WSSL/ZSC/STDP operands); "shift_sum" — planes combined with scales
        2^p before the dot, i.e. the byte is treated as a uint8 *value*
        (SSSC).
      pallas: force the Pallas kernel (True) or the jnp oracle (False);
        None auto-selects (Pallas on TPU).

    Returns:
      (8, M, N) f32 for mode="per_plane"; (G, 8, M, N) for grouped input;
      (M, N) f32 for mode="shift_sum".
    """
    if use_pallas(pallas):
        return _spike_matmul_pallas(x_packed, w, mode=mode,
                                    interpret=not on_tpu(), **blocks)
    return ref.spike_matmul_ref(x_packed, w, mode=mode)


def tflif_fused(x, bias=None, *, tau: float = 2.0, v_th=1.0,
                pallas: bool | None = None):
    """Fused bias-add + LIF over T timesteps, emitting packed spikes.

    Args:
      x: (T, M) f32 pre-activation accumulators (BN scale already folded into
        the producing matmul). Any T >= 1.
      bias: optional (M,) BN-folded bias, added inside the LIF charge.
      tau: LIF leak constant.
      v_th: firing threshold — scalar, or (M,) per-neuron vector (used by the
        int8 route to fold the per-channel weight scale into the comparison).
      pallas: backend override as in ``spike_matmul``.

    Returns:
      (G, M) uint8, G = ceil(T/8); bit j of group g = spike at timestep
      8g + j. Membrane state is carried across group boundaries.
    """
    if use_pallas(pallas):
        return _tflif_pallas(x, bias, tau=tau, v_th=v_th,
                             interpret=not on_tpu())
    return ref.tflif_ref(x, bias, tau=tau, v_th=v_th)


def stdp_attention(q, k, v, *, scale: float, pallas: bool | None = None,
                   **blocks):
    """Softmax-free spiking attention (Q K^T) V * scale.

    q, k, v: (BH, N, Dh) float {0,1} spike planes (one plane per grid row —
    callers fold T into BH). Returns (BH, N, Dh) f32 exact accumulators.
    """
    if use_pallas(pallas):
        return _stdp_pallas(q, k, v, scale=scale, interpret=not on_tpu(),
                            **blocks)
    return ref.stdp_attention_ref(q, k, v, scale=scale)


def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    pallas: bool | None = None, **blocks):
    """Standard softmax attention (the non-spiking LM stack's kernel).

    q: (BH, Nq, Dh); k, v: (BH, Nkv, Dh). Returns (BH, Nq, Dh) f32.
    """
    if use_pallas(pallas):
        return _flash_pallas(q, k, v, scale=scale, causal=causal,
                             interpret=not on_tpu(), **blocks)
    return ref.flash_attention_ref(q, k, v, scale=scale, causal=causal)


# ---------------------------------------------------------------------------
# Batched packed-bit entry points — the inference datapath
# ---------------------------------------------------------------------------
# These are what ``repro.infer`` dispatches through: activations stay packed
# 8-per-uint8 between layers (temporal bits for WSSL/ZSC/STDP, value bits for
# SSSC) and only unpack inside the matmul. The CPU reference route mirrors
# ``core.unified`` operation-for-operation — same reshapes, same single dot,
# same reduction order — so it is bit-exact against the float training graph;
# the Pallas route trades that for the fused uint8 kernels.

def spike_linear(x_packed, w, bias=None, *, t: int,
                 pallas: bool | None = None, route: str | None = None,
                 table=None, route_constants=None, occupancy=None, **blocks):
    """Packed WSSL (weight-stationary spiking linear).

    Args:
      x_packed: (G, ..., K) uint8 temporal plane groups, G = ceil(t/8);
        bit j of group g = the timestep-(8g+j) spike of that neuron.
      w: (K, N) weights; bias: optional (N,) added to every timestep.
      t: number of live timesteps (bits past t-1 must be zero).
      pallas: backend override. The Pallas branch honors ``route`` through
        ``_resolve_route_pallas``: "lut" runs the VMEM-table gather kernel
        (``lut_matmul_pallas``), "unpack" the grouped in-register dot
        kernel, "auto" the ``choose_pallas_route`` cost model, and a
        pinned "lut_sparse" the dense gather (bitwise identical).
      route: route selection — None (LUT iff ``table`` given, sparse
        LUT iff additionally ``occupancy`` given, else the unpack oracle),
        "auto" (the ``choose_route`` heuristic), or a forced "lut" /
        "lut_sparse" / "unpack".
      table: prebuilt ``lut_matmul.build_lut(w)`` result, cached by the
        compile-time route planner so the 256-entry chunk sums are paid
        once per layer, not per batch.
      route_constants: ``RouteConstants`` override for the route="auto"
        cost model (plans carry autotuned values; None = defaults).
      occupancy: calibrated CHUNK occupancy of this layer's packed inputs
        (``infer.backends.chunk_occupancy`` — fraction of nonzero index
        bytes), a STATIC python float: the sparse route's per-row gather
        budget is fixed at trace time from it. Inputs denser than the
        calibration fall back to the dense gather inside the kernel.

    Returns:
      (t, ..., N) f32 per-timestep accumulators. On the CPU unpack route all
      t planes of all groups are folded into the row dim of ONE dot (exactly
      ``unified.wssl``, hence bit-exact vs the float reference); the LUT
      route gathers chunk partial sums byte-wise with no unpacked tensor
      (bit-exact vs ``lut.lut_matmul_planes``, the fold-order oracle the
      reference backend emulates for planned layers) and the sparse LUT
      route additionally skips zero index bytes (still bit-exact — the
      skipped ``table[c, 0, :]`` entry is the exact-zero identity). The
      Pallas LUT route replays the same defined gather fold in-kernel
      (bit-exact against the CPU LUT route and its oracle); the Pallas
      unpack route runs the grouped dot kernel, one weight fetch per group
      of 8 planes (bit-exact for integer weights, reduction-order-
      tolerant for float32 — pin "lut" routes for float bit-exactness).
    """
    g = x_packed.shape[0]
    assert g == num_plane_groups(t), (g, t)
    lead, k = x_packed.shape[1:-1], x_packed.shape[-1]
    m = 1
    for d in lead:
        m *= d
    n = w.shape[-1]
    if use_pallas(pallas):
        resolved = _resolve_route_pallas(
            route, table, m=m, k=k, n=n, g=g, t=t,
            weights_are_int=lut._is_int_kernel(w),
            constants=route_constants)
        x2 = x_packed.reshape(g, -1, k)
        if resolved == "lut":
            tbl = table if _have_table(table) else lut.build_lut(w)
            idx = lut.plane_indices(x2)[:t]                # (t, M, C)
            per = lut.lut_matmul_pallas(idx, tbl,
                                        interpret=not on_tpu())
        else:
            per8 = _spike_matmul_pallas(x2, w, mode="per_plane",
                                        interpret=not on_tpu(), **blocks)
            per = per8.reshape(g * 8, m, n)[:t]            # (t, M, N)
        if bias is not None:
            per = per + bias.astype(per.dtype)
        return per.reshape((t, *lead, n))
    resolved = _resolve_route(
        route, table, m=m, k=k, n=n, g=g, t=t,
        weights_are_int=lut._is_int_kernel(w),
        constants=route_constants, occupancy=occupancy)
    if resolved in ("lut", "lut_sparse"):
        tbl = lut.build_lut(w) if table is None else table
        idx = lut.plane_indices(x_packed)[:t]              # (t, ..., C)
        if resolved == "lut_sparse":
            budget = lut.sparse_budget(tbl.shape[0], occupancy)
            per = lut.lut_matmul_sparse(idx, tbl, max_chunks=budget)
        else:
            per = lut.lut_matmul(idx, tbl)                 # (t, ..., N)
        if bias is not None:
            per = per + bias.astype(per.dtype)
        return per
    else:
        x2 = x_packed.reshape(g, -1, k)
        planes = unpack_timesteps(x2, t)                   # (t, M, K)
        per = (planes.reshape(t * m, k) @ w.astype(jnp.float32)
               ).reshape(t, m, n)
    if bias is not None:
        per = per + bias.astype(per.dtype)
    return per.reshape((t, *lead, n))


def sssc_linear(x_u8, w, bias=None, *, pallas: bool | None = None,
                route: str | None = None, table=None, route_constants=None,
                occupancy=None, **blocks):
    """Packed SSSC (shift-and-sum spiking conv, as a linear over 8 bit-planes).

    Args:
      x_u8: (..., K) uint8 *values* (the image is its own packing: bit p of a
        byte is value-plane p, combined with scale 2^p). Always exactly 8
        planes — SSSC never grows a plane-group axis.
      w: (K, N) weights; bias: optional (N,).
      route, table: CPU-route selection as in ``spike_linear`` — the value
        bytes are the LUT index source directly (an 8x8 bit transpose turns
        K value bytes into ceil(K/8) per-plane index bytes), and the 2^p
        plane combine uses the defined ``shift_sum_fold`` order.
      occupancy: calibrated chunk occupancy of the transposed value bytes
        (``infer.backends.value_chunk_occupancy``), static — enables the
        zero-chunk-skipping gather exactly as in ``spike_linear``.

    Returns:
      (..., N) f32 accumulators, ``y = sum_p 2^p (plane_p . W)`` — identical
      to an 8-bit conv. The Pallas route collapses the 8 planes into one dot
      (shift_sum mode).
    """
    lead, k = x_u8.shape[:-1], x_u8.shape[-1]
    x2 = x_u8.reshape(-1, k)
    m = x2.shape[0]
    n = w.shape[-1]
    if use_pallas(pallas):
        resolved = _resolve_route_pallas(
            route, table, m=m, k=k, n=n, g=1, t=8,
            weights_are_int=lut._is_int_kernel(w),
            constants=route_constants)
        if resolved == "lut":
            tbl = table if _have_table(table) else lut.build_lut(w)
            idx = lut.plane_indices(x2[None])              # (8, M, C)
            per = lut.lut_matmul_pallas(idx, tbl,
                                        interpret=not on_tpu())
            y = lut.shift_sum_fold(per)                    # (M, N)
        else:
            y = _spike_matmul_pallas(x2, w, mode="shift_sum",
                                     interpret=not on_tpu(), **blocks)
        if bias is not None:
            y = y + bias.astype(y.dtype)
        return y.reshape((*lead, n))
    resolved = _resolve_route(
        route, table, m=m, k=k, n=n, g=1, t=8,
        weights_are_int=lut._is_int_kernel(w),
        constants=route_constants, occupancy=occupancy)
    if resolved in ("lut", "lut_sparse"):
        tbl = lut.build_lut(w) if table is None else table
        idx = lut.plane_indices(x_u8[None])                # (8, ..., C)
        if resolved == "lut_sparse":
            budget = lut.sparse_budget(tbl.shape[0], occupancy)
            per = lut.lut_matmul_sparse(idx, tbl, max_chunks=budget)
        else:
            per = lut.lut_matmul(idx, tbl)
        y = lut.shift_sum_fold(per)                        # (..., N)
        if bias is not None:
            y = y + bias.astype(y.dtype)
        return y
    else:
        planes = bitplanes_u8(x2)                          # (8, M, K)
        per = (planes.reshape(8 * m, k) @ w.astype(jnp.float32)
               ).reshape(8, m, w.shape[-1])
        scales = (2.0 ** jnp.arange(8, dtype=per.dtype)).reshape(8, 1, 1)
        y = (per * scales).sum(axis=0)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y.reshape((*lead, w.shape[-1]))


def tflif_pack(acc, bias=None, *, t: int | None = None, tau: float = 2.0,
               v_th=1.0, pallas: bool | None = None):
    """Batched TFLIF: per-timestep accumulators -> packed plane groups.

    Args:
      acc: (T, ...) f32 accumulators, any T >= 1. The whole T axis is fused;
        membrane state crosses the 8-timestep group boundaries inside the
        kernel.
      bias: optional BN-folded shift, broadcastable to acc.shape[1:], added
        inside the same pass.
      v_th: scalar threshold, or an array broadcastable to acc.shape[1:] —
        per-channel thresholds carry the int8 weight-scale fold
        (spike iff h >= v_th/s without rescaling the accumulator).
      t: process only the first t timesteps of acc (defaults to all of
        them); honored identically on every branch.

    Returns:
      (G, ...) uint8 plane groups, G = ceil(T/8); bit j of group g = spike at
      timestep 8g + j.
    """
    if t is not None and t != acc.shape[0]:
        acc = acc[:t]                  # honor the override on every branch
    t = acc.shape[0]
    lead = acc.shape[1:]
    if not use_pallas(pallas):
        # CPU oracle runs natively N-D: in-graph flattens force XLA CPU's
        # fusion emitter into ~10x-slower reshape-chasing loop nests, and
        # broadcast shape never changes per-element results.
        return ref.tflif_ref(acc, bias, tau=tau, v_th=v_th)
    x2 = acc.reshape(t, -1)
    if bias is not None:
        bias = jnp.broadcast_to(bias, lead).reshape(-1)
    if not isinstance(v_th, (int, float)):
        v_th = jnp.broadcast_to(v_th, lead).reshape(-1)
    packed = tflif_fused(x2, bias, tau=tau, v_th=v_th, pallas=pallas)
    return packed.reshape((packed.shape[0], *lead))


def tflif_lut(acc, bias=None, *, table, v_th=1.0, t: int | None = None,
              tau: float = 2.0, pallas: bool | None = None):
    """Fused LIF -> pack -> byte-LUT matmul over a producer/consumer pair
    (the MLP fc1 -> fc2 step).

    Args:
      acc: (T, ..., K) f32 producer pre-LIF accumulators (producer bias
        NOT added — it goes through ``bias`` into the LIF charge, exactly
        as ``tflif_pack``). The trailing axis is the producer's channel
        dim = the consumer's contraction dim.
      bias: producer bias, None / scalar / (K,); v_th: producer threshold,
        scalar or (K,) (the int8 scale fold).
      table: (C, 256, N) consumer ``build_lut`` table — a REAL table, the
        fused step is a gather by definition.
      t: live timesteps (defaults to acc.shape[0]).

    Returns:
      ``(spikes, acc2)``: spikes (G, ..., K) uint8 packed producer output
      (what the unfused route would have written between the layers) and
      acc2 (t, ..., N) f32 consumer pre-LIF accumulators (consumer bias
      not added). The Pallas branch runs the single fused kernel
      (``kernels.fused.tflif_lut_matmul``); the CPU branch composes the
      same math from ``tflif_pack`` + ``plane_indices`` + ``lut_matmul``
      — both bit-exact against each other, so the fused step never
      changes logits, only traffic.
    """
    if not _have_table(table):
        raise ValueError("tflif_lut requires a real (C, 256, N) table — "
                         "the fused step is a gather by definition; build "
                         "one with lut_matmul.build_lut")
    if t is not None and t != acc.shape[0]:
        acc = acc[:t]
    t = acc.shape[0]
    lead, k = acc.shape[1:-1], acc.shape[-1]
    n = table.shape[-1]
    if use_pallas(pallas):
        x2 = acc.reshape(t, -1, k)
        b = None if bias is None else jnp.broadcast_to(
            jnp.asarray(bias, jnp.float32), (k,))
        vth = jnp.broadcast_to(jnp.asarray(v_th, jnp.float32), (k,))
        spikes, acc2 = _tflif_lut_pallas(x2, b, table, v_th=vth, tau=tau,
                                         interpret=not on_tpu())
        return (spikes.reshape(spikes.shape[0], *lead, k),
                acc2.reshape(t, *lead, n))
    spikes = tflif_pack(acc, bias, tau=tau, v_th=v_th, pallas=pallas)
    idx = lut.plane_indices(spikes)[:t]                    # (t, ..., C)
    return spikes, lut.lut_matmul(idx, table)


STDP_LUT_MIN_TOKENS = 128  # below this, score-table build cost can't amortize


def stdp_attention_packed(q_packed, k_packed, v_packed, *, t: int,
                          scale: float, pallas: bool | None = None,
                          route: str | None = None, **blocks):
    """Packed STDP: softmax-free attention over temporal plane groups.

    Args:
      q_packed, k_packed, v_packed: (G, ..., N, Dh) uint8 temporal plane
        groups (G = ceil(t/8)). Timesteps attend independently — spike
        attention has no cross-T term — so all t planes fold into the
        batch-heads grid dim of the tile-fused kernel.
      t: live timesteps; scale: output scale (power of two in Spikformer, so
        results stay exact).
      route: CPU-route selection. The LUT route computes the score matmul
        Q K^T by byte-gather — Q is never unpacked; K (the "weight" side)
        is, to build per-(t, head) tables, so this only pays off when the
        token count N amortizes the 256-entry build ("auto": N >=
        STDP_LUT_MIN_TOKENS). Binary q/k/v make every accumulator an exact
        integer, so all routes agree bit for bit regardless of order.

    Returns:
      (t, ..., N, Dh) f32 attention accumulators.
    """
    lead = q_packed.shape[1:-2]
    n, dh = q_packed.shape[-2:]
    g = q_packed.shape[0]

    if not use_pallas(pallas):
        if route == "auto":
            # score tables are per-(t, batch*head) and rebuilt every call (K
            # is an activation): require both enough tokens to amortize the
            # 256-entry build AND a bounded transient footprint, mirroring
            # MAX_TABLE_BYTES on the linear layers
            bh_all = 1
            for d in lead:
                bh_all *= d
            tables_bytes = t * bh_all * lut.num_k_chunks(dh) * 256 * n * 4
            route = ("lut" if n >= STDP_LUT_MIN_TOKENS
                     and tables_bytes <= lut.MAX_TABLE_BYTES else "unpack")
        if route == "lut":
            bh = 1
            for d in lead:
                bh *= d
            idx_q = lut.plane_indices(
                q_packed.reshape(g, bh * n, dh))[:t].reshape(t, bh, n, -1)
            k_pl = unpack_timesteps(k_packed.reshape(g, bh, n, dh), t)
            v_pl = unpack_timesteps(v_packed.reshape(g, bh, n, dh), t)
            tables = jax.vmap(jax.vmap(lut.build_lut))(
                k_pl.transpose(0, 1, 3, 2))                # (t,BH,C,256,N)
            s = jax.vmap(jax.vmap(lut.lut_matmul))(idx_q, tables)
            out = jnp.einsum("tbnm,tbmd->tbnd", s, v_pl) * scale
            return out.reshape((t, *lead, n, dh))
        if route not in (None, "unpack"):
            raise ValueError(f"unknown packed-stdp route {route!r}")

    def unfold(z):
        planes = unpack_timesteps(z.reshape(z.shape[0], -1, n, z.shape[-1]),
                                  t)                       # (t, BH', N, Dh)
        return planes.reshape(-1, n, z.shape[-1])          # (t*BH, N, Dh)

    out = stdp_attention(unfold(q_packed), unfold(k_packed), unfold(v_packed),
                         scale=scale, pallas=pallas, **blocks)
    return out.reshape((t, *lead, n, dh))
