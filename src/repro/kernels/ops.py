"""Jit'd dispatch wrappers for the Pallas kernels.

On a real TPU the Pallas kernels run compiled; on CPU (this container) they
run in interpret mode for correctness, and the pure-XLA reference path is used
wherever wall-time matters (training/benchmarks). ``use_pallas()`` picks the
default; every wrapper takes an explicit override.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .spike_matmul import spike_matmul as _spike_matmul_pallas
from .tflif import tflif_fused as _tflif_pallas
from .stdp_attention import stdp_attention as _stdp_pallas
from .flash_attention import flash_attention as _flash_pallas
from ..core.spike import bitplanes_u8, unpack_timesteps


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas(override: bool | None = None) -> bool:
    if override is not None:
        return override
    return on_tpu()


def spike_matmul(x_packed, w, *, mode: str = "per_plane",
                 pallas: bool | None = None, **blocks):
    if use_pallas(pallas):
        return _spike_matmul_pallas(x_packed, w, mode=mode,
                                    interpret=not on_tpu(), **blocks)
    return ref.spike_matmul_ref(x_packed, w, mode=mode)


def tflif_fused(x, bias=None, *, tau: float = 2.0, v_th: float = 1.0,
                pallas: bool | None = None):
    if use_pallas(pallas):
        return _tflif_pallas(x, bias, tau=tau, v_th=v_th,
                             interpret=not on_tpu())
    return ref.tflif_ref(x, bias, tau=tau, v_th=v_th)


def stdp_attention(q, k, v, *, scale: float, pallas: bool | None = None,
                   **blocks):
    if use_pallas(pallas):
        return _stdp_pallas(q, k, v, scale=scale, interpret=not on_tpu(),
                            **blocks)
    return ref.stdp_attention_ref(q, k, v, scale=scale)


def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    pallas: bool | None = None, **blocks):
    if use_pallas(pallas):
        return _flash_pallas(q, k, v, scale=scale, causal=causal,
                             interpret=not on_tpu(), **blocks)
    return ref.flash_attention_ref(q, k, v, scale=scale, causal=causal)


# ---------------------------------------------------------------------------
# Batched packed-bit entry points — the inference datapath
# ---------------------------------------------------------------------------
# These are what ``repro.infer`` dispatches through: activations stay packed
# 8-per-uint8 between layers (temporal bits for WSSL/ZSC/STDP, value bits for
# SSSC) and only unpack inside the matmul. The CPU reference route mirrors
# ``core.unified`` operation-for-operation — same reshapes, same single dot,
# same reduction order — so it is bit-exact against the float training graph;
# the Pallas route trades that for the fused uint8 kernels.

def spike_linear(x_packed, w, bias=None, *, t: int,
                 pallas: bool | None = None, **blocks):
    """Packed WSSL: x_packed (..., K) uint8 (bit i = timestep i's spike) ->
    (t, ..., N) per-timestep accumulators, T folded into the row dim of one
    weight-stationary dot exactly like ``unified.wssl``."""
    lead, k = x_packed.shape[:-1], x_packed.shape[-1]
    x2 = x_packed.reshape(-1, k)
    m = x2.shape[0]
    if use_pallas(pallas):
        per = _spike_matmul_pallas(x2, w, mode="per_plane",
                                   interpret=not on_tpu(), **blocks)[:t]
    else:
        planes = unpack_timesteps(x2, t)                       # (t, M, K)
        per = (planes.reshape(t * m, k) @ w.astype(jnp.float32)
               ).reshape(t, m, w.shape[-1])
    if bias is not None:
        per = per + bias.astype(per.dtype)
    return per.reshape((t, *lead, w.shape[-1]))


def sssc_linear(x_u8, w, bias=None, *, pallas: bool | None = None, **blocks):
    """Packed SSSC: x_u8 (..., K) uint8 *values* -> (..., N) accumulators via
    the shift-and-sum of 8 bit-plane dots (``y = sum_k 2^k (plane_k . W)``).
    The Pallas route collapses the 8 planes into one dot (shift_sum mode)."""
    lead, k = x_u8.shape[:-1], x_u8.shape[-1]
    x2 = x_u8.reshape(-1, k)
    m = x2.shape[0]
    if use_pallas(pallas):
        y = _spike_matmul_pallas(x2, w, mode="shift_sum",
                                 interpret=not on_tpu(), **blocks)
    else:
        planes = bitplanes_u8(x2)                              # (8, M, K)
        per = (planes.reshape(8 * m, k) @ w.astype(jnp.float32)
               ).reshape(8, m, w.shape[-1])
        scales = (2.0 ** jnp.arange(8, dtype=per.dtype)).reshape(8, 1, 1)
        y = (per * scales).sum(axis=0)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y.reshape((*lead, w.shape[-1]))


def tflif_pack(acc, bias=None, *, t: int | None = None, tau: float = 2.0,
               v_th: float = 1.0, pallas: bool | None = None):
    """Batched TFLIF: (T, ...) float accumulators -> (...) uint8 packed
    spikes (bit i = timestep i). The whole T axis is fused; ``bias`` (the
    BN-folded shift) is added inside the same pass."""
    t = acc.shape[0] if t is None else t
    assert t <= 8, f"one uint8 holds at most 8 timestep bits, got T={t}"
    lead = acc.shape[1:]
    x2 = acc.reshape(t, -1)
    if bias is not None:
        bias = jnp.broadcast_to(bias, lead).reshape(-1)
    packed = tflif_fused(x2, bias, tau=tau, v_th=v_th, pallas=pallas)
    return packed.reshape(lead)


def stdp_attention_packed(q_packed, k_packed, v_packed, *, t: int,
                          scale: float, pallas: bool | None = None, **blocks):
    """Packed STDP: q/k/v (..., N, Dh) uint8 temporal-packed spikes ->
    (t, ..., N, Dh) attention accumulators. Timesteps attend independently
    (spike attention has no cross-T term), so T folds into the batch-heads
    grid dim of the tile-fused kernel."""
    lead = q_packed.shape[:-2]
    n, dh = q_packed.shape[-2:]

    def unfold(z):
        planes = unpack_timesteps(z.reshape(-1, n, z.shape[-1]), t)
        return planes.reshape(-1, n, z.shape[-1])              # (t*BH, N, Dh)

    out = stdp_attention(unfold(q_packed), unfold(k_packed), unfold(v_packed),
                         scale=scale, pallas=pallas, **blocks)
    return out.reshape((t, *lead, n, dh))
