"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.spike import num_plane_groups


def spike_matmul_ref(x_packed, w, *, mode: str = "per_plane"):
    """x_packed: (M, K) or (G, M, K) uint8; w: (K, N).

    2D input -> (8, M, N) per-plane / (M, N) shift_sum, as the Pallas kernel.
    3D input (plane groups, mode="per_plane" only) -> (G, 8, M, N)."""
    if x_packed.ndim == 3:
        assert mode == "per_plane", "plane groups are temporal: per_plane only"
        return jnp.stack([spike_matmul_ref(xg, w, mode=mode)
                          for xg in x_packed])
    bits = ((x_packed[None, :, :] >> jnp.arange(8, dtype=jnp.uint8)[:, None, None])
            & jnp.uint8(1)).astype(jnp.float32)           # (8, M, K)
    per_plane = jnp.einsum("pmk,kn->pmn", bits, w.astype(jnp.float32))
    if mode == "per_plane":
        return per_plane
    scales = (2.0 ** jnp.arange(8, dtype=jnp.float32)).reshape(8, 1, 1)
    return (per_plane * scales).sum(axis=0)


def tflif_ref(x, bias=None, *, tau: float = 2.0, v_th=1.0):
    """x: (T, ...) -> (G, ...) uint8 packed spikes, G = ceil(T/8); bit j of
    group g is the spike at timestep 8g+j. The membrane state is carried
    across group boundaries (one sequential scan over all T). ``bias`` and
    ``v_th`` are scalars or arrays broadcastable against ``x.shape[1:]``
    (per-neuron thresholds carry the int8 weight-scale fold).

    Runs natively on any rank — flattening the neuron axes in-graph forces
    XLA CPU's fusion emitter into reshape-chasing loop nests that cost ~10x;
    broadcasting over the natural trailing axes vectorizes cleanly, and
    broadcast shape never changes per-element IEEE results, so exactness
    contracts are unaffected.
    """
    t_steps = x.shape[0]
    lead = x.shape[1:]
    groups = num_plane_groups(t_steps)
    if bias is None:
        bias = jnp.float32(0.0)
    v_th = jnp.asarray(v_th, jnp.float32)
    v = jnp.zeros(lead, jnp.float32)
    out = []
    for g in range(groups):
        packed = jnp.zeros(lead, jnp.uint8)
        for j in range(min(8, t_steps - 8 * g)):
            h = v + (x[8 * g + j].astype(jnp.float32) + bias - v) / tau
            s = h >= v_th
            v = jnp.where(s, 0.0, h)
            packed = packed | (s.astype(jnp.uint8) << jnp.uint8(j))
        out.append(packed)
    return jnp.stack(out)


def stdp_attention_ref(q, k, v, *, scale: float):
    """q, k, v: (BH, N, Dh) -> (Q Kt) V * scale."""
    s = jnp.einsum("bnd,bmd->bnm", q.astype(jnp.float32), k.astype(jnp.float32))
    return jnp.einsum("bnm,bmd->bnd", s, v.astype(jnp.float32)) * scale


def flash_attention_ref(q, k, v, *, scale: float, causal: bool = True):
    """q: (BH, Nq, Dh); k, v: (BH, Nkv, Dh). Exact softmax attention."""
    nq, nkv = q.shape[1], k.shape[1]
    s = jnp.einsum("bnd,bmd->bnm", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = (nkv - nq) + jnp.arange(nq)[:, None]
        kpos = jnp.arange(nkv)[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bnm,bmd->bnd", p, v.astype(jnp.float32))
