"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spike_matmul_ref(x_packed, w, *, mode: str = "per_plane"):
    """x_packed: (M, K) uint8; w: (K, N)."""
    bits = ((x_packed[None, :, :] >> jnp.arange(8, dtype=jnp.uint8)[:, None, None])
            & jnp.uint8(1)).astype(jnp.float32)           # (8, M, K)
    per_plane = jnp.einsum("pmk,kn->pmn", bits, w.astype(jnp.float32))
    if mode == "per_plane":
        return per_plane
    scales = (2.0 ** jnp.arange(8, dtype=jnp.float32)).reshape(8, 1, 1)
    return (per_plane * scales).sum(axis=0)


def tflif_ref(x, bias=None, *, tau: float = 2.0, v_th: float = 1.0):
    """x: (T, M) -> (M,) uint8 packed spikes (bit t = timestep t)."""
    t_steps, m = x.shape
    if bias is None:
        bias = jnp.zeros((m,), jnp.float32)
    v = jnp.zeros((m,), jnp.float32)
    packed = jnp.zeros((m,), jnp.uint8)
    for t in range(t_steps):
        h = v + (x[t].astype(jnp.float32) + bias - v) / tau
        s = h >= v_th
        v = jnp.where(s, 0.0, h)
        packed = packed | (s.astype(jnp.uint8) << jnp.uint8(t))
    return packed


def stdp_attention_ref(q, k, v, *, scale: float):
    """q, k, v: (BH, N, Dh) -> (Q Kt) V * scale."""
    s = jnp.einsum("bnd,bmd->bnm", q.astype(jnp.float32), k.astype(jnp.float32))
    return jnp.einsum("bnm,bmd->bnd", s, v.astype(jnp.float32)) * scale


def flash_attention_ref(q, k, v, *, scale: float, causal: bool = True):
    """q: (BH, Nq, Dh); k, v: (BH, Nkv, Dh). Exact softmax attention."""
    nq, nkv = q.shape[1], k.shape[1]
    s = jnp.einsum("bnd,bmd->bnm", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = (nkv - nq) + jnp.arange(nq)[:, None]
        kpos = jnp.arange(nkv)[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bnm,bmd->bnd", p, v.astype(jnp.float32))
