"""Unpack-free byte-LUT matmul: the CPU analogue of VESTA's multiplexer PE.

A binary spike turns multiply into *select* — VESTA's PE is a multiplexer,
not a multiplier. The software analogue on a byte-packed datapath: one uint8
of packed spikes *selects* a precomputed partial sum over its 8-row weight
chunk. Per chunk ``c`` of 8 weight rows, ``table[c, b, :]`` holds the partial
sum of rows whose bit is set in byte ``b``; the matmul then reduces to
gather-and-accumulate over the packed bytes — the ``(T, M, K)`` unpacked
plane tensor is never materialized, and the arithmetic drops from
``T*M*K*N`` multiply-adds to ``T*M*(K/8)*N`` gathered adds.

Bit layout plumbing: the inter-layer packed representation is *time*-packed
(bit j of byte ``[g, m, k]`` = timestep ``8g+j`` of neuron ``k`` — see
``core.spike``), while the LUT selects over 8 consecutive *K positions*. The
bridge is an 8x8 bit-matrix transpose (``plane_indices``), done wordwise on
two uint32 lanes (Hacker's Delight 7-3) — ~20 elementwise ops per 8 bytes,
several times cheaper than unpacking those 64 bits to float.

Exactness contract (the part that keeps the parity suite single-sourced):
float32 sums are not reorderable, and XLA's ``dot`` reduction order is both
unspecified and shape-dependent, so the LUT route does NOT try to match the
single-dot unpack oracle bitwise. Instead the route *defines* its reduction
tree — ascending-bit multiply-add folds inside a chunk, ascending-chunk adds
across chunks — built exclusively from elementwise IEEE ops whose per-element
results are shape-independent. ``lut_matmul_planes`` replays the identical
op sequence on unpacked {0,1} float planes; it is the bit-exact oracle for
this route (and what ``infer.backends.FloatBackend`` executes for LUT-planned
layers, the same emulation role it already plays for int8's threshold fold).
For integer weights (the int8 route) every partial sum is an exact small
integer, so all routes agree bitwise regardless of order; tables are then
held in int16 — half the gather bandwidth, still exact (|sum of 8| <= 1016,
chunk accumulation in int32).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
from jax import lax

K_CHUNK = 8  # weight rows selected by one byte — the PE fan-in of the paper


def num_k_chunks(k: int) -> int:
    """Number of 8-row weight chunks (= LUT gather steps) for K input rows."""
    assert k >= 1, k
    return -(-k // K_CHUNK)


def table_bytes(k: int, n: int, weights_are_int: bool) -> int:
    """Size of the cached LUT for a (K, N) kernel — the memory side of the
    memory/compute trade-off the dispatch heuristic weighs."""
    return num_k_chunks(k) * 256 * n * (2 if weights_are_int else 4)


def _is_int_kernel(w) -> bool:
    return jnp.issubdtype(w.dtype, jnp.integer)


# ---------------------------------------------------------------------------
# 8x8 bit-matrix transpose (time-packed bytes -> K-packed index bytes)
# ---------------------------------------------------------------------------

def bit_transpose8(b):
    """Transpose an 8x8 bit matrix held as 8 bytes, elementwise over leading
    axes: input ``b`` (..., 8) uint8 with rows i = bytes; output (..., 8)
    uint8 where ``out[..., j]`` bit i == ``b[..., i]`` bit j.

    Wordwise Hacker's Delight 7-3 on two little-endian uint32 lanes; the
    byte<->word marshalling is a free bitcast, and the lane swap absorbs the
    big-endian byte order the original algorithm assumes.
    """
    w = lax.bitcast_convert_type(
        b.reshape(*b.shape[:-1], 2, 4), jnp.uint32)         # (..., 2) LE words
    x, y = w[..., 1], w[..., 0]
    t = (x ^ (x >> 7)) & jnp.uint32(0x00AA00AA)
    x = x ^ t ^ (t << 7)
    t = (y ^ (y >> 7)) & jnp.uint32(0x00AA00AA)
    y = y ^ t ^ (t << 7)
    t = (x ^ (x >> 14)) & jnp.uint32(0x0000CCCC)
    x = x ^ t ^ (t << 14)
    t = (y ^ (y >> 14)) & jnp.uint32(0x0000CCCC)
    y = y ^ t ^ (t << 14)
    t = (x & jnp.uint32(0xF0F0F0F0)) | ((y >> 4) & jnp.uint32(0x0F0F0F0F))
    y = ((x << 4) & jnp.uint32(0xF0F0F0F0)) | (y & jnp.uint32(0x0F0F0F0F))
    x = t
    out = jnp.stack([y, x], axis=-1)
    return lax.bitcast_convert_type(out, jnp.uint8).reshape(b.shape)


def _pad_k(x, k: int, value=0):
    """Pad the trailing (K) axis up to a multiple of 8."""
    pad = num_k_chunks(k) * K_CHUNK - k
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths, constant_values=value)
    return x


def plane_indices(x_packed):
    """Time-packed plane groups -> per-plane LUT index bytes.

    Args:
      x_packed: (G, ..., K) uint8, bit j of [g, ..., k] = plane ``8g+j`` of
        input k (temporal planes for WSSL/ZSC, value bit-planes for SSSC
        with G == 1). Any number of row axes — the transpose runs natively
        on the caller's layout (no in-graph flatten; see ``ref.tflif_ref``).

    Returns:
      (G*8, ..., C) uint8, C = ceil(K/8): bit i of [p, ..., c] = plane p of
      input ``8c+i`` — the byte that selects chunk c's LUT entry for that
      row. Planes past the live count are all-zero bytes (the packing
      invariant keeps dead bits zero); callers slice ``[:t]``.
    """
    g, k = x_packed.shape[0], x_packed.shape[-1]
    lead = x_packed.shape[1:-1]
    c = num_k_chunks(k)
    x = _pad_k(x_packed, k).reshape(g, *lead, c, K_CHUNK)
    idx = bit_transpose8(x)                                 # [..., j] bit i
    return jnp.moveaxis(idx, -1, 1).reshape(g * K_CHUNK, *lead, c)


# ---------------------------------------------------------------------------
# Table build and gather-accumulate (the defined reduction tree)
# ---------------------------------------------------------------------------

def build_lut(w):
    """Precompute the 256 chunk partial sums: (K, N) -> (C, 256, N) table.

    ``table[c, b, :]`` = ascending-bit fold of ``bit_i(b) * w[8c+i, :]`` —
    elementwise multiply-adds only, so every entry equals the corresponding
    ``lut_matmul_planes`` partial bit for bit. Integer kernels produce an
    int16 table (exact, half the gather bandwidth); float kernels float32.
    """
    k, n = w.shape
    c = num_k_chunks(k)
    if _is_int_kernel(w):
        wc = _pad_k(w.astype(jnp.int16).T, k).T.reshape(c, K_CHUNK, n)
        bits = ((jnp.arange(256, dtype=jnp.int16)[:, None]
                 >> jnp.arange(K_CHUNK, dtype=jnp.int16)) & 1)
        tbl = jnp.zeros((c, 256, n), jnp.int16)
    else:
        wc = _pad_k(w.astype(jnp.float32).T, k).T.reshape(c, K_CHUNK, n)
        bits = ((jnp.arange(256)[:, None] >> jnp.arange(K_CHUNK)) & 1
                ).astype(jnp.float32)
        tbl = jnp.zeros((c, 256, n), jnp.float32)
    for i in range(K_CHUNK):
        tbl = tbl + bits[None, :, i, None] * wc[:, None, i, :]
    return tbl


def lut_matmul(idx, table, *, block_n: int | None = None):
    """Gather-and-accumulate: (..., C) index bytes x (C, 256, N) table ->
    (..., N) f32 accumulators (any number of row axes).

    Reduction is the defined ascending-chunk sequential fold. ``block_n``
    tiles the output columns to bound the (R, M, N)-sized gather
    intermediates (the K tiling is the chunk fold itself); tiling never
    changes per-element op order, so exactness is unaffected.
    """
    c, _, n = table.shape
    assert idx.shape[-1] == c, (idx.shape, table.shape)
    if block_n is not None and n > block_n:
        outs = [lut_matmul(idx, table[..., s:s + block_n])
                for s in range(0, n, block_n)]
        return jnp.concatenate(outs, axis=-1)
    acc_int = jnp.issubdtype(table.dtype, jnp.integer)
    gathered = jnp.take(table[0], idx[..., 0], axis=0)
    y = gathered.astype(jnp.int32) if acc_int else gathered
    for cc in range(1, c):
        g = jnp.take(table[cc], idx[..., cc], axis=0)
        y = y + (g.astype(jnp.int32) if acc_int else g)
    return y.astype(jnp.float32)


def sparse_budget(c: int, occupancy: float) -> int:
    """Static per-row gather budget for the zero-chunk-skipping route.

    ``occupancy`` is the calibrated *chunk* occupancy — the fraction of
    nonzero chunk-index bytes the layer's packed inputs carry (what
    ``infer.backends.chunk_occupancy`` measures) — so the expected nonzero
    chunks per row is ``occupancy * c``. One extra chunk of slack absorbs
    calibration jitter; rows that still exceed the budget fall back to the
    dense gather inside ``lut_matmul_sparse`` (exact, just not faster).
    """
    if not 0.0 <= occupancy <= 1.0:
        raise ValueError(f"occupancy must be in [0, 1], got {occupancy!r}")
    return min(c, max(1, math.ceil(occupancy * c) + 1))


def lut_matmul_sparse(idx, table, *, max_chunks: int,
                      block_n: int | None = None):
    """Zero-chunk-skipping gather: like ``lut_matmul`` but each row gathers
    only its first ``max_chunks`` nonzero index bytes.

    Per (plane, row), the nonzero chunk indices are compacted to the front
    via a cumsum rank (each nonzero byte's position among its row's
    nonzeros) matched against the output slots — ascending chunk order is
    inherited from the cumsum, so the fold visits the surviving chunks in
    the SAME order as the dense route. (``lax.top_k`` would compact too,
    but is ~10x slower than these elementwise ops on the CPU backend.)
    The skipped positions would have gathered ``table[c, 0, :]`` — built as
    an ascending-bit fold of ``0 * w`` it is exactly +0.0 (int16 tables: 0)
    — and ``x + (+0.0) == x`` for every accumulator value this route can
    produce, so dropping them is a bitwise identity. Slots past a row's
    nonzero count match nothing, leaving a flattened index of 0 =
    ``table[0, 0, :]``: the same zero entry. When ANY row holds more than
    ``max_chunks`` nonzero bytes the whole call falls back to the dense
    gather (``lax.cond``) — miscalibrated occupancy costs speed, never
    correctness.
    """
    c, _, n = table.shape
    assert idx.shape[-1] == c, (idx.shape, table.shape)
    assert max_chunks >= 1, max_chunks
    if max_chunks >= c:
        return lut_matmul(idx, table, block_n=block_n)
    if block_n is not None and n > block_n:
        outs = [lut_matmul_sparse(idx, table[..., s:s + block_n],
                                  max_chunks=max_chunks)
                for s in range(0, n, block_n)]
        return jnp.concatenate(outs, axis=-1)
    nz = idx != 0
    pos = jnp.cumsum(nz.astype(jnp.int32), axis=-1) - 1    # rank among nz
    slots = jnp.arange(max_chunks, dtype=jnp.int32)
    match = (pos[..., None, :] == slots[:, None]) & nz[..., None, :]
    # flattened (chunk, byte) gather index; unmatched slots sum to 0
    val = (jnp.arange(c, dtype=jnp.int32) * 256 + idx.astype(jnp.int32))
    gidx = jnp.where(match, val[..., None, :], 0).sum(-1)  # (..., B)
    nnz_max = jnp.max(pos[..., -1]) + 1
    acc_int = jnp.issubdtype(table.dtype, jnp.integer)
    flat = table.reshape(c * 256, n)

    def gather_sparse(_):
        g0 = jnp.take(flat, gidx[..., 0], axis=0)
        y = g0.astype(jnp.int32) if acc_int else g0
        for j in range(1, max_chunks):
            gj = jnp.take(flat, gidx[..., j], axis=0)
            y = y + (gj.astype(jnp.int32) if acc_int else gj)
        return y.astype(jnp.float32)

    def gather_dense(_):
        return lut_matmul(idx, table)

    return lax.cond(nnz_max <= max_chunks, gather_sparse, gather_dense, None)


def lut_matmul_pallas(idx, table, *, bm: int = 128, bn: int = 128,
                      bc: int = 32, interpret: bool = True):
    """Pallas byte-LUT matmul: (..., C) index bytes x (C, 256, N) table ->
    (..., N) f32, same contract as ``lut_matmul`` but executed by the
    grouped-grid Pallas kernel (``spike_matmul.lut_gather_matmul``) with
    the table VMEM-resident. Bit-exact against ``lut_matmul`` — the kernel
    replays the identical defined ascending-chunk fold with the identical
    accumulator dtypes. The first input axis is treated as the plane axis
    (the outermost grid dim); remaining lead axes fold into the row dim.
    """
    from .spike_matmul import lut_gather_matmul
    c = table.shape[0]
    assert idx.shape[-1] == c, (idx.shape, table.shape)
    lead = idx.shape[:-1]
    if idx.ndim == 2:
        idx3 = idx[None]                               # (1, M, C)
    else:
        idx3 = idx.reshape(idx.shape[0], -1, c)        # (P, M, C)
    y = lut_gather_matmul(idx3, table, bm=bm, bn=bn, bc=bc,
                          interpret=interpret)
    return y.reshape(*lead, table.shape[-1])


def lut_matmul_planes(planes, w):
    """The route's bit-exact oracle on unpacked planes: (R, M, K) {0,1}
    float32 x (K, N) -> (R, M, N) f32 via the IDENTICAL reduction tree as
    ``build_lut`` + ``lut_matmul`` (ascending-bit multiply-add fold per
    chunk, ascending-chunk adds). Elementwise IEEE ops only — no ``dot`` —
    so results are independent of R/M batching and match the packed gather
    route bit for bit. This is what ``FloatBackend`` runs for LUT-planned
    layers.
    """
    r, m, k = planes.shape
    n = w.shape[-1]
    c = num_k_chunks(k)
    wf = _pad_k(w.astype(jnp.float32).T, k).T.reshape(c, K_CHUNK, n)
    pc = _pad_k(planes, k).reshape(r, m, c, K_CHUNK)
    part = jnp.zeros((r, m, c, n), jnp.float32)
    for i in range(K_CHUNK):
        part = part + pc[..., i, None] * wf[None, None, :, i, :]
    y = part[:, :, 0, :]
    for cc in range(1, c):
        y = y + part[:, :, cc, :]
    return y


def shift_sum_fold(per_plane):
    """SSSC bit-plane combine with a defined order: (8, ..., N) per-plane
    accumulators -> (..., N), ``y = fold_p y + per[p] * 2^p`` ascending.
    Power-of-two scaling is exact; both the packed LUT route and its float
    emulation share this fold (XLA's ``sum(axis=0)`` reduce order is
    unspecified, so neither route may use it)."""
    y = per_plane[0]
    for p in range(1, 8):
        y = y + per_plane[p] * jnp.float32(2.0 ** p)
    return y


# ---------------------------------------------------------------------------
# Dispatch heuristic
# ---------------------------------------------------------------------------

MAX_TABLE_BYTES = 1 << 24  # 16 MiB per-layer table cap (memory trade-off)


@dataclasses.dataclass(frozen=True)
class RouteConstants:
    """Cost-model constants for ``choose_route``, in units of one dot FMA.

    The defaults were fit on the CPU microbenchmarks that motivated the LUT
    route (see docs/architecture.md): a gathered table row costs ~4x a dot
    FMA per element but covers 8 weight rows; the bit transpose replaces the
    4-bytes-per-bit unpack with ~2.5 byte-ops per packed byte. They are a
    property of the *host*, not the model — ``scripts/autotune_routes.py``
    refits them from timings and an ``ExecutionPlan`` carries them as data,
    so a committed plan pins the dispatch decisions it was tuned for.
    """
    gather_cost: float = 4.0     # per gathered table element
    transpose_cost: float = 2.5  # per packed input byte
    unpack_cost: float = 8.0     # per unpacked plane element (u8->f32 write)
    int_gather_discount: float = 0.5   # int16 tables halve gather bandwidth
    cache_bytes: int = 1 << 21   # table size where gathers stop hitting L2
    cache_penalty: float = 3.0   # gather-cost multiplier past cache_bytes
    compact_cost: float = 40.0   # sparse route: per (index byte x slot)
                                 # compaction element (cumsum + one-hot
                                 # select; N-independent, int32-bound)
    pallas_gather_cost: float = 2.0  # pallas route: per gathered table
                                     # element (one-hot MXU select row)
    pallas_dot_cost: float = 1.0     # pallas route: per unpack-dot FMA
                                     # (8 planes folded into one MXU dot)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RouteConstants":
        known = {f.name for f in dataclasses.fields(cls)}
        bad = set(d) - known
        if bad:
            raise ValueError(f"unknown route-constant keys {sorted(bad)}; "
                             f"expected a subset of {sorted(known)}")
        return cls(**d)


DEFAULT_ROUTE_CONSTANTS = RouteConstants()


def choose_route(*, m: int, k: int, n: int, g: int, t: int,
                 weights_are_int: bool = False,
                 max_table_bytes: int = MAX_TABLE_BYTES,
                 constants: RouteConstants | None = None,
                 occupancy: float | None = None) -> str:
    """Pick "lut", "lut_sparse" or "unpack" for a packed matmul of (t live
    planes, M rows, K inputs, N outputs, G plane groups) on the CPU route.

    The LUT route wins when its gather traffic (t*M*C*N table elements)
    undercuts the dot's t*M*K*N FMAs plus the t*M*K unpack writes it
    deletes; it loses when the table outgrows cache — int16 tables halve
    that pressure — or the per-layer table cap. The fallback is always the
    unpack route, which stays the bit-exact mirror of the float reference.
    ``constants`` overrides the host cost model (autotuned plans pass the
    fitted values; ``None`` keeps the committed defaults).

    ``occupancy`` is a measured/calibrated CHUNK occupancy (fraction of
    nonzero chunk-index bytes — ``infer.backends.chunk_occupancy``); when
    given, the zero-chunk-skipping gather competes too: its traffic scales
    with the *nonzero* chunks per row (``sparse_budget(c, occupancy)``
    gathers instead of c) plus an N-independent compaction term over the
    t*M*C index bytes times the slot count. ``None`` — no calibration —
    never picks the sparse route: sparsity claims must be measured, not
    assumed.
    """
    cc = DEFAULT_ROUTE_CONSTANTS if constants is None else constants
    c = num_k_chunks(k)
    tbl = table_bytes(k, n, weights_are_int)
    if tbl > max_table_bytes:
        return "unpack"
    gather_scale = cc.gather_cost * (cc.int_gather_discount
                                     if weights_are_int else 1.0)
    # cache pressure: once the table spills L2, gathered rows stop hitting
    cache_penalty = 1.0 if tbl <= cc.cache_bytes else cc.cache_penalty
    lut_cost = (t * m * c * n * gather_scale * cache_penalty
                + g * m * k * cc.transpose_cost)
    unpack_cost = t * m * k * (n + cc.unpack_cost)
    if occupancy is not None:
        budget = sparse_budget(c, occupancy)
        if budget < c:
            sparse_cost = (t * m * budget * n * gather_scale * cache_penalty
                           + g * m * k * cc.transpose_cost
                           + t * m * c * budget * cc.compact_cost)
            if sparse_cost < lut_cost and sparse_cost < unpack_cost:
                return "lut_sparse"
    return "lut" if lut_cost < unpack_cost else "unpack"


def choose_pallas_route(*, m: int, k: int, n: int, g: int, t: int,
                        weights_are_int: bool = False,
                        max_table_bytes: int = MAX_TABLE_BYTES,
                        constants: RouteConstants | None = None,
                        occupancy: float | None = None) -> str:
    """Pick "lut" or "unpack" for the Pallas backend's packed matmul.

    The Pallas kernel pair differs from the CPU routes in kind, so the
    cost model does too: the LUT route's gather is a (bm, 256) one-hot MXU
    select per chunk (``spike_matmul.gather256``) against a VMEM-resident
    table — t*M*C*N selected elements plus the G*M*K bit transpose that
    builds the index bytes — while the unpack route folds all 8 planes of
    a group into the row dim of one MXU dot (t*M*K*N FMAs, no unpack
    writes: the bits expand in-register inside the kernel). The constants
    (``pallas_gather_cost`` / ``pallas_dot_cost``) are host/device
    properties; ``scripts/autotune_routes.py --pallas`` refits them.

    ``occupancy`` is accepted for signature parity with ``choose_route``
    and ignored: the dense Pallas gather has no zero-chunk skipping (a
    pinned "lut_sparse" route runs the dense Pallas gather, which is
    bitwise identical). There is no sparse candidate to weigh.
    """
    cc = DEFAULT_ROUTE_CONSTANTS if constants is None else constants
    c = num_k_chunks(k)
    if table_bytes(k, n, weights_are_int) > max_table_bytes:
        return "unpack"
    lut_cost = (t * m * c * n * cc.pallas_gather_cost
                + g * m * k * cc.transpose_cost)
    dot_cost = t * m * k * n * cc.pallas_dot_cost
    return "lut" if lut_cost < dot_cost else "unpack"
