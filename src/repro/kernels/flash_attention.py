"""Causal FlashAttention Pallas kernel (online softmax, KV-tile streaming).

Beyond-paper infrastructure: VESTA's STDP fuses (Q Kt)V tile-wise because
spiking attention has no softmax. The SAME streaming schedule plus online
max/sum bookkeeping gives exact softmax attention for the standard (non-
spiking) assigned architectures — the score matrix never touches HBM.

Shapes: q: (BH, Nq, Dh); k, v: (BH, Nkv, Dh); causal over absolute positions
(q position offset = Nkv - Nq, i.e. the usual decode/prefill convention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, nkv_steps: int, scale: float, bq: int, bkv: int, q_offset: int,
            causal: bool):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bkv)
    if causal:
        qpos = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nkv_steps - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "bq", "bkv",
                                             "interpret"))
def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    bq: int = 128, bkv: int = 128, interpret: bool = True):
    """q: (BH, Nq, Dh); k, v: (BH, Nkv, Dh) -> (BH, Nq, Dh)."""
    bh, nq, dh = q.shape
    nkv = k.shape[1]
    bq_, bkv_ = min(bq, nq), min(bkv, nkv)
    pq, pk = (-nq) % bq_, (-nkv) % bkv_
    q_offset = nkv - nq  # causal alignment (decode convention)
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        # pad K with zeros; padded scores masked below via kpos >= nkv check
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    # mask K padding by folding it into the causal comparison: padded kpos are
    # >= nkv, and the largest legal qpos is nkv-1, so qpos >= kpos already
    # excludes them when causal=True. For non-causal, handle via explicit mask.
    if not causal and pk:
        raise NotImplementedError("non-causal with KV padding")
    nqp, nkvp = q.shape[1], k.shape[1]
    grid = (bh, nqp // bq_, nkvp // bkv_)
    y = pl.pallas_call(
        functools.partial(_kernel, nkv_steps=grid[2], scale=scale, bq=bq_,
                          bkv=bkv_, q_offset=q_offset, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv_, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv_, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nqp, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return y[:, :nq, :]
