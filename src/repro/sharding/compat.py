"""JAX-version compatibility for mesh APIs.

The repo targets the current mesh API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, size/name ``AbstractMesh``); older
releases (<= 0.4.x) spell these differently or not at all. Everything that
touches a mesh context goes through this module so model code stays
version-agnostic:

  * ``get_abstract_mesh()`` — the ambient mesh as an object with ``.empty``,
    ``.axis_names`` and ``.shape`` (a name->size mapping). On old JAX this is
    the physical mesh installed by the ``Mesh`` context manager.
  * ``set_mesh(mesh)``      — context manager activating ``mesh``.
  * ``abstract_mesh(axis_sizes, axis_names)`` — devices-free mesh for
    rule-level tests, covering both AbstractMesh constructor signatures.
"""
from __future__ import annotations

import jax

_HAS_GET_ABSTRACT = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_SET_MESH = hasattr(jax, "set_mesh")


class _EmptyMesh:
    """Stand-in for "no mesh active" matching the AbstractMesh surface."""
    empty = True
    axis_names = ()
    shape = {}


def get_abstract_mesh():
    """The mesh installed by the innermost ``set_mesh`` (never None)."""
    if _HAS_GET_ABSTRACT:
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib
    pm = mesh_lib.thread_resources.env.physical_mesh
    if pm.empty:
        return _EmptyMesh()
    return pm


def set_mesh(mesh):
    """``jax.set_mesh`` where available; else the Mesh context manager (the
    pre-0.5 spelling with identical scoping semantics)."""
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def abstract_mesh(axis_sizes, axis_names):
    """AbstractMesh(sizes, names) across both constructor signatures."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))
