"""Activation sharding hints.

``shard_hint(x, "dp", None, "model")`` pins a tensor's layout when a mesh
context is active and the dims divide evenly; otherwise it is a no-op, so
model code stays runnable on a single CPU device. "dp" expands to the
("pod", "data") axis group on multi-pod meshes.

These hints are what keep XLA's SPMD propagation from replicating the big
activations (fp32 logits, attention heads) — without them the 49k-152k-vocab
unembed replicates onto every chip.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P

from .compat import get_abstract_mesh


def shard_hint(x, *dims):
    am = get_abstract_mesh()
    if am.empty:
        return x
    names = am.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    spec = []
    for d, size in zip(dims, x.shape):
        if d is None:
            spec.append(None)
        elif d == "dp":
            dpsize = math.prod(am.shape[a] for a in dp)
            ok = dp and size % dpsize == 0
            spec.append((dp if len(dp) > 1 else dp[0]) if ok else None)
        else:
            ok = d in names and size % am.shape[d] == 0
            spec.append(d if ok else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
