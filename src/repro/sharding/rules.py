"""Path-based sharding rules: parameter paths -> PartitionSpecs.

Storage layout is FSDP x TP (ZeRO-3 style): 2-D weights shard their input dim
over the data(+pod) axes and their output dim over the model axis; MoE expert
tensors shard the expert dim over data(+pod) (expert parallelism) and the
hidden dim over model. Rules match on path *suffixes* and specify trailing
dims only — stacked-layer leading dims (L, ...) are padded with None
automatically, so the same table covers scanned and unrolled models.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..nn.module import map_with_path
from .compat import abstract_mesh  # noqa: F401  (re-export for rule tests)


def dp_axes(mesh: Mesh):
    """The data-parallel axis group: ('pod','data') on multi-pod meshes."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def serving_mesh(devices=None) -> Mesh:
    """A 1-D ``("data",)`` mesh over the host's devices — the axis a
    serving fleet replicates over. Inference replicas are pure data
    parallelism (whole-model copies, batches split across them), so the
    fleet consumes only this axis; the FSDP x TP rule table above is the
    training/large-model story."""
    devices = list(jax.devices()) if devices is None else list(devices)
    if not devices:
        raise ValueError("serving_mesh needs at least one device")
    return Mesh(np.asarray(devices), ("data",))


def replica_devices(n: int, mesh: Mesh | None = None) -> list:
    """Device assignment for ``n`` data-parallel serving replicas: replica
    ``i`` serves from device ``i % mesh_size`` along the data axis of
    ``mesh`` (default: ``serving_mesh()`` over the host).

    On a single-device host every entry is ``None`` — the fleet's
    thread-backed mode, where replicas share the default device (and the
    jitted step; see ``repro.infer.compile.replicate_model``) instead of
    paying a pointless device_put onto the device they are already on."""
    if n < 1:
        raise ValueError(f"need n >= 1 replicas, got {n!r}")
    mesh = serving_mesh() if mesh is None else mesh
    devs = list(np.asarray(mesh.devices).flat)
    if len(devs) <= 1:
        return [None] * n
    return [devs[i % len(devs)] for i in range(n)]


# (regex on path, spec builder over (dp,)) — first match wins
_RULES = [
    (r"embed/embedding$",              lambda dp: ("model", dp)),
    (r"head/kernel$",                  lambda dp: (dp, "model")),
    (r"(wq|wk|wv)/kernel$",            lambda dp: (dp, "model")),
    (r"wo/kernel$",                    lambda dp: ("model", dp)),
    (r"(gate|up)/kernel$",             lambda dp: (dp, "model")),
    (r"down/kernel$",                  lambda dp: ("model", dp)),
    (r"moe/router$",                   lambda dp: (dp, None)),
    (r"moe/w_(gate|up)$",              lambda dp: (dp, None, "model")),
    (r"moe/w_down$",                   lambda dp: (dp, "model", None)),
    (r"ssm/in_proj$",                  lambda dp: (dp, None)),
    (r"ssm/out_proj$",                 lambda dp: (None, dp)),
]


def spec_for(path: str, shape: tuple, mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    for pat, builder in _RULES:
        if re.search(pat, path):
            trailing = builder(dp)
            lead = (None,) * (len(shape) - len(trailing))
            spec = lead + tuple(trailing)
            # verify divisibility; drop axes that don't divide evenly
            fixed = []
            for dim, ax in zip(shape, spec):
                if ax is None:
                    fixed.append(None)
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                fixed.append(ax if dim % size == 0 else None)
            return P(*fixed)
    return P()  # replicate (norm scales, biases, small vectors)


def param_shardings(mesh: Mesh, params_shapes):
    """params_shapes: pytree of ShapeDtypeStructs (from jax.eval_shape)."""
    return map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for(path, leaf.shape, mesh)),
        params_shapes)


def opt_state_shardings(mesh: Mesh, opt_shapes):
    """Moments share the param rules (paths are nested under m/ and v/)."""
    def fn(path, leaf):
        clean = re.sub(r"^(m|v)/", "", path)
        if path == "step":
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, spec_for(clean, leaf.shape, mesh))
    return map_with_path(fn, opt_shapes)


def batch_shardings(mesh: Mesh, batch_shapes):
    """Inputs: shard the batch dim over dp when divisible, else replicate."""
    dp = dp_axes(mesh)
    dpsize = 1
    for a in dp:
        dpsize *= mesh.shape[a]
    dp_spec = dp if len(dp) > 1 else dp[0]

    def fn(path, leaf):
        shape = leaf.shape
        if not shape:
            return NamedSharding(mesh, P())
        if "mrope" in path:  # (3, B, S)
            if shape[1] % dpsize == 0:
                return NamedSharding(mesh, P(None, dp_spec))
            return NamedSharding(mesh, P())
        if shape[0] % dpsize == 0:
            return NamedSharding(mesh, P(dp_spec))
        return NamedSharding(mesh, P())
    return map_with_path(fn, batch_shapes)


def cache_shardings(mesh: Mesh, cache_shapes):
    """Decode caches: KV (B, KV, S, dh) -> batch over dp if divisible, S over
    model (sequence-sharded cache => per-chip cache bytes / 16). SSM states
    shard batch only. `positions` vectors replicate."""
    dp = dp_axes(mesh)
    dpsize = 1
    for a in dp:
        dpsize *= mesh.shape[a]
    dp_spec = dp if len(dp) > 1 else dp[0]

    def fn(path, leaf):
        shape = leaf.shape
        if not shape:
            return NamedSharding(mesh, P())
        if path.endswith("positions"):
            # per-row slot positions (..., B, length): batch over dp
            if len(shape) >= 2 and shape[-2] % dpsize == 0:
                return NamedSharding(mesh, P(
                    *(None,) * (len(shape) - 2), dp_spec, None))
            return NamedSharding(mesh, P())
        b_ok = shape[-4] % dpsize == 0 if len(shape) >= 4 else False
        if re.search(r"(kv/k|kv/v|cross_k|cross_v)$", path) and len(shape) >= 4:
            seq_ok = shape[-2] % mesh.shape["model"] == 0
            lead = (None,) * (len(shape) - 4)
            return NamedSharding(mesh, P(
                *lead, dp_spec if b_ok else None, None,
                "model" if seq_ok else None, None))
        # ssm / conv states: batch over dp. State is (..., B, H, P, N) and
        # conv buffer is (..., B, k-1, C) — locate B from the right so the
        # same rule covers stacked (scan) and per-layer (unrolled) trees.
        if path.endswith("ssm"):
            bidx = len(shape) - 4
        elif path.endswith("conv"):
            bidx = len(shape) - 3
        else:
            return NamedSharding(mesh, P())
        if bidx >= 0 and shape[bidx] % dpsize == 0:
            spec = [None] * len(shape)
            spec[bidx] = dp_spec
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())
    return map_with_path(fn, cache_shapes)
