"""Sharding: path-based parameter rules + activation hints.

The public surface, in three layers:

* ``rules`` — path -> PartitionSpec tables for parameters, optimizer
  state, batches and caches (FSDP x TP storage layout), plus the serving
  fleet's data-parallel axis: ``serving_mesh()`` / ``replica_devices()``
  assign whole-model replicas to devices (``repro.serve.fleet`` consumes
  these; on a single-device host the assignment degrades to thread-backed
  ``None`` entries).
* ``hints`` — ``shard_hint`` activation layout pins that no-op without an
  active mesh, so model code runs unchanged on one CPU device.
* ``compat`` — the jax-version shims (``set_mesh``,
  ``get_abstract_mesh``, ``abstract_mesh``) everything mesh-touching goes
  through.
"""
from . import compat, hints, rules
from .compat import abstract_mesh, get_abstract_mesh, set_mesh
from .hints import shard_hint
from .rules import (batch_shardings, cache_shardings, dp_axes,
                    opt_state_shardings, param_shardings, replica_devices,
                    serving_mesh, spec_for)

__all__ = [
    # submodules
    "rules", "hints", "compat",
    # rule tables + fleet placement
    "dp_axes", "spec_for", "param_shardings", "opt_state_shardings",
    "batch_shardings", "cache_shardings", "serving_mesh", "replica_devices",
    # activation hints
    "shard_hint",
    # version shims
    "set_mesh", "get_abstract_mesh", "abstract_mesh",
]
