"""Sharding: path-based parameter rules + activation hints."""
