"""Fault tolerance for long multi-pod runs: heartbeats, straggler detection,
and a restart policy — the control plane a 1000-node deployment wraps around
the SPMD data plane.

JAX's multi-controller runtime fails STOP-THE-WORLD on a node loss (a
collective times out and every process raises). The recovery loop is
therefore structural, not per-op:

    monitor -> detect (dead node / straggler / NaN) -> decide
            -> restore last committed checkpoint -> resume (maybe elastic)

Everything here is pure-Python control plane and runs identically on CPU;
the tests inject synthetic failures. `TrainSupervisor.run` is the generic
retry harness `launch/train.py` uses.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable


# ---------------------------------------------------------------------------
# heartbeats
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-node liveness. On real clusters nodes POST heartbeats to a
    coordinator; here `beat()` is called directly (tests inject silence)."""

    n_nodes: int
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self.last_beat = {i: now for i in range(self.n_nodes)}

    def beat(self, node: int, t: float | None = None):
        self.last_beat[node] = self.clock() if t is None else t

    def dead_nodes(self) -> list[int]:
        now = self.clock()
        return [n for n, t in self.last_beat.items()
                if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_nodes()


# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerDetector:
    """EWMA + z-score over per-node step times.

    A node is a straggler when its step time deviates from the fleet median
    by more than `z_thresh` fleet-MAD units for `patience` consecutive steps.
    Mitigation at scale: exclude the node and reshard (elastic), or swap in a
    hot spare; the decision callback gets the node list.
    """

    n_nodes: int
    alpha: float = 0.2            # EWMA smoothing
    z_thresh: float = 4.0
    patience: int = 3

    def __post_init__(self):
        self.ewma = [None] * self.n_nodes
        self.strikes = [0] * self.n_nodes

    def update(self, step_times: list[float]) -> list[int]:
        """Feed one step's per-node durations; returns current stragglers."""
        assert len(step_times) == self.n_nodes
        for i, t in enumerate(step_times):
            self.ewma[i] = t if self.ewma[i] is None else \
                self.alpha * t + (1 - self.alpha) * self.ewma[i]
        vals = sorted(self.ewma)
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2]
        # floor at 5% of the median: a perfectly uniform fleet (MAD 0) must
        # not flag nodes for noise, and recovered nodes must un-flag as
        # their EWMA decays back toward the median
        scale = max(mad, 0.05 * max(med, 1e-9))
        out = []
        for i, v in enumerate(self.ewma):
            z = (v - med) / scale
            if z > self.z_thresh:
                self.strikes[i] += 1
            else:
                self.strikes[i] = 0
            if self.strikes[i] >= self.patience:
                out.append(i)
        return out


# ---------------------------------------------------------------------------
# restart policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RestartPolicy:
    """Exponential backoff with a failure budget (rolling window)."""

    max_restarts: int = 10
    window_s: float = 3600.0
    backoff_s: float = 5.0
    backoff_mult: float = 2.0
    max_backoff_s: float = 300.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self.failures: list[float] = []

    def record_failure(self) -> bool:
        """Record one failure; returns True if a restart is allowed."""
        now = self.clock()
        self.failures = [t for t in self.failures if now - t < self.window_s]
        self.failures.append(now)
        return len(self.failures) <= self.max_restarts

    def next_delay(self) -> float:
        n = len(self.failures)
        return min(self.backoff_s * self.backoff_mult ** max(0, n - 1),
                   self.max_backoff_s)


# ---------------------------------------------------------------------------
# NaN / loss-spike guard
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LossGuard:
    """Detects divergence: NaN/inf loss, or loss > spike_mult x running min.
    On trigger the supervisor restores the last checkpoint and (optionally)
    skips the bad data window."""

    spike_mult: float = 10.0
    warmup: int = 20

    def __post_init__(self):
        self.best = math.inf
        self.n = 0

    def check(self, loss: float) -> bool:
        """True => healthy; False => diverged."""
        self.n += 1
        if math.isnan(loss) or math.isinf(loss):
            return False
        if self.n > self.warmup and loss > self.spike_mult * max(self.best, 1e-9):
            return False
        self.best = min(self.best, loss)
        return True


# ---------------------------------------------------------------------------
# the supervisor
# ---------------------------------------------------------------------------

class NodeFailure(RuntimeError):
    """Raised by the step function when the collective runtime dies
    (in tests: injected)."""


@dataclasses.dataclass
class TrainSupervisor:
    """Generic restart harness:

        sup = TrainSupervisor(policy, make_state, run_segment)
        sup.run()

    `make_state(restore_step)` builds/(re)loads training state;
    `run_segment(state)` advances until failure (raising NodeFailure) or
    completion (returning None) or a checkpoint boundary (returning state').
    The harness owns backoff, the failure budget, and the restart loop; it
    is deliberately ignorant of JAX so the tests can drive it with fakes.
    """

    policy: RestartPolicy
    make_state: Callable
    run_segment: Callable
    sleep: Callable[[float], None] = time.sleep

    def run(self):
        state = self.make_state(None)
        restarts = 0
        while True:
            try:
                state = self.run_segment(state)
                if state is None:
                    return {"restarts": restarts, "completed": True}
            except NodeFailure:
                if not self.policy.record_failure():
                    return {"restarts": restarts, "completed": False,
                            "reason": "failure budget exhausted"}
                self.sleep(self.policy.next_delay())
                restarts += 1
                state = self.make_state("latest")
