"""Spike-form data handling: bit-packing and encodings.

VESTA's PE unit feeds 8 binary inputs against one shared 8-bit weight. The
TPU-native analogue is *storage*: spikes live packed 8-per-uint8 in HBM (the
"Small Input SRAM" / "Output SRAM" of the paper), and kernels unpack them in
VMEM. This is where the 8x activation-bandwidth saving comes from.

Plane semantics:
  * temporal packing  — the 8 bits of a byte are 8 consecutive timesteps:
    used by ZSC / WSSL / STDP. Each plane is an independent output. For
    T > 8 the packed tensor carries a leading *plane-group* axis of size
    G = ceil(T/8); group g holds timesteps 8g .. 8g+7.
  * bit-plane packing — the 8 bits are the binary expansion of a uint8 pixel:
    used by SSSC. Planes are summed with weights 2^k (always exactly 8
    planes, so never more than one group).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_POWERS = 2 ** jnp.arange(8, dtype=jnp.uint8)


def pack_bits(x, axis: int = -1):
    """Pack a binary {0,1} array along ``axis`` (size must be multiple of 8)
    into uint8. Output has that axis shrunk 8x."""
    x = jnp.moveaxis(x, axis, -1)
    assert x.shape[-1] % 8 == 0, f"pack axis {x.shape[-1]} not multiple of 8"
    x = x.reshape(*x.shape[:-1], x.shape[-1] // 8, 8).astype(jnp.uint8)
    packed = (x * _POWERS).sum(axis=-1, dtype=jnp.uint32).astype(jnp.uint8)
    return jnp.moveaxis(packed, -1, axis)


def unpack_bits(x, axis: int = -1, *, count: int = 8, dtype=jnp.float32):
    """Inverse of pack_bits: uint8 -> {0,1} planes; axis grows 8x (or ``count``
    bits per byte if count < 8)."""
    x = jnp.moveaxis(x, axis, -1)
    bits = (x[..., None] >> jnp.arange(count, dtype=jnp.uint8)) & jnp.uint8(1)
    bits = bits.reshape(*x.shape[:-1], x.shape[-1] * count).astype(dtype)
    return jnp.moveaxis(bits, -1, axis)


def num_plane_groups(t: int) -> int:
    """Number of uint8 plane groups needed for a T-timestep spike train."""
    assert t >= 1, t
    return -(-t // 8)


def pack_timesteps(spikes, *, time_axis: int = 0):
    """Temporal packing for the inference datapath: a (T, ...) binary spike
    train becomes ``G = ceil(T/8)`` bytes per neuron, returned with a leading
    *plane-group* axis: output (G, ...) uint8 where bit j of group g is the
    spike at timestep ``8*g + j`` (matching ``kernels.ref.tflif_ref`` output).
    Bits past T-1 in the last group are zero. The T axis is consumed; all
    other axes keep their layout."""
    t = spikes.shape[time_axis]
    g = num_plane_groups(t)
    x = jnp.moveaxis(spikes, time_axis, 0).astype(jnp.uint8)
    pad = g * 8 - t
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad, *x.shape[1:]), jnp.uint8)], axis=0)
    x = x.reshape(g, 8, *x.shape[1:])
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(
        (1, 8) + (1,) * (x.ndim - 2))
    return jnp.bitwise_or.reduce(x << shifts, axis=1)


def packed_occupancy(packed, t: int) -> float:
    """Mean firing rate of a ``(G, ...)`` packed spike tensor over its ``t``
    live timesteps: set bits / (t * neurons). Bits past ``t - 1`` in the
    last group are zero by the ``pack_timesteps`` invariant, so a plain
    popcount over every byte is exact — no unpack, no masking. Accepts
    numpy or jax input (the readout is a host-side float either way); this
    is the firing-rate number the serving occupancy EWMAs and the event
    front end's per-window readout share."""
    g = packed.shape[0]
    assert g == num_plane_groups(t), (g, t)
    x = np.asarray(packed, np.uint8)
    neurons = x.size // g if g else 0
    if not neurons:
        return 0.0
    return float(np.unpackbits(x.reshape(-1)).sum()) / (t * neurons)


def unpack_timesteps(packed, t: int, *, time_axis: int = 0,
                     dtype=jnp.float32):
    """Inverse of ``pack_timesteps``: (G, ...) uint8 plane groups -> (T, ...)
    binary planes inserted at ``time_axis`` (bit j of group g = timestep
    ``8*g + j``)."""
    g = packed.shape[0]
    assert g == num_plane_groups(t), (g, t)
    bits = (packed[:, None, ...] >> jnp.arange(8, dtype=jnp.uint8).reshape(
        (1, 8) + (1,) * (packed.ndim - 1))) & jnp.uint8(1)
    planes = bits.reshape(g * 8, *packed.shape[1:])[:t]
    return jnp.moveaxis(planes.astype(dtype), 0, time_axis)


def bitplanes_u8(x, *, dtype=jnp.float32):
    """uint8 tensor (...,) -> (8, ...) binary planes, LSB first (SSSC input)."""
    planes = (x[None, ...] >> jnp.arange(8, dtype=jnp.uint8).reshape(
        (8,) + (1,) * x.ndim)) & jnp.uint8(1)
    return planes.astype(dtype)


def structured_spikes(key, *, t: int, shape: tuple, rate: float,
                      chunk: int = 8, group_rate: float = 0.9):
    """Random packed spikes at overall firing rate ``rate`` with
    CHANNEL-STRUCTURED sparsity: an exact count of ``chunk``-aligned
    channel groups is active (shared across rows and timesteps) and only
    those fire, each active channel at ``group_rate``. Returns
    ``(G, *shape)`` uint8 plane groups via ``pack_timesteps``.

    Why not iid bits: at iid rate p, a K-chunk of 8 channels is all-zero
    with probability ``(1-p)^8`` (~6% at p=0.3) — nearly nothing for a
    zero-chunk skipper to skip. Trained SNNs are not iid: whole channels
    go quiet together while the surviving ones fire often (the layer-wise
    sparsity structure sparse-accelerator papers exploit), which
    concentrates the zeros into skippable chunks. Here the active-group
    fraction is ``rate / group_rate``, so the resulting CHUNK occupancy
    (what the sparse route's budget is sized from) tracks the firing rate
    ~1:1 instead of doubling it; the active-group count is exact, not a
    Bernoulli draw, so the occupancy a benchmark measures is the one it
    asked for.

    The last axis of ``shape`` is the channel axis and must be a multiple
    of ``chunk``; ``rate`` must not exceed ``group_rate``.
    """
    assert 0.0 <= rate <= group_rate <= 1.0, (rate, group_rate)
    *lead, channels = shape
    assert channels % chunk == 0, (channels, chunk)
    if rate == 0.0:
        return jnp.zeros((num_plane_groups(t), *shape), jnp.uint8)
    kg, kb = jax.random.split(key)
    groups = channels // chunk
    n_active = max(1, round(rate / group_rate * groups))
    active = jnp.zeros(groups, bool).at[
        jax.random.permutation(kg, groups)[:n_active]].set(True)
    active = jnp.repeat(active, chunk)            # (channels,) group mask
    # in-group rate chosen so the overall rate stays ``rate`` after masking
    bits = jax.random.bernoulli(kb, min(1.0, rate * groups / n_active),
                                (t, *lead, channels))
    return pack_timesteps((bits & active).astype(jnp.uint8))


def rate_decode(spikes, axis: int = 0):
    """Spike train -> rate (mean over timesteps); classification readout."""
    return spikes.astype(jnp.float32).mean(axis=axis)


def space_to_depth(x, block: int = 2):
    """(..., H, W, C) -> (..., H/b, W/b, b*b*C). This *is* the ZSC zig-zag
    placement: a 2x2/s2 convolution becomes a plain matmul over 4C features."""
    *lead, h, w, c = x.shape
    assert h % block == 0 and w % block == 0, (h, w, block)
    x = x.reshape(*lead, h // block, block, w // block, block, c)
    x = jnp.moveaxis(x, -4, -3)  # (..., H/b, W/b, b, b, C)
    return x.reshape(*lead, h // block, w // block, block * block * c)
