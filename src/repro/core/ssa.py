"""Spiking Self-Attention (SSA) — the attention of Spikformer V2.

Q, K, V are spike tensors (binary), produced by Linear+BN+LIF stacks; the
attention map is ``(Q Kt) V * scale`` with NO softmax (spikes are non-negative
so no normalization is needed — Spikformer uses a fixed scale instead). That
is exactly what makes VESTA's STDP tiling possible: V columns are consumed as
soon as they are produced.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..nn.module import KeyStream
from ..nn.layers import linear_init
from .lif import bn_init, bn_train_apply, bn_apply, tflif
from .unified import wssl, stdp


def ssa_init(key, dim: int, heads: int, dtype=jnp.float32):
    ks = KeyStream(key)
    p = {}
    for name in ("wq", "wk", "wv", "wo"):
        p[name] = linear_init(ks(), dim, dim, bias=False, dtype=dtype)
        p[name + "_bn"] = bn_init(dim, dtype)
    return p


def _lin_bn_lif(pw, pbn, x, *, train: bool):
    """spikes (T,B,N,D) -> Linear -> BN -> TFLIF -> spikes. Returns (s, stats)."""
    y = wssl(x, pw["kernel"])                    # (T,B,N,F) accumulator
    if train:
        y, stats = bn_train_apply(pbn, y, axes=(0, 1, 2))
    else:
        y, stats = bn_apply(pbn, y), None
    return tflif(y), stats


def ssa_apply(p, x, *, heads: int, scale: float, train: bool = False):
    """x: (T, B, N, D) spikes -> (T, B, N, D) spikes, plus BN-stat updates."""
    t, b, n, d = x.shape
    dh = d // heads
    new_stats = {}
    q, st = _lin_bn_lif(p["wq"], p["wq_bn"], x, train=train); new_stats["wq_bn"] = st
    k, st = _lin_bn_lif(p["wk"], p["wk_bn"], x, train=train); new_stats["wk_bn"] = st
    v, st = _lin_bn_lif(p["wv"], p["wv_bn"], x, train=train); new_stats["wv_bn"] = st

    def to_heads(z):
        return z.reshape(t, b, n, heads, dh).transpose(0, 1, 3, 2, 4)

    attn = stdp(to_heads(q), to_heads(k), to_heads(v), scale=scale)  # (T,B,H,N,dh)
    attn = tflif(attn)                       # spike the attention output
    attn = attn.transpose(0, 1, 3, 2, 4).reshape(t, b, n, d)
    out, st = _lin_bn_lif(p["wo"], p["wo_bn"], attn, train=train); new_stats["wo_bn"] = st
    return out, new_stats
