"""Spikformer V2-8-512-IAND — the model VESTA executes (paper Fig. 1).

Structure:
  SCS  — Spiking Convolutional Stem: 4 conv layers, 2x2 kernel, stride 2
         (224 -> 14; channels 3 -> 64 -> 128 -> 256 -> 512). Layer 0 input is
         an 8-bit image => SSSC; layers 1..3 have spike inputs => ZSC.
  8 x Spikformer encoder blocks: SSA + MLP(512 -> 2048 -> 512), every linear
         followed by BN + LIF (=> TFLIF in hardware), IAND spike residuals.
  Head — rate decode over T=4 timesteps, mean over tokens, Linear -> 1000.

All activations between layers are binary spikes (the IAND variant's "pure
binary inter-layer propagation"), which is the property the whole VESTA
datapath relies on.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from ..nn.module import KeyStream, param_count
from ..nn.layers import linear_init, linear
from .lif import bn_init, bn_train_apply, bn_apply, tflif, fold_bn
from .spike import rate_decode
from .unified import sssc, zsc, wssl
from .ssa import ssa_init, ssa_apply


@dataclasses.dataclass(frozen=True)
class SpikformerConfig:
    img_size: int = 224
    in_channels: int = 3
    timesteps: int = 4
    dim: int = 512
    depth: int = 8
    heads: int = 8
    mlp_ratio: int = 4
    num_classes: int = 1000
    scs_channels: tuple = (64, 128, 256, 512)
    residual: str = "iand"          # "iand" (SEW IAND, keeps binary) or "add"
    attn_scale: float = 0.125

    @property
    def tokens(self) -> int:
        side = self.img_size // (2 ** len(self.scs_channels))
        return side * side

    def scaled(self, *, img_size=32, dim=64, depth=2, heads=2, classes=10,
               timesteps=None):
        """Reduced config for CPU smoke tests. ``timesteps`` overrides T
        (any T >= 1 — the packed datapath uses ceil(T/8) plane groups)."""
        return dataclasses.replace(
            self, img_size=img_size, dim=dim, depth=depth, heads=heads,
            num_classes=classes, scs_channels=(8, 16, 32, dim),
            timesteps=self.timesteps if timesteps is None else timesteps)


def init(key, cfg: SpikformerConfig, dtype=jnp.float32):
    ks = KeyStream(key)
    p = {"scs": {}, "blocks": {}, "head": linear_init(
        ks(), cfg.dim, cfg.num_classes, bias=True, dtype=dtype)}
    cin = cfg.in_channels
    for i, cout in enumerate(cfg.scs_channels):
        p["scs"][f"conv{i}"] = {
            "kernel": jax.random.normal(ks(), (2, 2, cin, cout), dtype)
            * (1.0 / jnp.sqrt(4.0 * cin)),
            "bn": bn_init(cout, dtype),
        }
        cin = cout
    hidden = cfg.dim * cfg.mlp_ratio
    for i in range(cfg.depth):
        p["blocks"][f"b{i}"] = {
            "ssa": ssa_init(ks(), cfg.dim, cfg.heads, dtype),
            "mlp": {
                "fc1": linear_init(ks(), cfg.dim, hidden, bias=False, dtype=dtype),
                "fc1_bn": bn_init(hidden, dtype),
                "fc2": linear_init(ks(), hidden, cfg.dim, bias=False, dtype=dtype),
                "fc2_bn": bn_init(cfg.dim, dtype),
            },
        }
    return p


def _combine(new, res, mode: str):
    if mode == "iand":
        # SEW IAND: (NOT new) AND res — keeps activations strictly binary.
        return (1.0 - new) * res
    return new + res


def _bn_lif(pbn, y, axes, *, train: bool):
    if train:
        y, stats = bn_train_apply(pbn, y, axes=axes)
    else:
        y, stats = bn_apply(pbn, y), None
    return tflif(y), stats


def apply(params, images_u8, cfg: SpikformerConfig, *, train: bool = False):
    """images_u8: (B, H, W, C) uint8. Returns (logits, bn_stat_updates)."""
    t = cfg.timesteps
    stats = {"scs": {}, "blocks": {}}

    # --- SCS stem ---------------------------------------------------------
    # Layer 0: SSSC on the 8-bit image; identical accumulator for every
    # timestep (the image does not change across T), so compute once.
    c0 = params["scs"]["conv0"]
    y = sssc(images_u8, c0["kernel"] * (1.0 / 255.0))   # (B,H/2,W/2,C0), fp
    y = jnp.broadcast_to(y[None], (t, *y.shape))
    x, st = _bn_lif(c0["bn"], y, axes=(0, 1, 2, 3), train=train)
    stats["scs"]["conv0"] = st
    # Layers 1..3: ZSC on spike inputs.
    for i in range(1, len(cfg.scs_channels)):
        ci = params["scs"][f"conv{i}"]
        y = zsc(x, ci["kernel"])                        # (T,B,H/2,W/2,Ci)
        x, st = _bn_lif(ci["bn"], y, axes=(0, 1, 2, 3), train=train)
        stats["scs"][f"conv{i}"] = st

    # --- tokens -----------------------------------------------------------
    tt, b, h, w, c = x.shape
    x = x.reshape(tt, b, h * w, c)                      # (T,B,N,D) spikes

    # --- encoder blocks ----------------------------------------------------
    for i in range(cfg.depth):
        blk = params["blocks"][f"b{i}"]
        bstats = {}
        attn, st = ssa_apply(blk["ssa"], x, heads=cfg.heads,
                             scale=cfg.attn_scale, train=train)
        bstats["ssa"] = st
        x = _combine(attn, x, cfg.residual)
        mlp = blk["mlp"]
        y = wssl(x, mlp["fc1"]["kernel"])               # MLP1 (512 -> 2048)
        s1, st = _bn_lif(mlp["fc1_bn"], y, axes=(0, 1, 2), train=train)
        bstats["fc1_bn"] = st
        y = wssl(s1, mlp["fc2"]["kernel"])              # MLP2 (2048 -> 512)
        s2, st = _bn_lif(mlp["fc2_bn"], y, axes=(0, 1, 2), train=train)
        bstats["fc2_bn"] = st
        x = _combine(s2, x, cfg.residual)
        stats["blocks"][f"b{i}"] = bstats

    # --- head ---------------------------------------------------------------
    rate = rate_decode(x, axis=0).mean(axis=1)          # (B, D)
    logits = linear(params["head"], rate)
    return logits, stats


def merge_bn_stats(params, stats):
    """Write the EMA'd BN running stats produced by a training step back into
    the param tree (stats has the same topology with {mean,var} leaves)."""
    out = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy

    def rec(p, s):
        for k, v in s.items():
            if v is None:
                continue
            if isinstance(v, dict) and "mean" in v and "var" in v:
                tgt = p[k] if k in p else None
                if tgt is None:
                    continue
                tgt["mean"], tgt["var"] = v["mean"], v["var"]
            elif isinstance(v, dict):
                child = p.get(k, p)
                rec(child if isinstance(child, dict) else p, v)

    # stats paths: scs/convI -> params['scs'][convI]['bn']; blocks/bI/{ssa/*_bn, fcJ_bn}
    for name, st in stats.get("scs", {}).items():
        if st is not None:
            out["scs"][name]["bn"] = {**out["scs"][name]["bn"], **st}
    for bname, bstats in stats.get("blocks", {}).items():
        blk = out["blocks"][bname]
        ssa_st = bstats.get("ssa") or {}
        for wn, st in ssa_st.items():
            if st is not None:
                blk["ssa"][wn] = {**blk["ssa"][wn], **st}
        for fc in ("fc1_bn", "fc2_bn"):
            st = bstats.get(fc)
            if st is not None:
                blk["mlp"][fc] = {**blk["mlp"][fc], **st}
    return out


def fold_inference_params(params, cfg: SpikformerConfig):
    """Fold every BN into its preceding conv/linear (the TFLIF merge): the
    inference graph then contains only matmuls + LIF comparisons, exactly the
    layer set VESTA executes. Returns a new tree of {kernel, bias} pairs."""
    out = {"scs": {}, "blocks": {}, "head": params["head"]}
    for i in range(len(cfg.scs_channels)):
        c = params["scs"][f"conv{i}"]
        kern = c["kernel"] if i > 0 else c["kernel"] * (1.0 / 255.0)
        k2 = kern.reshape(-1, kern.shape[-1])
        kf, bf = fold_bn(k2, None, c["bn"])
        out["scs"][f"conv{i}"] = {"kernel": kf, "bias": bf}
    for bi, blk in params["blocks"].items():
        fb = {"ssa": {}, "mlp": {}}
        for wn in ("wq", "wk", "wv", "wo"):
            kf, bf = fold_bn(blk["ssa"][wn]["kernel"], None, blk["ssa"][wn + "_bn"])
            fb["ssa"][wn] = {"kernel": kf, "bias": bf}
        for fc in ("fc1", "fc2"):
            kf, bf = fold_bn(blk["mlp"][fc]["kernel"], None, blk["mlp"][fc + "_bn"])
            fb["mlp"][fc] = {"kernel": kf, "bias": bf}
        out["blocks"][bi] = fb
    return out


def forward_folded(folded, images_u8, cfg: SpikformerConfig, *, backend,
                   layer_occupancy=None):
    """The inference forward over BN-folded params through a pluggable
    execution backend — the graph VESTA executes: matmuls + LIF comparisons
    only, with every activation between layers a binary spike train.

    ``backend`` implements the dataflow ops over an opaque activation type;
    the implementations live in ``repro.infer.backends`` (float {0,1} spike
    trains for the differentiable reference, packed uint8 plane groups for
    the hardware-shaped path). ``folded`` may be the float tree from
    ``fold_inference_params`` or its int8 quantization
    (``infer.quant.quantize_folded``) — layers carrying a ``scale`` leaf are
    dispatched with it — and may additionally carry per-layer ``lut`` leaves
    (the route-planning pass's cached byte-LUT tables,
    ``infer.compile.plan_route_tables``):
    the packed backend then runs the unpack-free gather route and the float
    backend its fold-order emulation, keeping the pair bit-exact.

    ``layer_occupancy`` maps layer paths ("scs/conv0", "blocks/b0/ssa/wq",
    ...) to STATIC calibrated chunk-occupancy floats for layers the plan
    routed "lut_sparse". It is closed over, never part of the traced tree
    — the sparse gather budget must be a compile-time constant. The kwarg
    is forwarded to a backend method only for layers that carry a value,
    so backends without the ``occupancy`` parameter keep working under
    dense plans. Returns (B, num_classes) logits.
    """
    t = cfg.timesteps
    occ = layer_occupancy or {}

    def extra(path):
        o = occ.get(path)
        return {} if o is None else {"occupancy": o}

    def wssl(z, layer, path):
        return backend.wssl_lif(z, layer["kernel"], layer["bias"], t=t,
                                scale=layer.get("scale"),
                                lut=layer.get("lut"), **extra(path))

    c0 = folded["scs"]["conv0"]
    x = backend.sssc_lif(images_u8, c0["kernel"], c0["bias"], t=t,
                         scale=c0.get("scale"), lut=c0.get("lut"),
                         **extra("scs/conv0"))
    for i in range(1, len(cfg.scs_channels)):
        ci = folded["scs"][f"conv{i}"]
        x = backend.zsc_lif(x, ci["kernel"], ci["bias"], t=t,
                            scale=ci.get("scale"), lut=ci.get("lut"),
                            **extra(f"scs/conv{i}"))
    x = backend.to_tokens(x)

    for i in range(cfg.depth):
        blk = folded["blocks"][f"b{i}"]
        ssa, mlp = blk["ssa"], blk["mlp"]
        bp = f"blocks/b{i}"
        q = wssl(x, ssa["wq"], f"{bp}/ssa/wq")
        k = wssl(x, ssa["wk"], f"{bp}/ssa/wk")
        v = wssl(x, ssa["wv"], f"{bp}/ssa/wv")
        att = backend.stdp_lif(q, k, v, heads=cfg.heads,
                               scale=cfg.attn_scale, t=t)
        att = wssl(att, ssa["wo"], f"{bp}/ssa/wo")
        x = backend.residual(att, x, cfg.residual)
        # backends exposing ``mlp_pair_lif`` may fuse the fc1 -> LIF -> fc2
        # step into one kernel (packed spikes never unpacked in HBM); a
        # None return means "not applicable here" and the two-layer
        # composition below is the universal fallback — both are bit-exact
        # against each other, so the choice never changes logits
        s2 = None
        pair = getattr(backend, "mlp_pair_lif", None)
        if pair is not None:
            s2 = pair(x, mlp["fc1"], mlp["fc2"], t=t,
                      **extra(f"{bp}/mlp/fc1"))
        if s2 is None:
            s1 = wssl(x, mlp["fc1"], f"{bp}/mlp/fc1")
            s2 = wssl(s1, mlp["fc2"], f"{bp}/mlp/fc2")
        x = backend.residual(s2, x, cfg.residual)

    rate = backend.rate(x, t=t)                         # (B, D)
    head = folded["head"]
    logits = rate @ head["kernel"].astype(rate.dtype)
    if "bias" in head:
        logits = logits + head["bias"].astype(logits.dtype)
    return logits


def loss_fn(params, batch, cfg: SpikformerConfig, *, train: bool = True):
    """Cross-entropy over classes; returns (loss, (accuracy, stats))."""
    logits, stats = apply(params, batch["image"], cfg, train=train)
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return nll, (acc, stats)
