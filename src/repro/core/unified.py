"""The unified PE: VESTA's four dataflows expressed on one engine.

All four computational layer types of the spiking transformer reduce to ONE
primitive — a weight-stationary matmul over binary planes — differing only in
(a) where the planes come from and (b) how planes are reduced:

  WSSL  planes = T timesteps of spikes,   per-plane outputs (weight stationary)
  ZSC   planes = T timesteps of spikes,   conv2x2/s2 == space-to-depth + WSSL
  SSSC  planes = 8 bit-planes of a uint8, outputs summed with scales 2^k
  STDP  planes = T timesteps,             (Q Kt) V fused tile-wise, no softmax

This module is the float/differentiable reference used for training (spikes
are {0,1} floats carrying surrogate gradients). The packed-bit inference path
lives in ``repro.kernels`` (Pallas, `spike_matmul` / `stdp_attention`), with
``repro.kernels.ops`` dispatching between them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .spike import space_to_depth, bitplanes_u8


def wssl(spikes, kernel, bias=None, *, compute_dtype=jnp.float32):
    """Weight-Stationary Spiking Linear.

    spikes: (T, ..., D) binary; kernel: (D, F). The T axis is folded into the
    row dimension so one weight fetch serves all timesteps (the paper computes
    one output column per weight column across the whole T-fused input map;
    XLA's matmul does the same weight-stationary loop once T is folded).
    """
    t = spikes.shape[0]
    lead = spikes.shape[1:-1]
    d = spikes.shape[-1]
    x = spikes.reshape((-1, d)).astype(compute_dtype)
    y = x @ kernel.astype(compute_dtype)
    if bias is not None:
        y = y + bias.astype(compute_dtype)
    return y.reshape((t, *lead, kernel.shape[-1]))


def zsc(spikes, kernel, bias=None, *, compute_dtype=jnp.float32):
    """Zig-Zag Spiking Convolution: 2x2/stride-2 conv over spike inputs.

    spikes: (T, B, H, W, C); kernel: (2, 2, C, F). The zig-zag placement of
    2x2 input submatrices across timesteps == space-to-depth so that every
    output pixel is one row of a T-fused matmul (full PE utilization).
    """
    x = space_to_depth(spikes, 2)                       # (T,B,H/2,W/2,4C)
    k = kernel.reshape((-1, kernel.shape[-1]))          # (4C, F)
    return wssl(x, k, bias, compute_dtype=compute_dtype)


def sssc(image_u8, kernel, bias=None, *, compute_dtype=jnp.float32):
    """Shift-and-Sum Spiking Convolution: first-layer 2x2/s2 conv on uint8.

    image_u8: (B, H, W, C) uint8; kernel: (2, 2, C, F). The 8-bit input is
    decomposed into 8 binary planes which run through the SAME binary datapath
    as WSSL/ZSC, then partial results are summed with shifts:
        y = sum_k 2^k * (plane_k . W)
    Output is (B, H/2, W/2, F) — identical to an 8-bit conv. Because the image
    is constant across timesteps, SSSC runs once and the result is reused for
    all T (paper Sec. II-D).
    """
    x = space_to_depth(image_u8, 2)                     # (B,H/2,W/2,4C) uint8
    planes = bitplanes_u8(x, dtype=compute_dtype)       # (8, B, H/2, W/2, 4C)
    k = kernel.reshape((-1, kernel.shape[-1]))
    per_plane = wssl(planes, k, None, compute_dtype=compute_dtype)  # (8,...,F)
    scales = (2.0 ** jnp.arange(8, dtype=compute_dtype)).reshape(
        (8,) + (1,) * (per_plane.ndim - 1))
    y = (per_plane * scales).sum(axis=0)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def stdp(q, k, v, *, scale: float, compute_dtype=jnp.float32):
    """Spiking Tile-wise Dot Product: softmax-free attention (Q Kt) V * scale.

    q, k, v: (T, B, H, N, Dh) binary spikes. Since spike attention has no
    softmax, each V column can be consumed as soon as it is produced; the
    reference computes Kt V first — an exactly equivalent associativity choice
    ((Q Kt) V == Q (Kt V)) that, like the paper's tiling, never materializes
    the N x N score matrix when N > Dh. The Pallas kernel
    (``kernels.stdp_attention``) implements the tile-fused streaming version.
    """
    qf = q.astype(compute_dtype)
    kf = k.astype(compute_dtype)
    vf = v.astype(compute_dtype)
    ctx = jnp.einsum("tbhnd,tbhnf->tbhdf", kf, vf)       # (T,B,H,Dh,Dh')
    out = jnp.einsum("tbhnd,tbhdf->tbhnf", qf, ctx) * scale
    return out
