"""Analytic VESTA engine model — reproduces the paper's Tables I & II.

VESTA: 512 PE units x 8 PE blocks = 4096 PEs @ 500 MHz. The paper counts a
MAC as 2 synaptic ops, so peak throughput = 4096 GSOPS (Table I). This module
counts, per layer of Spikformer V2-8-512 on a 224x224x3 image, the MACs each
of the four dataflows executes and converts them to cycles:

    cycles(op) = MACs(op) / (PE_TOTAL * utilization(op))

Two models are provided:
  * ideal      — utilization 1.0 for every dataflow (upper bound on the
                 published PE geometry).
  * calibrated — per-dataflow utilization BACK-SOLVED from the paper's
    Table II shares and the 30 fps claim (16.67 M cycles/frame). This is a
    reproduction artifact in its own right: it quantifies how far each VESTA
    dataflow runs from the unified-PE peak. (The paper's Table III already
    hints that only ZSC/SSSC "improve PE utilization" — WSSL and STDP are
    buffer-size optimizations, and indeed calibrate to far lower utilization.)

This model is also the bridge to the TPU port: same MAC counts, but the
denominator becomes the MXU peak and the packed-spike memory system — see
EXPERIMENTS.md section "Paper-validation".
"""
from __future__ import annotations

import dataclasses

from .spikformer import SpikformerConfig

PE_UNITS = 512
PE_BLOCKS_PER_UNIT = 8
PE_TOTAL = PE_UNITS * PE_BLOCKS_PER_UNIT        # 4096 PEs
FREQ_HZ = 500e6
# the paper counts a MAC as 2 synaptic ops: 4096 PEs x 0.5 GHz x 2 = 4096 GSOPS
PEAK_GSOPS = PE_TOTAL * FREQ_HZ * 2 / 1e9

# Paper Table II (percent of compute time) and the fps claim.
PAPER_TABLE2 = {"ZSC": 0.19, "SSSC": 4.13, "WSSL": 80.79, "STDP": 14.88}
PAPER_FPS = 30.0
PAPER_CYCLES_PER_FRAME = FREQ_HZ / PAPER_FPS     # 16.67 M


@dataclasses.dataclass
class OpCount:
    method: str        # ZSC | SSSC | WSSL | STDP
    layer: str
    macs: float        # 1b x 8b multiply-accumulates
    utilization: float = 1.0

    @property
    def cycles(self) -> float:
        return self.macs / (PE_TOTAL * self.utilization)


def spikformer_op_counts(cfg: SpikformerConfig | None = None) -> list[OpCount]:
    cfg = cfg or SpikformerConfig()
    t = cfg.timesteps
    ops: list[OpCount] = []
    side = cfg.img_size

    # --- SCS ---------------------------------------------------------------
    cin = cfg.in_channels
    for i, cout in enumerate(cfg.scs_channels):
        side //= 2
        out_elems = side * side * cout
        fan_in = 4 * cin                       # 2x2 kernel
        if i == 0:
            # SSSC: 8 bit-planes, runs ONCE (image constant across T)
            ops.append(OpCount("SSSC", f"scs.conv{i}", out_elems * fan_in * 8))
        else:
            # ZSC: T timesteps of spike input
            ops.append(OpCount("ZSC", f"scs.conv{i}", out_elems * fan_in * t))
        cin = cout

    # --- encoder blocks ------------------------------------------------------
    n = cfg.tokens
    d = cfg.dim
    dh = d // cfg.heads
    hidden = d * cfg.mlp_ratio
    for b in range(cfg.depth):
        # WSSL: q,k,v,proj linears + MLP1 + MLP2, all x T timesteps
        lin_macs = t * n * (4 * d * d + d * hidden + hidden * d)
        ops.append(OpCount("WSSL", f"block{b}.linears", lin_macs))
        # STDP: (Kt V) is d x n x d per head; Q (KtV) is n x d x d per head; x T
        stdp_macs = t * cfg.heads * (2 * n * dh * dh)
        ops.append(OpCount("STDP", f"block{b}.ssa_dotprod", stdp_macs))

    return ops


def macs_by_method(cfg: SpikformerConfig | None = None) -> dict[str, float]:
    out: dict[str, float] = {}
    for o in spikformer_op_counts(cfg):
        out[o.method] = out.get(o.method, 0.0) + o.macs
    return out


def implied_utilization(cfg: SpikformerConfig | None = None) -> dict[str, float]:
    """Back-solve each dataflow's PE utilization from Table II + 30 fps:
    cycles_m = share_m * 16.67M  =>  u_m = MACs_m / (4096 * cycles_m).
    Values are capped at 1.0; a cap indicates the paper's op count for that
    dataflow is smaller than our reconstruction (see EXPERIMENTS.md notes on
    ZSC / the unpublished SCS channel widths)."""
    macs = macs_by_method(cfg)
    util = {}
    for m, macs_m in macs.items():
        cycles_m = PAPER_TABLE2[m] / 100.0 * PAPER_CYCLES_PER_FRAME
        util[m] = min(1.0, macs_m / (PE_TOTAL * cycles_m))
    return util


def table2_distribution(cfg: SpikformerConfig | None = None,
                        *, calibrated: bool = False) -> dict[str, float]:
    """Computation-time share per dataflow (paper Table II)."""
    cfg = cfg or SpikformerConfig()
    util = implied_utilization(cfg) if calibrated else {}
    ops = spikformer_op_counts(cfg)
    by: dict[str, float] = {}
    for o in ops:
        u = util.get(o.method, 1.0)
        by[o.method] = by.get(o.method, 0.0) + o.macs / (PE_TOTAL * u)
    total = sum(by.values())
    return {k: 100.0 * v / total for k, v in sorted(by.items())}


def frames_per_second(cfg: SpikformerConfig | None = None,
                      *, calibrated: bool = False) -> float:
    cfg = cfg or SpikformerConfig()
    util = implied_utilization(cfg) if calibrated else {}
    cycles = sum(o.macs / (PE_TOTAL * util.get(o.method, 1.0))
                 for o in spikformer_op_counts(cfg))
    return FREQ_HZ / cycles


def table1_summary() -> dict[str, float]:
    """Engine-level numbers comparable to paper Table I."""
    return {
        "pe_number": PE_TOTAL,
        "frequency_mhz": FREQ_HZ / 1e6,
        "peak_gsops": PEAK_GSOPS,
        "ideal_fps": frames_per_second(),
        "calibrated_fps": frames_per_second(calibrated=True),
        "paper_fps": PAPER_FPS,
        "total_gmacs_per_frame": sum(macs_by_method().values()) / 1e9,
    }
