"""VESTA core: the paper's contribution — spiking transformer compute with
unified dataflows (ZSC / SSSC / WSSL / STDP) and the Temporal-Fused LIF."""
from . import lif, spike, unified, ssa, spikformer, engine_model  # noqa: F401
